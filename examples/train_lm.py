"""End-to-end driver: train a ~large-M-parameter LM for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch mamba2-130m]
      [--steps 300] [--full]

Uses the production training driver (sharding, checkpoint/restart,
straggler detection).  By default trains the reduced config on CPU and
prints the loss trajectory; --full selects the real config (TPU-scale).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2.5-3b")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3,
                   help="peak LR (default tuned for the smoke-scale configs)")
    p.add_argument("--full", action="store_true",
                   help="use the full (TPU-scale) config")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    from repro.configs import get_config
    from repro.launch.train import train

    cfg = get_config(args.arch, smoke=not args.full)
    print(f"[example] training {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M "
          f"params, {args.steps} steps @ batch {args.batch} x seq {args.seq}")
    state = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                  lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                  log_every=25)
    losses = np.asarray(state["losses"])
    k = max(len(losses) // 10, 1)
    first, last = losses[:k].mean(), losses[-k:].mean()
    print(f"[example] loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first else 'NOT LEARNING'})")


if __name__ == "__main__":
    main()
