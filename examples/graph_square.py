"""Graph squaring with sparse-output SpGEMM: A @ A and chained A @ A @ A.

Run:  PYTHONPATH=src python examples/graph_square.py

Squares a sparse R-MAT graph on a 2x2 device grid with
``matmul(..., output="sparse")``: the symbolic phase predicts C's block
structure host-side, the numeric phase accumulates straight into packed
blocks, and the result is a ``DistBSR`` handle — so the cube chains through
a second multiply without ever materializing (or re-tiling) a dense
intermediate.  Compares footprints against the dense-output path and
verifies both against a numpy oracle.

(The companion ``examples/spgemm_graph.py`` does dense-output triangle
counting; this example is the sparse-output / chained-multiply story.)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime.platform import set_host_device_count  # noqa: E402

set_host_device_count(4)      # before jax init (single XLA_FLAGS write site)

import numpy as np  # noqa: E402

from repro.core import api
from repro.core.api import DistBSR
from repro.core.bsr import rmat_matrix
from repro.core.dist import make_grid_mesh


def main():
    a = rmat_matrix(scale=8, edgefactor=1, seed=11)   # sparse digraph
    g = 2
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a, g=g, block_size=8)

    # A^2 with a sparse DistBSR output (plan epilogue packs, not densifies)
    a2 = api.matmul(a_h, a_h, mesh=mesh, algorithm="ring_c", impl="ref",
                    output="sparse")
    assert isinstance(a2, DistBSR)
    sym = api.symbolic_spgemm(a_h.tiled, a_h.tiled)
    dense_bytes = a.size * 4
    print(f"A^2 predicted block density: {sym.density():.3f}")
    print(f"A^2 packed footprint: {a2.footprint_bytes():,} B "
          f"(dense output: {dense_bytes:,} B, "
          f"{dense_bytes / a2.footprint_bytes():.1f}x larger)")

    # chained cube: the sparse handle is the next left operand, no densify
    a3 = api.matmul(a2, a_h, mesh=mesh, algorithm="ring_c", impl="ref",
                    output="sparse")
    print(f"A^3 packed footprint: {a3.footprint_bytes():,} B "
          f"(capacity {a3.capacity} blocks/tile)")

    want2, want3 = a @ a, a @ a @ a
    err2 = float(np.abs(np.asarray(a2.densify()) - want2).max())
    err3 = float(np.abs(np.asarray(a3.densify()) - want3).max())
    print(f"max|A^2 err| = {err2:.2e}   max|A^3 err| = {err3:.2e}")
    assert err2 < 1e-3 and err3 < 1e-3, "mismatch!"

    # the dense-output path agrees bit-for-bit on the logical values
    a2_dense = np.asarray(api.matmul(a_h, a_h, mesh=mesh,
                                     algorithm="ring_c", impl="ref"))
    print(f"dense-output agreement: max|diff| = "
          f"{np.abs(a2_dense - np.asarray(a2.densify())).max():.2e}")
    print("MATCH — sparse-output SpGEMM chains without densifying")


if __name__ == "__main__":
    main()
