"""Quickstart: the paper's distributed sparse matmul engine in 5 minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

Builds an R-MAT sparse matrix, wraps it in a persistent :class:`DistBSR`
handle (the analogue of the paper's BCL distributed matrix: placement/skew
decided once, reused forever), plans every algorithm from the paper — the
bulk-synchronous SUMMA baselines and the asynchronous RDMA-style rings —
through the plan-based API (``repro.core.api``), and checks each against a
dense reference.  Because the operands are handles and the executables are
plans, the second call of any plan is pure communication + compute: no
re-pad, no re-skew, no re-trace (``plan.traces`` stays at 1).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime.platform import set_host_device_count  # noqa: E402

set_host_device_count(4)      # before jax init (single XLA_FLAGS write site)

import jax.numpy as jnp  # noqa: E402
import numpy as np

from repro.core import api
from repro.core.api import DistBSR, DistDense
from repro.core.bsr import BSR, rmat_matrix
from repro.core.dist import make_grid_mesh
from repro.core.roofline import SUMMIT_V100, TPU_V5E, spmm_model
from repro.core.schedule import stage_imbalance
from repro.kernels import ops


def main():
    # --- 1. a skewed sparse matrix (R-MAT scale 8, like the paper's Fig 1) --
    a_dense = rmat_matrix(scale=8, edgefactor=8, seed=0)   # 256 x 256
    n_cols = 32
    b = np.random.default_rng(0).standard_normal((256, n_cols)).astype(
        np.float32)

    # --- 2. local kernel: Pallas BSR SpMM vs reference ----------------------
    a_local = BSR.from_dense(a_dense, block_size=8)
    y_ref = np.asarray(ops.bsr_spmm(a_local, jnp.asarray(b), impl="ref"))
    y_pal = np.asarray(ops.bsr_spmm(a_local, jnp.asarray(b),
                                    impl="interpret", block_n=8))
    print(f"local kernel: nnz blocks={a_local.nnzb}, "
          f"fill={a_local.block_fill_ratio():.2f}, "
          f"pallas-vs-ref max err={np.abs(y_ref - y_pal).max():.2e}")

    # --- 3. distributed algorithms on a 2x2 device grid ---------------------
    # DistMatrix handles are built ONCE; each algorithm's skew placement is
    # materialized lazily on first use and cached on the handle.
    g = 2
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a_dense, g=g, block_size=8)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    want = a_dense @ b
    print(f"\ndistributed SpMM on {g}x{g} grid (tile load imbalance = "
          f"{a_h.tiled.load_imbalance():.2f}):")
    for alg in api.algorithms():
        plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                               impl="ref")
        got = plan(a_h, b_h)
        got = plan(a_h, b_h)          # second call: cached executable
        err = np.abs(np.asarray(got) - want).max()
        style = api.REGISTRY.get(alg).style.upper().ljust(4)
        print(f"  [{style}] {alg:12s} max err {err:.2e} "
              f"(traces={plan.traces})")

    # --- 3b. sparsity-aware planning: balanced tiling + auto-scheduling -----
    # balance="rows" spreads nonzero blocks over grid rows before tiling
    # (shrinking the uniform capacity every device executes); the carried
    # permutation is inverted in the epilogue, so results are unchanged.
    # algorithm="auto" scores every schedule's cost model and builds the
    # cheapest.
    a_bal = DistBSR.from_dense(a_dense, g=g, block_size=8, balance="rows")
    b_bal = DistDense.for_rhs(jnp.asarray(b), a_bal)
    plan_auto = api.plan_matmul(a_bal, b_bal, mesh=mesh, algorithm="auto",
                                impl="ref")
    err = np.abs(np.asarray(plan_auto(a_bal, b_bal)) - want).max()
    print(f"\nbalanced tiling: capacity {a_h.capacity} -> {a_bal.capacity}, "
          f"padded-flop waste {a_h.tiled.padded_flop_waste():.2f} -> "
          f"{a_bal.tiled.padded_flop_waste():.2f}; "
          f"auto chose {plan_auto.algorithm.name!r} (max err {err:.2e})")

    # --- 4. the paper's Fig-1 story: sync amplifies imbalance ---------------
    counts = np.asarray(a_h.counts, dtype=np.float64)
    per_stage, end_to_end = stage_imbalance(counts)
    print(f"\nload imbalance (flops max/avg): per-stage (BSP) "
          f"{per_stage:.2f}x vs end-to-end (async) {end_to_end:.2f}x")

    # --- 5. the paper's SS4 inter-node roofline ------------------------------
    # The paper-exact model (density-based, CSR wire format) ...
    d = a_dense.mean()
    for mach in (SUMMIT_V100, TPU_V5E):
        m = spmm_model(256, 256, n_cols, g * g, float(d), mach)
        print(f"roofline[{mach.name}]: AI_net={m['ai_net']:.2f} fl/B, "
              f"predicted {m['perf'] / 1e9:.1f} GF/s/chip "
              f"({'network' if m['net_bound'] else 'compute'}-bound)")
    # ... and the plan's own cost model (padded-BSR wire format, per step):
    plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                           impl="ref")
    cm = plan.cost_model(a_h)
    pp = plan.predicted_perf(TPU_V5E)
    print(f"plan cost model[ring_c]: {cm['net_bytes_per_step']:.0f} B/step, "
          f"AI_net={cm['ai_net']:.2f} fl/B, predicted "
          f"{pp['perf'] / 1e9:.1f} GF/s/chip on {TPU_V5E.name}")


if __name__ == "__main__":
    main()
