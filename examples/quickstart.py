"""Quickstart: the paper's distributed sparse matmul engine in 5 minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

Builds an R-MAT sparse matrix, distributes it over a (fake multi-device)
2x2 grid, and runs every algorithm from the paper — bulk-synchronous SUMMA
and the asynchronous RDMA-style ring algorithms — checking them against a
dense reference and printing the communication-balance story.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import spmm as dspmm
from repro.core.bsr import BSR, TiledBSR, rmat_matrix
from repro.core.dist import make_grid_mesh
from repro.core.grid import ProcessGrid
from repro.core.roofline import SUMMIT_V100, TPU_V5E, spmm_model
from repro.core.schedule import stage_imbalance
from repro.kernels import ops


def main():
    # --- 1. a skewed sparse matrix (R-MAT scale 8, like the paper's Fig 1) --
    a_dense = rmat_matrix(scale=8, edgefactor=8, seed=0)   # 256 x 256
    n_cols = 32
    b = np.random.default_rng(0).standard_normal((256, n_cols)).astype(
        np.float32)

    # --- 2. local kernel: Pallas BSR SpMM vs reference ----------------------
    a_local = BSR.from_dense(a_dense, block_size=8)
    y_ref = np.asarray(ops.bsr_spmm(a_local, jnp.asarray(b), impl="ref"))
    y_pal = np.asarray(ops.bsr_spmm(a_local, jnp.asarray(b),
                                    impl="interpret", block_n=8))
    print(f"local kernel: nnz blocks={a_local.nnzb}, "
          f"fill={a_local.block_fill_ratio():.2f}, "
          f"pallas-vs-ref max err={np.abs(y_ref - y_pal).max():.2e}")

    # --- 3. distributed algorithms on a 2x2 device grid ---------------------
    g = 2
    mesh = make_grid_mesh(g)
    grid = ProcessGrid(g, g)
    a_tiled = TiledBSR.from_dense(a_dense, grid, block_size=8)
    want = a_dense @ b
    print(f"\ndistributed SpMM on {g}x{g} grid "
          f"(tile load imbalance = {a_tiled.load_imbalance():.2f}):")
    for alg in dspmm.ALGORITHMS:
        got = dspmm.spmm(a_tiled, jnp.asarray(b), mesh=mesh, algorithm=alg,
                         impl="ref")
        err = np.abs(np.asarray(got) - want).max()
        style = "BSP " if alg.startswith("summa") else "RDMA"
        print(f"  [{style}] {alg:12s} max err {err:.2e}")

    # --- 4. the paper's Fig-1 story: sync amplifies imbalance ---------------
    counts = np.asarray(a_tiled.counts, dtype=np.float64)
    per_stage, end_to_end = stage_imbalance(counts)
    print(f"\nload imbalance (flops max/avg): per-stage (BSP) "
          f"{per_stage:.2f}x vs end-to-end (async) {end_to_end:.2f}x")

    # --- 5. the paper's SS4 inter-node roofline ------------------------------
    d = a_dense.mean()
    for mach in (SUMMIT_V100, TPU_V5E):
        m = spmm_model(256, 256, n_cols, g * g, float(d), mach)
        print(f"roofline[{mach.name}]: AI_net={m['ai_net']:.2f} fl/B, "
              f"predicted {m['perf'] / 1e9:.1f} GF/s/chip "
              f"({'network' if m['net_bound'] else 'compute'}-bound)")


if __name__ == "__main__":
    main()
