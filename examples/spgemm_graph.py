"""Graph analytics with distributed SpGEMM: triangle counting (paper SS6.2
motivates SpGEMM with exactly this class of algorithm).

Run:  PYTHONPATH=src python examples/spgemm_graph.py

Counts triangles in an R-MAT graph via tr(A^3)/6, computing A @ A with the
paper's asynchronous ring algorithm on a 2x2 device grid through the
plan-based API (one ``DistBSR`` handle used for both operands, so the skew
placements are shared) and comparing against a dense-numpy oracle.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime.platform import set_host_device_count  # noqa: E402

set_host_device_count(4)      # before jax init (single XLA_FLAGS write site)

import numpy as np  # noqa: E402

from repro.core import api
from repro.core.api import DistBSR
from repro.core.bsr import rmat_matrix
from repro.core.dist import make_grid_mesh


def main():
    a = rmat_matrix(scale=7, edgefactor=4, seed=7)
    a = np.minimum(a + a.T, 1.0)            # undirected, unweighted
    np.fill_diagonal(a, 0.0)

    g = 2
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a, g=g, block_size=8)

    # A2 = A @ A via the paper's ring stationary-C SpGEMM
    a2 = np.asarray(api.matmul(a_h, a_h, mesh=mesh, algorithm="ring_c",
                               impl="ref"))
    # triangles = trace(A^3) / 6 = sum(A * A^2) / 6
    tri = float((a * a2).sum() / 6.0)
    tri_ref = float(np.trace(a @ a @ a) / 6.0)
    print(f"triangles (distributed SpGEMM): {tri:.0f}")
    print(f"triangles (dense oracle):       {tri_ref:.0f}")
    assert abs(tri - tri_ref) < 1e-3, "mismatch!"
    print("MATCH — distributed SpGEMM is exact on this graph")

    # also show the BSP baseline gives the same result
    a2_bsp = np.asarray(api.matmul(a_h, a_h, mesh=mesh,
                                   algorithm="summa_bcast", impl="ref"))
    print(f"BSP SUMMA agreement: max|diff| = {np.abs(a2 - a2_bsp).max():.2e}")


if __name__ == "__main__":
    main()
