"""Serve a small model with batched requests: prefill + greedy decode.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch recurrentgemma-2b]

Exercises the full serving path (batched prefill, ring-buffer KV caches /
recurrent states, stepwise decode) and verifies the decoded continuation
against a full-forward recomputation.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="recurrentgemma-2b")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen-len", type=int, default=12)
    args = p.parse_args()

    import jax.numpy as jnp
    import jax

    from repro.configs import get_config
    from repro.launch.serve import serve
    from repro.models import lm, transformer as tf

    cfg = get_config(args.arch, smoke=True)
    out = serve(cfg, requests=args.requests, prompt_len=args.prompt_len,
                gen_len=args.gen_len, seed=0)
    print(f"[serve] {args.arch}: prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_s']:.2f}s "
          f"({out['decode_tok_per_s']:.1f} tok/s on CPU)")
    print(f"[serve] generations:\n{out['generated']}")

    # verify greedy decode against teacher-forced full forward
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, args.prompt_len)), jnp.int32)
    gen = lm.greedy_decode(params, {"tokens": prompts}, cfg, steps=4,
                           max_len=args.prompt_len + 8)
    full = jnp.concatenate([prompts, gen[:, :3]], axis=1)
    logits, _, _ = tf.forward(params, {"tokens": full}, cfg)
    redo = jnp.argmax(logits[:, args.prompt_len - 1:], axis=-1)
    assert (np.asarray(redo[:, :4]) == np.asarray(gen)).all(), \
        "greedy decode disagrees with teacher-forced forward"
    print("[serve] greedy decode == teacher-forced forward ✓")


if __name__ == "__main__":
    main()
