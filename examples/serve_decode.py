"""Serve a small model through ServeEngine: continuous batching + greedy
decode, verified against the dense reference path.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch llama3-8b]

Exercises the request-level serving path (bucketed admission, per-request
positions, KV-row splicing at step boundaries) and verifies each decoded
continuation against an unbatched ``lm.greedy_decode`` of the same prompt.
"""
import argparse

import numpy as np


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="recurrentgemma-2b")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen-len", type=int, default=12)
    p.add_argument("--sparse", action="store_true",
                   help="MoE dispatch / attention scoring via plan_matmul")
    args = p.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import lm, transformer as tf
    from repro.serving import ServeEngine

    cfg = get_config(args.arch, smoke=True)
    max_len = args.prompt_len + args.gen_len + 8
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (args.prompt_len,))
               for _ in range(args.requests)]

    engine = ServeEngine(cfg, params=params, max_batch=2, max_len=max_len,
                         sparse=args.sparse)
    for toks in prompts:
        engine.submit(toks, max_new_tokens=args.gen_len)
    results = engine.run()
    m = engine.summary()
    print(f"[serve] {args.arch}: prefill {m['prefill_s']:.2f}s, "
          f"decode {m['decode_s']:.2f}s "
          f"({(m['decode_tok_per_s'] or 0):.1f} tok/s on CPU)")
    print(f"[serve] ttft p50 {m['ttft_p50_s']:.3f}s, "
          f"tpot p50 {m['tpot_p50_s']:.3f}s, "
          f"dropped mean {m['dropped_mean']:.4f}")
    print(f"[serve] generations:\n"
          f"{np.stack([results[r] for r in sorted(results)])}")

    # verify continuous-batched decode against the unbatched reference
    for rid, toks in enumerate(prompts):
        ref = lm.greedy_decode(params, {"tokens": jnp.asarray(toks[None])},
                               cfg, steps=args.gen_len, max_len=max_len)
        assert (np.asarray(ref)[0] == results[rid]).all(), \
            f"request {rid} diverges from the dense reference"
    print("[serve] engine decode == unbatched greedy reference ✓")


if __name__ == "__main__":
    main()
