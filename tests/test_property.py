"""Hypothesis property tests for system invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.bsr import BSR, TiledBSR, random_sparse
from repro.core.dist import skew_dense, tileize, unskew_c_rows, untileize
from repro.core.grid import ProcessGrid, ceil_div, pad_to_multiple
from repro.core.roofline import spmm_internode_ai, spmm_local_ai
from repro.kernels import ops


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 16))
def test_pad_to_multiple_properties(a, b, m):
    p = pad_to_multiple(a, m)
    assert p >= a and p % m == 0 and p - a < m
    assert ceil_div(a, b) == -(-a // b)


@given(st.integers(4, 40), st.integers(4, 40),
       st.sampled_from([2, 4, 8]),
       st.floats(0.0, 1.0), st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_bsr_roundtrip_property(m, n, bs, density, seed):
    d = random_sparse(m, n, density, seed=seed)
    a = BSR.from_dense(d, bs)
    back = np.asarray(a.to_dense())[:m, :n]
    np.testing.assert_array_equal(back, d)
    # rows stay sorted (kernel contract), even with extra padding
    r = np.asarray(a.with_capacity(a.capacity + 3).rows)
    assert (np.diff(r) >= 0).all()


@given(st.integers(0, 5), st.sampled_from([8, 16]),
       st.floats(0.05, 0.6))
@settings(max_examples=15, deadline=None)
def test_spmm_kernel_linearity(seed, size, density):
    """BSR(a) @ (x + y) == BSR(a) @ x + BSR(a) @ y (ref impl)."""
    a_d = random_sparse(size, size, density, seed=seed)
    a = BSR.from_dense(a_d, 4)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((size, 4)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((size, 4)), jnp.float32)
    lhs = ops.bsr_spmm(a, x + y, impl="ref")
    rhs = ops.bsr_spmm(a, x, impl="ref") + ops.bsr_spmm(a, y, impl="ref")
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@given(st.sampled_from([2, 3, 4]), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_skew_unskew_inverse(g, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4 * g, 4 * g)), jnp.float32)
    sk = skew_dense(x, g, "rows")
    np.testing.assert_array_equal(np.asarray(unskew_c_rows(sk, g)),
                                  np.asarray(x))
    # tileize/untileize inverse
    np.testing.assert_array_equal(np.asarray(untileize(tileize(x, g))),
                                  np.asarray(x))


@given(st.sampled_from([2, 4]), st.floats(0.05, 0.9), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_tiled_bsr_counts_conserve_nnzb(g, density, seed):
    d = random_sparse(8 * g, 8 * g, density, seed=seed)
    t = TiledBSR.from_dense(d, ProcessGrid(g, g), block_size=4)
    total_tiles = int(np.asarray(t.counts).sum())
    whole = BSR.from_dense(d, 4)
    # tiling never merges blocks; block counts can only grow at tile edges
    assert total_tiles >= whole.nnzb
    assert t.capacity >= int(np.asarray(t.counts).max())


@given(st.integers(32, 2048))
@settings(max_examples=20, deadline=None)
def test_roofline_monotone_in_width(n):
    lo = spmm_internode_ai(1 << 16, 1 << 16, n, 16, 1e-3)
    hi = spmm_internode_ai(1 << 16, 1 << 16, 2 * n, 16, 1e-3)
    assert hi > lo
    # local AI <= inter-node AI (local includes C bytes in denominator)
    assert spmm_local_ai(1 << 16, 1 << 16, n, 16, 1e-3) < lo
