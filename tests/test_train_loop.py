"""Training-loop integration: loss decreases, checkpoint/restart resumes
bit-identically, fault injection recovers."""
import os

import numpy as np
import pytest


def test_loss_decreases_tiny_lm(tmp_path):
    from repro.configs import get_config
    from repro.launch.train import train

    cfg = get_config("qwen2.5-3b", smoke=True)
    state = train(cfg, steps=30, batch=4, seq=32, lr=3e-3,
                  ckpt_dir=None, log_every=0)
    losses = np.asarray(state["losses"])
    assert np.isfinite(losses).all()
    assert losses[-5:].mean() < losses[:5].mean(), \
        f"loss did not decrease: {losses[:5]} -> {losses[-5:]}"


def test_checkpoint_resume_bit_identical(tmp_path):
    """train 20 straight == train 10, 'crash', resume to 20."""
    import jax
    from repro.configs import get_config
    from repro.launch.train import train

    cfg = get_config("mamba2-130m", smoke=True)
    d1 = str(tmp_path / "straight")
    s_full = train(cfg, steps=20, batch=2, seq=16, ckpt_dir=d1,
                   ckpt_every=100, log_every=0, seed=7)

    d2 = str(tmp_path / "resumed")
    s_a = train(cfg, steps=20, batch=2, seq=16, ckpt_dir=d2,
                ckpt_every=100, log_every=0, seed=7, stop_after=10)
    # relaunch with the same job config: restores step-10 and continues
    s_b = train(cfg, steps=20, batch=2, seq=16, ckpt_dir=d2,
                ckpt_every=100, log_every=0, seed=7)

    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_restartable_loop_recovers():
    from repro.runtime import RestartableLoop

    calls = {"n": 0, "recovered": 0}

    def body(step):
        calls["n"] += 1
        if step == 3 and calls["recovered"] == 0:
            raise RuntimeError("injected node failure")

    def recover():
        calls["recovered"] += 1
        return 2  # checkpoint was at step 2

    loop = RestartableLoop(6, recover, max_restarts=2)
    end = loop.run(body, 0)
    assert end == 6
    assert calls["recovered"] == 1


def test_restartable_loop_bounded_restarts():
    from repro.runtime import RestartableLoop

    def body(step):
        raise RuntimeError("always fails")

    loop = RestartableLoop(4, lambda: 0, max_restarts=2)
    with pytest.raises(RuntimeError):
        loop.run(body, 0)


def test_straggler_detector_flags_outlier():
    from repro.runtime import StragglerDetector

    det = StragglerDetector(alpha=0.3, threshold=3.0, warmup=3)
    flagged = []
    for step in range(20):
        dt = 1.0 + 0.01 * (step % 3)
        if step == 15:
            dt = 10.0
        if det.observe(step, dt):
            flagged.append(step)
    assert flagged == [15]


def test_elastic_mesh_choice():
    from repro.runtime.elastic import choose_mesh_shape

    # full pod
    assert choose_mesh_shape(256, model_divisors=(64,), max_model=16) \
        == (16, 16)
    # lost 6 nodes of 64 chips... shaves to a usable count
    data, model = choose_mesh_shape(250, model_divisors=(64,), max_model=16)
    assert data * model <= 250
    assert data * model >= int(250 * 0.875)
    assert 64 % model == 0
