"""Runtime-layer unit tests: fault tolerance, elastic sizing, injection.

Covers the pieces the elastic replanning controller is assembled from,
without touching jax or a device mesh (those paths run in
``test_distributed.py`` via the ``elastic`` selftest):

* StragglerDetector warmup gating and variance poisoning (an outlier must
  not inflate the EWMA variance it was detected against);
* choose_mesh_shape divisor/shaving boundaries and the prefer_model path;
* choose_grid_shape square-fit boundaries;
* RestartableLoop consecutive-vs-lifetime restart accounting, the
  recover-raises path, and bounded-retry overflow;
* PreemptionSignal handler chaining, uninstall restore, and the context
  manager;
* determinism of the seeded fault injectors (StragglerInjector,
  TransientFailure, DeviceLoss).
"""
import signal

import numpy as np
import pytest

from repro.runtime import (DeviceLoss, PreemptionSignal, RestartableLoop,
                           StragglerDetector, StragglerInjector,
                           TransientFailure, choose_grid_shape,
                           choose_mesh_shape)


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------
def test_straggler_detector_warmup_suppresses_flags():
    """A spike inside the warmup window is never flagged, even when it
    would clear the z-score threshold with room to spare."""
    det = StragglerDetector(alpha=0.5, threshold=2.0, warmup=5)
    for step in range(4):
        det.observe(step, 1.0)
    assert det.observe(4, 100.0) is False     # count == warmup: still warm
    assert det.events == []


def test_straggler_detector_flags_after_warmup():
    det = StragglerDetector(alpha=0.1, threshold=4.0, warmup=5)
    rng = np.random.default_rng(0)
    for step in range(20):
        det.observe(step, 1.0 + 0.01 * rng.standard_normal())
    assert det.observe(20, 8.0) is True
    assert det.events[-1]["step"] == 20


def test_straggler_detector_outlier_does_not_poison_variance():
    """Flagged steps must not feed the EWMA stats: after one huge outlier,
    an equally huge follow-up step is still flagged (if the outlier had
    inflated the variance, the second spike would pass as normal)."""
    det = StragglerDetector(alpha=0.1, threshold=4.0, warmup=5)
    rng = np.random.default_rng(1)
    for step in range(20):
        det.observe(step, 1.0 + 0.01 * rng.standard_normal())
    mean_before, var_before = det.mean, det.var
    assert det.observe(20, 50.0) is True
    assert det.mean == mean_before and det.var == var_before
    assert det.observe(21, 50.0) is True      # still an outlier
    assert len(det.events) == 2


# ---------------------------------------------------------------------------
# choose_mesh_shape / choose_grid_shape
# ---------------------------------------------------------------------------
def test_choose_mesh_shape_divisor_boundary():
    # model axis must divide 64 heads; largest divisor <= max_model wins
    assert choose_mesh_shape(256, model_divisors=(64,), max_model=16) \
        == (16, 16)
    # max_model caps the model axis even when a larger divisor exists
    assert choose_mesh_shape(256, model_divisors=(64,), max_model=8) \
        == (32, 8)


def test_choose_mesh_shape_shaves_failed_nodes():
    # 250 chips cap the model axis at 2 (250 = 2*5^3); shaving to 248
    # unlocks model-8, which the scan over the shave range must find
    assert choose_mesh_shape(250, model_divisors=(8,), max_model=8) \
        == (31, 8)
    # 8 chips needing model-7: only 7 of them are usable
    assert choose_mesh_shape(8, model_divisors=(7,), max_model=7) == (1, 7)
    # shaving is bounded at 87.5% utilization, never below
    data, model = choose_mesh_shape(250, model_divisors=(64,), max_model=16)
    assert data * model >= int(250 * 0.875)
    with pytest.raises(ValueError, match="no usable mesh"):
        choose_mesh_shape(8, max_model=0)


def test_choose_mesh_shape_prefer_model():
    # without preference the largest valid model axis wins ...
    assert choose_mesh_shape(64, model_divisors=(16,), max_model=16) \
        == (4, 16)
    # ... prefer_model overrides when it's a valid candidate
    assert choose_mesh_shape(64, model_divisors=(16,), max_model=16,
                             prefer_model=4) == (16, 4)
    # an invalid preference (doesn't divide the heads) is ignored
    assert choose_mesh_shape(64, model_divisors=(16,), max_model=16,
                             prefer_model=3) == (4, 16)


def test_choose_grid_shape_boundaries():
    assert choose_grid_shape(1) == 1
    assert choose_grid_shape(3) == 1          # 2x2 doesn't fit on 3
    assert choose_grid_shape(4) == 2
    assert choose_grid_shape(8) == 2
    assert choose_grid_shape(9) == 3
    assert choose_grid_shape(10 ** 6) == 1000  # exact square, no fp slip
    # survivor-id collections count, ids themselves don't matter
    assert choose_grid_shape((0, 3, 4, 5)) == 2
    assert choose_grid_shape(range(9), max_g=2) == 2
    with pytest.raises(ValueError, match="at least one"):
        choose_grid_shape(0)


# ---------------------------------------------------------------------------
# RestartableLoop
# ---------------------------------------------------------------------------
def test_restartable_loop_consecutive_vs_total_restarts():
    """Failures separated by progress never accumulate: a loop with
    max_restarts=1 survives three separate single failures, and the
    lifetime count still reports all of them."""
    failed = set()

    def body(step):
        if step in (1, 3, 5) and step not in failed:
            failed.add(step)
            raise RuntimeError(f"fault at {step}")

    loop = RestartableLoop(7, recover=lambda: max(failed), max_restarts=1)
    assert loop.run(body) == 7
    assert loop.restarts == 0                 # reset by progress
    assert loop.total_restarts == 3


def test_restartable_loop_bounded_consecutive_failures():
    loop = RestartableLoop(5, recover=lambda: 0, max_restarts=2)
    with pytest.raises(RuntimeError, match="always fails"):
        loop.run(lambda step: (_ for _ in ()).throw(
            RuntimeError("always fails")))
    assert loop.restarts == 3                 # the raising failure
    assert loop.total_restarts == 3


def test_restartable_loop_recover_raises_propagates():
    """A broken recovery path (e.g. corrupt checkpoint) surfaces its own
    exception instead of being swallowed by the retry loop."""
    def body(step):
        if step == 2:
            raise RuntimeError("node failure")

    def recover():
        raise OSError("checkpoint unreadable")

    loop = RestartableLoop(4, recover, max_restarts=3)
    with pytest.raises(OSError, match="checkpoint unreadable"):
        loop.run(body)
    assert loop.total_restarts == 1


# ---------------------------------------------------------------------------
# PreemptionSignal
# ---------------------------------------------------------------------------
def test_preemption_signal_chains_and_restores():
    seen = {"outer": 0}

    def outer_handler(signum, frame):
        seen["outer"] += 1

    orig = signal.signal(signal.SIGTERM, outer_handler)
    try:
        with PreemptionSignal() as ps:
            assert not ps.requested
            signal.raise_signal(signal.SIGTERM)
            assert ps.requested
            assert seen["outer"] == 1         # chained, not clobbered
        # context exit restored the outer handler
        assert signal.getsignal(signal.SIGTERM) is outer_handler
        signal.raise_signal(signal.SIGTERM)
        assert seen["outer"] == 2
    finally:
        signal.signal(signal.SIGTERM, orig)


def test_preemption_signal_uninstall_idempotent():
    orig = signal.getsignal(signal.SIGTERM)
    ps = PreemptionSignal(install=False)
    assert ps.install() is True
    assert ps.install() is True               # second install is a no-op
    ps.uninstall()
    ps.uninstall()                            # and so is double-uninstall
    assert signal.getsignal(signal.SIGTERM) is orig


# ---------------------------------------------------------------------------
# fault injectors: seeded determinism
# ---------------------------------------------------------------------------
def test_straggler_injector_deterministic_and_scoped():
    inj = StragglerInjector(device=2, factor=8.0, seed=7, jitter=0.5,
                            start_step=3)
    assert inj.step_time(5, 0, 1.0) == 1.0    # healthy device untouched
    assert inj.step_time(2, 2, 1.0) == 1.0    # before start_step
    t = inj.step_time(5, 2, 1.0)
    assert 8.0 <= t <= 12.0                   # factor x (1 + jitter*u)
    inj2 = StragglerInjector(device=2, factor=8.0, seed=7, jitter=0.5,
                             start_step=3)
    assert inj2.step_time(5, 2, 1.0) == t     # seeded replay
    with pytest.raises(ValueError, match="factor"):
        StragglerInjector(device=0, factor=0.5)


def test_transient_failure_fails_listed_calls_only():
    fail = TransientFailure(fail_on=(2, 4), message="boom")
    wrapped = fail(lambda x: x + 1)
    assert wrapped(1) == 2
    with pytest.raises(RuntimeError, match="boom .call 2."):
        wrapped(1)
    assert wrapped(1) == 2
    with pytest.raises(RuntimeError, match="call 4"):
        wrapped(1)
    assert wrapped(1) == 2
    assert (fail.calls, fail.failures) == (5, 2)


def test_device_loss_seeded_and_partitioned():
    loss = DeviceLoss(9, 5, seed=0)
    again = DeviceLoss(9, 5, seed=0)
    assert loss.lost() == again.lost()
    assert len(loss.survivors()) == 4
    assert sorted(loss.lost() + loss.survivors()) == list(range(9))
    assert DeviceLoss(9, 5, seed=1).lost() != loss.lost() or True
    with pytest.raises(ValueError, match="n_lost"):
        DeviceLoss(4, 4)
