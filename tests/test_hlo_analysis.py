"""Validate the compile-time HLO profiler against ground truth.

The critical property: a scanned (while-loop) model must report the same
dot-flops and collective bytes as its unrolled twin — i.e. trip-count
multiplication works.  These tests compile tiny modules on 1 CPU device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    a = jnp.zeros((128, 64))
    b = jnp.zeros((64, 32))
    txt = _compile_text(lambda x, y: x @ y, a, b)
    stats = analyze_hlo(txt)
    assert stats.dot_flops == pytest.approx(2 * 128 * 64 * 32, rel=0.01)


def test_scan_flops_match_unrolled():
    w = jnp.zeros((8, 64, 64))
    x = jnp.zeros((4, 64))

    def scanned(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(w, x):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    s_scan = analyze_hlo(_compile_text(scanned, w, x))
    s_unr = analyze_hlo(_compile_text(unrolled, w, x))
    assert s_scan.dot_flops == pytest.approx(s_unr.dot_flops, rel=0.05)
    assert s_scan.dot_flops == pytest.approx(8 * 2 * 4 * 64 * 64, rel=0.05)
    # memory proxy should agree within 2x (fusion boundaries may differ)
    assert s_scan.hbm_bytes == pytest.approx(s_unr.hbm_bytes, rel=1.0)


def test_grad_flops_scale():
    """Backward of y = x@w costs ~2 extra dots."""
    w = jnp.zeros((64, 64))
    x = jnp.zeros((16, 64))

    fwd = analyze_hlo(_compile_text(lambda w, x: (x @ w).sum(), w, x))
    bwd = analyze_hlo(_compile_text(
        jax.grad(lambda w, x: (x @ w).sum()), w, x))
    assert bwd.dot_flops >= fwd.dot_flops  # at least the dL/dw dot


def test_no_collectives_on_single_device():
    x = jnp.zeros((8, 8))
    stats = analyze_hlo(_compile_text(lambda x: x * 2, x))
    assert stats.total_collective_bytes == 0


# ---------------------------------------------------------------------------
# Overlap classification (analyze_overlap)
# ---------------------------------------------------------------------------
from repro.launch.hlo_analysis import analyze_overlap  # noqa: E402

_OVERLAPPED_HLO = """
HloModule overlap_fixture

ENTRY %main (p0: f32[8,8], p1: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %p1 = f32[8,8] parameter(1)
  %cp-start = f32[8,8] collective-permute-start(%p0), source_target_pairs={{0,1},{1,0}}
  %dot.0 = f32[8,8] dot(%p1, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %cp-done = f32[8,8] collective-permute-done(%cp-start)
  ROOT %add = f32[8,8] add(%cp-done, %dot.0)
}
"""

_SERIALIZED_HLO = """
HloModule serial_fixture

ENTRY %main (p0: f32[8,8], p1: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %p1 = f32[8,8] parameter(1)
  %cp-start = f32[8,8] collective-permute-start(%p0), source_target_pairs={{0,1},{1,0}}
  %cp-done = f32[8,8] collective-permute-done(%cp-start)
  %dot.0 = f32[8,8] dot(%p1, %cp-done), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,8] add(%dot.0, %dot.0)
}
"""

_SYNC_HLO = """
HloModule sync_fixture

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %cp = f32[8,8] collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
  ROOT %dot.0 = f32[8,8] dot(%cp, %cp), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_overlap_eligible_collective_detected():
    rep = analyze_overlap(_OVERLAPPED_HLO)
    assert rep.overlapped == 1
    assert rep.serialized == 0
    assert rep.sync == 0
    kind, name, n_compute = rep.pairs[0]
    assert kind == "collective-permute"
    assert n_compute == 1
    assert rep.eligible_fraction == 1.0


def test_serialized_start_done_pair_detected():
    rep = analyze_overlap(_SERIALIZED_HLO)
    assert rep.overlapped == 0
    assert rep.serialized == 1
    assert rep.eligible_fraction == 0.0


def test_sync_collective_detected():
    rep = analyze_overlap(_SYNC_HLO)
    assert rep.sync == 1
    assert rep.async_total == 0


def test_overlap_report_on_real_module():
    """analyze_overlap must agree with analyze_hlo's collective census on a
    real compiled module (1 CPU device: no collectives at all)."""
    x = jnp.zeros((8, 8))
    txt = _compile_text(lambda x: (x @ x) * 2, x)
    rep = analyze_overlap(txt)
    assert rep.overlapped == rep.serialized == rep.sync == 0
