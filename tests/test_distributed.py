"""Multi-device distributed correctness, via subprocess self-tests.

The main pytest process must keep a single CPU device (smoke tests assume
it), so multi-device checks run in subprocesses that set
``XLA_FLAGS=--xla_force_host_platform_device_count`` before importing jax.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_selftest(devices: int, check: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest",
         "--devices", str(devices), "--check", check],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, (
        f"selftest {check} on {devices} devices failed:\n"
        f"{proc.stdout}\n{proc.stderr}")
    return proc.stdout


@pytest.mark.parametrize("check", ["dense", "spmm", "spgemm",
                                   "spgemm_sparse", "api", "balance",
                                   "steal3d", "wire"])
def test_selftest_2x2(check):
    out = _run_selftest(4, check)
    assert "SELFTEST PASSED" in out


@pytest.mark.slow
def test_selftest_3x3_all_core():
    for check in ("dense", "spmm", "spgemm", "spgemm_sparse"):
        out = _run_selftest(9, check)
        assert "SELFTEST PASSED" in out


def test_selftest_moe():
    out = _run_selftest(4, "moe")
    assert "SELFTEST PASSED" in out


def test_selftest_train_parallel():
    out = _run_selftest(8, "train_parallel")
    assert "SELFTEST PASSED" in out


def test_selftest_elastic():
    """End-to-end recovery: drift-triggered re-selection flips the
    schedule, and a 3x3 -> 2x2 device-loss shrink rebuilds a validated
    steal3d plan whose product matches the dense reference."""
    out = _run_selftest(9, "elastic")
    assert "SELFTEST PASSED" in out
    assert "elastic/reselect_flips" in out
    assert "elastic/shrink_3x3_to_2x2" in out
