"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.data.pipeline import SyntheticLM
from repro.models import lm, transformer as tf
from repro.optim import AdamW

SEQ = 32
BATCH = 2


def _batch_for(cfg):
    src = SyntheticLM(cfg, BATCH, SEQ, seed=0)
    return {k: jnp.asarray(v) for k, v in src(0).items()}


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    # ---- forward shapes ----
    inputs, labels = lm._shift_batch(batch, cfg)
    logits, _, aux = tf.forward(params, inputs, cfg)
    assert logits.shape[-1] == cfg.vocab_size
    assert logits.shape[:2] == labels.shape
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"

    # ---- one train step ----
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"])), f"{arch}: NaN grad"
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-9b", "mamba2-130m",
                                  "recurrentgemma-2b", "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    """Prefill + stepwise decode logits == full forward logits (KV-cache /
    state correctness), greedily for a few steps."""
    cfg = get_config(arch, smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 12)),
        jnp.int32)

    # full forward over the first 8 + next 4 tokens
    logits_full, _, _ = tf.forward(params, {"tokens": toks}, cfg)

    # prefill on 8, decode tokens 8..11
    last, caches, pos = lm.prefill(params, {"tokens": toks[:, :8]}, cfg,
                                   max_len=32, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, 7]),
                               rtol=2e-3, atol=2e-3)
    step = lm.make_decode_step(cfg)
    for t in range(8, 12):
        logits_t, caches = step(params, toks[:, t:t + 1], caches, pos)
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(logits_full[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode diverges at position {t}")
        pos = pos + 1


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        lm.prefill(params, {"frames": jnp.zeros((1, 4, cfg.frontend_dim))},
                   cfg, max_len=8)


def test_param_specs_match_param_tree():
    """Sharding spec tree must mirror the param tree exactly, per arch."""
    for arch in list_archs():
        cfg = get_config(arch, smoke=True)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        specs = tf.param_specs(cfg)
        pt = jax.tree_util.tree_structure(params)
        st = jax.tree_util.tree_structure(
            specs, is_leaf=lambda s: isinstance(
                s, jax.sharding.PartitionSpec))
        assert pt == st, f"{arch}: param/spec tree mismatch"


def test_cache_specs_match_cache_tree():
    for arch in ["llama3-8b", "gemma2-9b", "mamba2-130m",
                 "recurrentgemma-2b"]:
        cfg = get_config(arch, smoke=True)
        cache = tf.init_cache(cfg, 2, 16)
        specs = tf.cache_specs(cfg)
        ct = jax.tree_util.tree_structure(cache)
        st = jax.tree_util.tree_structure(
            specs, is_leaf=lambda s: isinstance(
                s, jax.sharding.PartitionSpec))
        assert ct == st, f"{arch}: cache/spec tree mismatch"


def test_blocked_attention_matches_plain():
    """KV-chunked online-softmax path == materialized-scores path."""
    import repro.models.attention as am
    cfg = get_config("gemma2-9b", smoke=True)  # softcap + local/global kinds
    p = am.init_attn(cfg, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, cfg.d_model))
    positions = jnp.arange(64, dtype=jnp.int32)
    for kind in ("g", "l"):
        q, k, v = am._project_qkv(p, x, cfg)
        from repro.models.common import apply_rope, rope
        sin, cos = rope(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        plain = am._sdpa(q, k, v,
                         am._pair_mask(cfg, kind, positions, positions)[None],
                         cfg)
        blocked = am._sdpa_blocked(q, k, v, cfg, kind, positions, positions,
                                   kv_chunk=16)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(plain),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"kind={kind}")
