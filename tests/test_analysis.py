"""Static analysis tests: schedule verifier, jaxpr lint, source rules.

Three layers, matching ``src/repro/analysis``:

* **clean-plan contract** — every healthy plan across the dispatch
  matrix (algorithm x output x wire x overlap) produces *zero* findings
  from both the schedule checker and the jaxpr lint;
* **mutation tests** — each seeded violation class (corrupted ppermute
  permutation, dropped/duplicated steal3d accumulation item, rolled
  packed-wire consume map, corrupted sparse pair list, overlap bodies
  that consume in-flight buffers or issue transfers late) is flagged
  with its named rule id and an actionable message.  The
  ``jaxpr.collective-count`` drift rule needs g >= 2 and is mutated in
  ``selftest --check analysis`` (it rides tier-1 via
  ``tools/run_tier1.sh``);
* **plumbing** — ``plan_matmul(validate=...)`` modes, memoization and
  the never-cache-a-failing-plan rule; ``validate_assignment`` fail-fast
  on injected :class:`Assignment3D`; the ``source_rules`` registry
  (rule ids, ``--json`` / ``--list-rules``, per-line waiver pragmas).

Single-device (g=1) like the rest of the suite; multi-device coverage
rides ``selftest --check analysis`` on 4 fake devices.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax.numpy as jnp
from jax import lax

from repro import analysis
from repro.analysis import source_rules
from repro.analysis.jaxpr_lint import (check_collective_count,
                                       check_hot_loop, trace_plan)
from repro.core import api
from repro.core import steal3d  # analysis: allow(source.import.repro.core.steal3d)
from repro.core.api import Algorithm, DistBSR, DistDense, plan_matmul
from repro.core.bsr import random_sparse
from repro.core.schedule import assign_3d_lpt

G = 1  # the main pytest process owns a single CPU device


@pytest.fixture
def operands():
    a_d = random_sparse(16, 16, 0.3, seed=0)
    b = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    b_sph = DistBSR.from_dense(random_sparse(16, 16, 0.25, seed=1), g=G,
                               block_size=4)
    return a_h, b_h, b_sph


def _rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# Clean-plan contract: zero findings across the dispatch matrix
# ---------------------------------------------------------------------------
_DENSE_ALGS = ("ring_c", "ring_a", "ring_c_bidir", "summa_ag",
               "summa_bcast", "steal3d")
_SPARSE_OUT_ALGS = ("ring_c", "summa_ag", "summa_bcast")
_SPGEMM_ALGS = ("ring_c", "ring_a", "summa_ag", "summa_bcast", "steal3d")

_MATRIX = (
    [(alg, "spmm", "dense", wire, ov)
     for alg in _DENSE_ALGS
     for wire in ("padded", "packed")
     for ov in ("off", "on")]
    + [(alg, "spgemm", "sparse", wire, "off")
       for alg in _SPARSE_OUT_ALGS
       for wire in ("padded", "packed")]
    + [(alg, "spgemm", "dense", "padded", "off") for alg in _SPGEMM_ALGS]
)


@pytest.mark.parametrize(
    "alg,kind,output,wire,overlap", _MATRIX,
    ids=[f"{a}-{k}-{o}-{w}-ov_{v}" for a, k, o, w, v in _MATRIX])
def test_healthy_plans_prove_clean(operands, alg, kind, output, wire,
                                   overlap):
    a_h, b_h, b_sph = operands
    rhs = b_h if kind == "spmm" else b_sph
    plan = plan_matmul(a_h, rhs, algorithm=alg, impl="ref", output=output,
                       wire=wire, overlap=overlap)
    findings = analysis.check_plan(plan, a_h, rhs) \
        + analysis.lint_plan(plan, a_h, rhs)
    assert not findings, "\n".join(str(f) for f in findings)


def test_registry_rule_ids_unique_and_documented():
    rules = analysis.all_rules()
    ids = [r for r, _ in rules]
    assert len(ids) == len(set(ids))
    for prefix in ("schedule.", "jaxpr.", "source."):
        assert any(r.startswith(prefix) for r in ids), prefix
    assert all(desc for _, desc in rules)


def test_finding_and_error_formatting():
    f = analysis.Finding("x.rule", "broken thing", subject="ring_c/step 2")
    assert str(f) == "x.rule [ring_c/step 2]: broken thing"
    err = analysis.PlanValidationError([f])
    assert "x.rule" in str(err) and "1 finding" in str(err)
    assert err.findings == [f]
    assert isinstance(err, ValueError)


# ---------------------------------------------------------------------------
# Mutation tests: every seeded violation class is flagged by rule id
# ---------------------------------------------------------------------------
def test_mutation_invalid_ppermute_perm(operands, monkeypatch):
    """A non-bijective ring permutation (would deadlock the ppermute) is
    named by schedule.ppermute-bijection."""
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       cache=False)
    monkeypatch.setattr(api, "_ring_perm", lambda g, sign=1: ((0, 1),))
    findings = analysis.check_plan(plan, a_h, b_h)
    assert "schedule.ppermute-bijection" in _rules_of(findings)
    msg = str([f for f in findings
               if f.rule == "schedule.ppermute-bijection"][0])
    assert "bijection" in msg or "deadlock" in msg or "device" in msg


def _copied_aux(sp):
    return {k: np.asarray(v).copy() for k, v in sp.aux.items()}


def test_mutation_steal_dropped_accumulation(operands):
    """Blanking one real (A, B) pair drops its (i, k, j) block product:
    schedule.steal-exactly-once."""
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                       cache=False)
    sp = plan.steal
    try:
        aux = _copied_aux(sp)
        pa = aux["pa"]
        inert = pa.reshape(-1).max()  # the zero-block sentinel slot
        pa[tuple(np.argwhere(pa != inert)[0])] = inert
        plan.steal = dataclasses.replace(sp, aux=aux)
        findings = analysis.check_plan(plan, a_h, b_h)
        assert "schedule.steal-exactly-once" in _rules_of(findings)
    finally:
        plan.steal = sp
    assert not analysis.check_plan(plan, a_h, b_h)


def test_mutation_steal_duplicated_accumulation(operands):
    """Copying a real pair onto an inert slot of the same device double-
    counts its block product: schedule.steal-exactly-once."""
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                       cache=False)
    sp = plan.steal
    try:
        aux = _copied_aux(sp)
        pa, pb, ps = aux["pa"], aux["pb"], aux["ps"]
        inert = pa.reshape(-1).max()
        r0 = tuple(np.argwhere(pa != inert)[0])
        same_dev = [tuple(i) for i in np.argwhere(pa == inert)
                    if tuple(i[:2]) == r0[:2]]
        i0 = same_dev[0]
        pa[i0], pb[i0], ps[i0] = pa[r0], pb[r0], ps[r0]
        plan.steal = dataclasses.replace(sp, aux=aux)
        findings = analysis.check_plan(plan, a_h, b_h)
        assert "schedule.steal-exactly-once" in _rules_of(findings)
    finally:
        plan.steal = sp
    assert not analysis.check_plan(plan, a_h, b_h)


def test_mutation_broken_consume_map(operands):
    """Rolling the packed-wire gidx consume map desynchronizes it from
    the pack layout: schedule.wire-contract."""
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       wire="packed", cache=False)
    good = np.asarray(plan._aux["a_gidx"])
    try:
        plan._aux["a_gidx"] = np.roll(good, 1, axis=-1)
        findings = analysis.check_plan(plan, a_h, b_h)
        assert "schedule.wire-contract" in _rules_of(findings)
    finally:
        plan._aux["a_gidx"] = good
    assert not analysis.check_plan(plan, a_h, b_h)


def test_mutation_corrupt_sparse_pair_list(operands):
    """Pointing a sparse-output pair at the zero slot drops a real
    (i, k, j) contribution: schedule.sparse-pairs-exactly-once."""
    a_h, _, b_sph = operands
    plan = plan_matmul(a_h, b_sph, algorithm="ring_c", impl="ref",
                       output="sparse", wire="padded", cache=False)
    good = plan._pairs["pb"]
    pb = np.asarray(good).copy()
    zero_slot = int(np.asarray(b_sph.grid_structure().zero_slot)[0, 0])
    try:
        pb[0, 0, 0, 0] = zero_slot  # first real pair now consumes zeros
        plan._pairs["pb"] = pb
        findings = analysis.check_plan(plan, a_h, b_sph)
        assert "schedule.sparse-pairs-exactly-once" in _rules_of(findings)
    finally:
        plan._pairs["pb"] = good
    assert not analysis.check_plan(plan, a_h, b_sph)


def _overlap_taint_body(a, b, geom):
    """Broken overlap: computes on the in-flight ppermute output."""
    bb = api._densify_b(b, geom)
    acc0 = api._pvary(jnp.zeros((geom.tm, geom.tn), geom.out_dtype), geom)

    def step(carry, _):
        b_t, acc = carry
        b_n = api._tree_ppermute(b_t, geom.axr, geom.g)
        acc = acc + api._local_mm(a, b_n, geom)
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (bb, acc0), None, length=geom.g)
    return acc


def _overlap_late_issue_body(a, b, geom):
    """Broken overlap: accumulates before issuing step t+1's transfer."""
    bb = api._densify_b(b, geom)
    acc0 = api._pvary(jnp.zeros((geom.tm, geom.tn), geom.out_dtype), geom)

    def step(carry, _):
        b_t, acc = carry
        acc = acc + api._local_mm(a, b_t, geom)
        b_n = api._tree_ppermute(b_t, geom.axr, geom.g)
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (bb, acc0), None, length=geom.g)
    return acc


@pytest.mark.parametrize("body", [_overlap_taint_body,
                                  _overlap_late_issue_body],
                         ids=["inflight-consume", "late-issue"])
def test_mutation_reordered_overlap_carry(operands, body):
    a_h, b_h, _ = operands
    name = "bad_overlap_body"
    api.REGISTRY.register(Algorithm(name=name, body=body, msgs_per_step=1))
    try:
        plan = plan_matmul(a_h, b_h, algorithm=name, impl="ref",
                           overlap="on", cache=False)
        findings = analysis.lint_plan(plan, a_h, b_h)
        assert "jaxpr.overlap-carry" in _rules_of(findings)
        msg = str([f for f in findings
                   if f.rule == "jaxpr.overlap-carry"][0])
        assert "carr" in msg or "transfer" in msg  # actionable, not bare
    finally:
        api.REGISTRY.unregister(name)


def test_hot_loop_rule_binds_pallas_paths_only(operands):
    """The reference kernel accumulates via scatter-add by design, so the
    gather-only contract is exempt under impl='ref' but the same trace is
    flagged when a pallas/interpret impl claims it."""
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       cache=False)
    jaxpr = trace_plan(plan, a_h, b_h)
    assert check_hot_loop(jaxpr, impl="ref") == []
    findings = check_hot_loop(jaxpr, impl="interpret")
    assert _rules_of(findings) == ["jaxpr.scan-hot-loop"]


def test_collective_count_skips_degenerate_grid(operands):
    """At g == 1 the ring perms alias, so the n_msgs drift rule abstains
    (the real mutation runs at g=2 in selftest --check analysis)."""
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       cache=False)
    jaxpr = trace_plan(plan, a_h, b_h)
    assert check_collective_count(plan, jaxpr) == []


# ---------------------------------------------------------------------------
# plan_matmul(validate=...) plumbing
# ---------------------------------------------------------------------------
def test_validate_modes_pass_and_memoize(operands):
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       cache=False, validate="fast")
    assert "fast" in plan._validated and "full" not in plan._validated
    plan.validate("full", a_h, b_h)
    assert {"fast", "full"} <= plan._validated
    # re-validating a verified plan is a no-op (memoized verdict)
    plan.validate("full", a_h, b_h)
    plan2 = plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                        cache=False, validate="full")
    assert {"fast", "full"} <= plan2._validated


def test_validate_off_and_bad_mode(operands):
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       cache=False)
    assert plan._validated == set()
    with pytest.raises(ValueError, match="validate"):
        plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                    validate="paranoid")


def test_validate_failing_plan_raises_and_is_not_cached(operands,
                                                        monkeypatch):
    a_h, b_h, _ = operands
    api.clear_plan_cache()
    monkeypatch.setattr(api, "_ring_perm", lambda g, sign=1: ((0, 1),))
    with pytest.raises(analysis.PlanValidationError) as ei:
        plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                    validate="fast")
    assert "schedule.ppermute-bijection" in str(ei.value)
    assert api.plan_cache_size() == 0  # a failing plan never enters
    monkeypatch.undo()
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       validate="fast")
    assert api.plan_cache_size() == 1
    # the cache-hit path re-validates (memoized) instead of skipping
    plan_b = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                         validate="full")
    assert plan_b is plan and "full" in plan._validated


# ---------------------------------------------------------------------------
# steal3d: fail-fast Assignment3D validation + injection
# ---------------------------------------------------------------------------
def _lpt_fixture(g=2, seed=3):
    rng = np.random.default_rng(seed)
    cost_ik = rng.integers(1, 20, size=(g, g)).astype(np.float64)
    flops = np.broadcast_to(cost_ik[:, :, None], (g, g, g))
    return cost_ik, assign_3d_lpt(flops, g)


def test_validate_assignment_accepts_lpt_result():
    cost_ik, asg = _lpt_fixture()
    assert steal3d.validate_assignment(asg, 2) is asg
    assert steal3d.validate_assignment(asg, 2, cost_ik=cost_ik) is asg


def test_validate_assignment_rejects_bad_shape_and_dtype():
    _, asg = _lpt_fixture()
    with pytest.raises(ValueError, match="shape"):
        steal3d.validate_assignment(
            dataclasses.replace(asg, dev=np.zeros((2, 2), np.int64)), 2)
    with pytest.raises(ValueError, match="integer"):
        steal3d.validate_assignment(
            dataclasses.replace(asg, dev=asg.dev.astype(np.float64)), 2)


def test_validate_assignment_rejects_out_of_range_device():
    _, asg = _lpt_fixture()
    dev = asg.dev.copy()
    dev[0, 0, 0] = 4  # g*g for g=2
    with pytest.raises(ValueError, match="outside"):
        steal3d.validate_assignment(dataclasses.replace(asg, dev=dev), 2)


def test_validate_assignment_rejects_locality_violation():
    _, asg = _lpt_fixture()
    dev = asg.dev.copy()
    dev[0, 0, 1] = 2  # device (1, 0): neither row 0 nor column 1
    with pytest.raises(ValueError, match="locality"):
        steal3d.validate_assignment(dataclasses.replace(asg, dev=dev), 2)


def test_validate_assignment_rejects_makespan_regressions():
    cost_ik, asg = _lpt_fixture()
    with pytest.raises(ValueError, match="makespan"):
        steal3d.validate_assignment(
            dataclasses.replace(asg, makespan=asg.owner_makespan * 2), 2)
    # recorded fields fine, but realized loads (all of row 0 piled on
    # device (0, 0)) exceed owner-computes once recomputed from cost_ik
    g = 2
    owner = assign_3d_lpt(np.broadcast_to(cost_ik[:, :, None], (g, g, g)),
                          g, locality="none")
    dev = owner.dev.copy()
    dev[0, :, :] = 0
    with pytest.raises(ValueError, match="realized makespan"):
        steal3d.validate_assignment(
            dataclasses.replace(owner, dev=dev), g, cost_ik=cost_ik)


def test_build_steal_plan_assignment_injection(operands):
    a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                       cache=False)
    asg = plan.steal.assignment
    sp2 = steal3d.build_steal_plan(a_h, b_h, plan.geom, assignment=asg)
    assert sp2.assignment is asg
    assert np.array_equal(np.asarray(sp2.aux["pa"]),
                          np.asarray(plan.steal.aux["pa"]))
    bad = dataclasses.replace(asg, dev=np.zeros((2, 2, 2), np.int64))
    with pytest.raises(ValueError, match="shape"):
        steal3d.build_steal_plan(a_h, b_h, plan.geom, assignment=bad)


# ---------------------------------------------------------------------------
# source_rules: registry, CLI flags, waiver pragmas
# ---------------------------------------------------------------------------
def test_source_rule_registry_covers_legacy_families():
    ids = [r.id for r in source_rules.iter_rules()]
    assert len(ids) == len(set(ids))
    assert len(ids) == len(source_rules.FORBIDDEN_MODULES) + 3
    for mod in source_rules.FORBIDDEN_MODULES:
        assert f"source.import.{mod}" in ids
    assert "source.xla-flags-write" in ids
    assert "source.perf-counter-discipline" in ids
    assert "source.assignment3d-construction" in ids


def test_source_rules_list_rules_flag(capsys):
    assert source_rules.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in source_rules.iter_rules():
        assert rule.id in out
    assert source_rules.main(["--list-rules", "--json"]) == 0
    listed = json.loads(capsys.readouterr().out)
    assert {e["rule"] for e in listed} \
        == {r.id for r in source_rules.iter_rules()}


def test_source_rules_json_output_and_waiver(tmp_path, capsys):
    (tmp_path / "examples").mkdir()
    bad = tmp_path / "examples" / "bad.py"
    bad.write_text("from repro.core.spmm import spmm\n")
    assert source_rules.main(["--json", str(tmp_path)]) == 1
    report = json.loads(capsys.readouterr().out)
    assert not report["ok"]
    assert report["violations"][0]["rule"] == "source.import.repro.core.spmm"
    assert report["violations"][0]["line"] == 1
    # per-line waiver pragma suppresses exactly that rule on that line
    bad.write_text("from repro.core.spmm import spmm"
                   "  # analysis: allow(source.import.repro.core.spmm)\n")
    assert source_rules.main(["--json", str(tmp_path)]) == 0
    assert json.loads(capsys.readouterr().out)["ok"]


def test_source_rules_waiver_is_rule_specific(tmp_path):
    (tmp_path / "examples").mkdir()
    bad = tmp_path / "examples" / "bad.py"
    bad.write_text("from repro.core.spmm import spmm"
                   "  # analysis: allow(source.xla-flags-write)\n")
    found = source_rules.violations(str(tmp_path))
    assert len(found) == 1 and "spmm" in found[0]
