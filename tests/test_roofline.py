"""Paper SS4 roofline model tests."""
import math

import pytest

from repro.core import roofline as rl


def test_spmm_ai_formula_by_hand():
    # m=k=1024, n=256, p=16, d=0.01, w=4
    m = k = 1024.0
    n, p, d, w = 256.0, 16.0, 0.01, 4
    sp = math.sqrt(p)
    flops = 2 * (d * m * k / p) * (n / sp)
    net_bytes = w * (2 * d * m * k / p + m / sp + 1 + k * n / p)
    assert rl.spmm_internode_ai(1024, 1024, 256, 16, 0.01) == pytest.approx(
        flops / net_bytes)


def test_spmm_local_ai_includes_c_bytes():
    ai_l = rl.spmm_local_ai(1024, 1024, 256, 16, 0.01)
    ai_n = rl.spmm_internode_ai(1024, 1024, 256, 16, 0.01)
    assert ai_l < ai_n  # local AI divides by A+B+C bytes, net by A+B only


def test_wider_b_is_more_intense():
    """Paper SS6.1: wider dense B => higher inter-node AI => less net-bound."""
    ais = [rl.spmm_internode_ai(1 << 20, 1 << 20, n, 24, 1e-4)
           for n in (32, 128, 512, 1024)]
    assert all(a < b for a, b in zip(ais, ais[1:]))


def test_spgemm_local_ai_gu_formula():
    assert rl.spgemm_local_ai(cf=4.0, b=4) == pytest.approx(
        4.0 / ((3 + 8) * 4))


def test_roofline_min_behavior():
    mach = rl.SUMMIT_V100
    # deep in the bandwidth-bound region the roofline is linear in AI
    lo = rl.internode_roofline(1.0, 100.0, mach)
    assert lo == pytest.approx(1.0 * mach.net_bw)
    # huge AI saturates at the local peak
    hi = rl.internode_roofline(1e12, 100.0, mach)
    assert hi == pytest.approx(rl.local_peak(100.0, mach))


def test_spmm_model_summit_is_network_bound():
    """Paper Fig. 2: SpMM on Summit is well into the network-bound regime."""
    # isolates-like: m=k ~ 17.5M, nnz ~ 5.2B => d ~ 1.7e-5, p=24, n=512
    d = 5.2e9 / (17.5e6 ** 2)
    out = rl.spmm_model(17_500_000, 17_500_000, 512, 24, d, rl.SUMMIT_V100)
    assert out["net_bound"]
    assert out["perf"] < rl.SUMMIT_V100.arith_peak


def test_tpu_constants():
    assert rl.TPU_V5E.arith_peak == pytest.approx(197e12)
    assert rl.TPU_V5E.mem_bw == pytest.approx(819e9)
    assert rl.TPU_V5E.net_bw == pytest.approx(50e9)
