"""Overlap A-B correctness: double-buffered schedules match bulk ones.

The split-step bodies (``overlap="on"``: step t+1's collective issued
before step t's accumulate, two-slot carry per stream) must be a pure
re-ordering — bit-for-bit-close to the bulk-synchronous bodies
(``overlap="off"``) across the full dispatch matrix: every registered
schedule x spmm/spgemm x padded/packed wire x dense/sparse output.

Also pins the *structure* of the overlap bodies via jaxpr inspection:
the scanned steps stay free of sort/scatter bloat (same contract
test_api.py enforces for the bulk bodies), and the double-buffered scan
actually carries the extra buffer slots (wider carry than bulk) — the
dependence slack the latency-hiding scheduler needs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.api import REGISTRY, DistBSR, DistDense, plan_matmul
from repro.core.bsr import random_sparse
from repro.core.dist import make_grid_mesh

G = 1  # the main pytest process owns a single CPU device


@pytest.fixture(scope="module")
def operands():
    a_d = random_sparse(16, 16, 0.3, seed=0)
    b = np.random.default_rng(3).standard_normal((16, 8)).astype(np.float32)
    b_sp = random_sparse(16, 16, 0.25, seed=1)
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    b_sp_h = DistBSR.from_dense(b_sp, g=G, block_size=4)
    mesh = make_grid_mesh(G)
    return a_d, b, b_sp, a_h, b_h, b_sp_h, mesh


def _as_dense(c):
    return np.asarray(c.densify() if hasattr(c, "densify") else c)


def _cells(alg: str, kind: str):
    """The (wire, output) cells this (algorithm, kind) pair supports."""
    cells = [("padded", "dense"), ("packed", "dense")]
    if kind == "spgemm" and REGISTRY.get(alg).sparse_body is not None:
        cells += [("padded", "sparse"), ("packed", "sparse")]
    return cells


@pytest.mark.parametrize("kind", ["spmm", "spgemm"])
@pytest.mark.parametrize("alg", api.algorithms())
def test_overlap_on_matches_off_across_dispatch_matrix(operands, alg, kind):
    a_d, b, b_sp, a_h, b_h, b_sp_h, mesh = operands
    rhs_h = b_h if kind == "spmm" else b_sp_h
    ref = a_d @ (b if kind == "spmm" else b_sp)
    for wire, output in _cells(alg, kind):
        plans = {
            ov: plan_matmul(a_h, rhs_h, mesh=mesh, algorithm=alg,
                            impl="ref", wire=wire, output=output,
                            overlap=ov, cache=False)
            for ov in ("on", "off")}
        got = {ov: _as_dense(p(a_h, rhs_h)) for ov, p in plans.items()}
        np.testing.assert_allclose(
            got["on"], got["off"], atol=1e-4,
            err_msg=f"{alg}/{kind}/{wire}/{output}: overlap=on diverges "
                    "from overlap=off")
        np.testing.assert_allclose(
            got["on"], ref, atol=1e-4,
            err_msg=f"{alg}/{kind}/{wire}/{output}: overlap=on wrong result")


# ---------------------------------------------------------------------------
# jaxpr structure of the double-buffered bodies
# ---------------------------------------------------------------------------
# walk primitives shared via repro.analysis.jaxpr_lint (single copy for
# the lint rules and every jaxpr-structure test)
from repro.analysis.jaxpr_lint import iter_eqns as _iter_eqns  # noqa: E402
from repro.analysis.jaxpr_lint import scan_eqns, trace_plan  # noqa: E402


def _scan_eqns(plan, a_h, rhs_h):
    return scan_eqns(trace_plan(plan, a_h, rhs_h))


@pytest.mark.parametrize("kind", ["spmm", "spgemm"])
@pytest.mark.parametrize("alg", ["ring_c", "ring_a", "ring_c_bidir"])
def test_overlap_scan_step_free_of_sort_and_scatter(operands, alg, kind):
    """Two-slot buffering must not smuggle sort/scatter into the hot loop."""
    _a_d, _b, _b_sp, a_h, b_h, b_sp_h, mesh = operands
    rhs_h = b_h if kind == "spmm" else b_sp_h
    # impl="interpret": the ref-impl local kernel accumulates via
    # scatter-add, which would mask body-structure regressions (same
    # choice as test_api.py's bulk-body hot-loop test)
    plan = plan_matmul(a_h, rhs_h, mesh=mesh, algorithm=alg,
                       impl="interpret", overlap="on", cache=False)
    scans = _scan_eqns(plan, a_h, rhs_h)
    assert scans, "expected a scanned ring loop in the overlap plan"
    prims = {sub.primitive.name
             for eqn in scans for sub in _iter_eqns(eqn.params["jaxpr"].jaxpr)}
    offenders = {p for p in prims if "sort" in p or "scatter" in p}
    assert not offenders, (
        f"hot-loop bloat in overlap {alg}/{kind} scan step: "
        f"{sorted(offenders)}")


@pytest.mark.parametrize("alg", ["ring_c", "ring_a", "ring_c_bidir"])
def test_overlap_scan_carries_extra_buffer_slots(operands, alg):
    """The double-buffered scan carries strictly more state than the bulk
    scan — the second buffer slot that decouples step t+1's transfer from
    step t's accumulate."""
    _a_d, _b, _b_sp, a_h, b_h, _b_sp_h, mesh = operands
    carries = {}
    for ov in ("on", "off"):
        plan = plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg, impl="ref",
                           overlap=ov, cache=False)
        scans = _scan_eqns(plan, a_h, b_h)
        assert scans, f"expected a scanned ring loop (overlap={ov})"
        carries[ov] = max(e.params["num_carry"] for e in scans)
    assert carries["on"] > carries["off"], (
        f"{alg}: overlap=on scan carry ({carries['on']}) not wider than "
        f"bulk ({carries['off']}) — double buffer missing from the carry")
