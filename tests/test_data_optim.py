"""Data pipeline determinism + optimizer + gradient compression tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import MemmapTokens, Prefetcher, SyntheticLM
from repro.optim import AdamW, cosine_schedule
from repro.optim.compression import (ErrorFeedbackState, compress_int8,
                                     decompress_int8)


def test_synthetic_deterministic_per_step():
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLM(cfg, 4, 16, seed=3)
    a, b = src(10), src(10)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src(11)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_disjoint_across_hosts():
    cfg = get_config("llama3-8b", smoke=True)
    a = SyntheticLM(cfg, 4, 16, seed=3, host_index=0, num_hosts=2)(5)
    b = SyntheticLM(cfg, 4, 16, seed=3, host_index=1, num_hosts=2)(5)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_memmap_tokens(tmp_path):
    path = tmp_path / "toks.bin"
    data = np.arange(1000, dtype=np.int32)
    data.tofile(path)
    src = MemmapTokens(str(path), batch=2, seq=9)
    b0 = src(0)
    assert b0["tokens"].shape == (2, 10)
    np.testing.assert_array_equal(b0["tokens"][0], data[:10])
    # deterministic
    np.testing.assert_array_equal(src(0)["tokens"], b0["tokens"])


def test_prefetcher_resume(tmp_path):
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLM(cfg, 2, 8, seed=0)
    pf = Prefetcher(src, depth=2, start_step=4)
    got = pf.get(4)
    np.testing.assert_array_equal(got["tokens"], src(4)["tokens"])
    got5 = pf.get(5)
    np.testing.assert_array_equal(got5["tokens"], src(5)["tokens"])
    pf.close()


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"x": 2 * params["x"]}
        updates, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_adamw_clip_norm():
    opt = AdamW(lr=0.1, clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    _, state = opt.update({"x": jnp.asarray([1e6, 0.0, 0.0])}, state, params)
    assert float(AdamW.last_grad_norm(state)) > 1e5  # records raw norm


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=0.02)
    assert float(lr(100)) == pytest.approx(0.1, abs=0.02)


@given(st.lists(st.floats(-100, 100), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_int8_compression_bounded_error(vals):
    g = jnp.asarray(vals, jnp.float32)
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    # error bounded by half a quantization step
    max_abs = max(abs(v) for v in vals) or 1.0
    assert float(jnp.abs(back - g).max()) <= max_abs / 127.0 + 1e-6


def test_error_feedback_preserves_sum():
    """With error feedback, quantization error doesn't accumulate: the sum
    of applied updates converges to the sum of true gradients."""
    rng = np.random.default_rng(0)
    true = rng.standard_normal((50, 16)).astype(np.float32)
    resid = jnp.zeros(16)
    applied = jnp.zeros(16)
    for t in range(50):
        g = jnp.asarray(true[t]) + resid
        q, s = compress_int8(g)
        deq = decompress_int8(q, s)
        resid = g - deq
        applied = applied + deq
    drift = float(jnp.abs(applied - jnp.asarray(true.sum(0))).max())
    assert drift <= float(jnp.abs(jnp.asarray(true)).max()) / 127.0 + 1e-5
