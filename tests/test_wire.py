"""Packed wire format tests (single-device, g=1 grid; multi-device
coverage rides ``tests/test_distributed.py`` via ``selftest --check
wire``).

Covers the ISSUE-5 satellite checklist: pack->unpack roundtrip identity
over random structures (hypothesis-free seed sweep, like
``test_schedule_static.py``), empty-operand (capacity-0) shipments,
bucket monotonicity (packed wire bytes <= padded, monotone in real block
count), packed-vs-padded allclose across every algorithm x operand kind,
the packed cost model flipping an ``auto_select`` decision, the
structure guard on packed plans, and the LRU bound + eviction counter on
the plan-layer caches.

All access goes through ``repro.core.api`` — importing
``repro.core.wire`` directly is banned by ``tools/check_api.py`` (also
asserted here).
"""
import dataclasses
import importlib.util
import pathlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import api
from repro.core.api import (DistBSR, DistDense, matmul, plan_matmul,
                            wire_capacity)
from repro.core.bsr import random_sparse, rmat_matrix
from repro.core.grid import bucket_capacity
from repro.core.roofline import Machine
from repro.kernels import ops as kops

G = 1  # the main pytest process owns a single CPU device


def _random_handle(seed, *, density=0.2, n=32, bs=4, capacity="bucket"):
    return DistBSR.from_dense(random_sparse(n, n, density, seed=seed), g=G,
                              block_size=bs, capacity=capacity)


@pytest.fixture
def operands():
    a_d = random_sparse(16, 16, 0.3, seed=0)
    b = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)
    b_sp = random_sparse(16, 16, 0.25, seed=1)
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    b_sph = DistBSR.from_dense(b_sp, g=G, block_size=4)
    return a_d, b, b_sp, a_h, b_h, b_sph


# ---------------------------------------------------------------------------
# Wire capacity: bounds + monotonicity
# ---------------------------------------------------------------------------
def test_wire_capacity_bounds_and_monotonicity():
    prev = 0
    for max_real in range(0, 200, 7):
        wc = wire_capacity(max_real)
        assert wc >= max_real + 1           # room for the zero tail slot
        assert wc == bucket_capacity(max_real + 1)
        assert wc >= prev                   # monotone in real block count
        prev = wc
    # the padded stride clamps a bucket overshoot: packed never ships
    # wider than the padded layout it replaces
    assert wire_capacity(59, 67) == min(bucket_capacity(60), 67)
    assert wire_capacity(3, 100) == bucket_capacity(4)


def test_packed_operand_invariants():
    """Packed layout contract over random structures: real blocks in the
    prefix (stored order), zero tail, slot_map composes, dmap unique."""
    for seed in range(6):
        h = _random_handle(seed, density=0.05 + 0.1 * (seed % 3))
        po = h.packed_operand()
        t = h.tiled
        store = t.store_capacity
        assert po.wire_capacity <= store
        blocks = np.asarray(h.packed_wire("natural")["blocks"])
        raw = np.asarray(t.blocks)
        for i in range(h.g):
            for j in range(h.g):
                nr = int(po.n_real[i, j])
                assert nr < po.wire_capacity
                # packed prefix is the real blocks, stored order
                sl = po.pack_idx[i, j, :nr]
                np.testing.assert_array_equal(blocks[i, j, :nr],
                                              raw[i, j, sl])
                # tail slots are guaranteed zero
                assert np.all(blocks[i, j, nr:] == 0)
                # slot_map: stored real slot -> its packed rank
                np.testing.assert_array_equal(
                    po.slot_map[i, j, sl], np.arange(nr))


def test_pack_roundtrip_identity():
    """Property: densify-by-gather of the packed blocks reproduces every
    tile exactly, and the consume lists drive the augment-free SpMM
    kernel to the same result as the stored (padded) layout."""
    for seed in range(5):
        n, bs = 24 + 8 * (seed % 2), 4
        h = _random_handle(seed + 10, density=0.15, n=n, bs=bs)
        po = h.packed_operand()
        t = h.tiled
        packed = np.asarray(h.packed_wire("natural")["blocks"])
        dense = np.asarray(t.to_dense())
        tm, tn = t.tile_shape
        for i in range(h.g):
            for j in range(h.g):
                tile = dense[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn]
                # roundtrip 1: packed blocks + dense map -> dense tile
                got = np.asarray(kops.densify_packed(
                    jnp.asarray(packed[i, j]), jnp.asarray(po.dmap[i, j]),
                    n_block_rows=po.tile_nbr, n_block_cols=po.tile_nbc))
                np.testing.assert_array_equal(got, tile)
                # roundtrip 2: consume lists (gidx/rows/cols) meet the
                # bsr_spmm_raw(augment=False) contract bit-for-bit
                eye = jnp.eye(tn, dtype=jnp.float32)
                got2 = np.asarray(kops.bsr_spmm_raw(
                    jnp.asarray(packed[i, j])[jnp.asarray(po.gidx[i, j])],
                    jnp.asarray(po.rows[i, j]), jnp.asarray(po.cols[i, j]),
                    eye, n_block_rows=po.tile_nbr, impl="ref"))
                np.testing.assert_allclose(got2, tile, atol=1e-6)


# ---------------------------------------------------------------------------
# Packed plans: allclose to padded, bytes never larger
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("alg", sorted(set(api.algorithms())))
@pytest.mark.parametrize("kind", ["spmm", "spgemm"])
def test_packed_matches_padded(operands, alg, kind, subtests=None):
    a_d, b, b_sp, a_h, b_h, b_sph = operands
    rhs, want = (b_h, a_d @ b) if kind == "spmm" else (b_sph, a_d @ b_sp)
    packed = plan_matmul(a_h, rhs, algorithm=alg, impl="ref", wire="packed")
    padded = plan_matmul(a_h, rhs, algorithm=alg, impl="ref", wire="padded")
    assert padded.wire == "padded"
    got_p = np.asarray(packed(a_h, rhs))
    got_d = np.asarray(padded(a_h, rhs))
    np.testing.assert_allclose(got_p, want, atol=1e-5)
    np.testing.assert_allclose(got_p, got_d, atol=1e-5)
    cm_p, cm_d = packed.cost_model(), padded.cost_model()
    assert cm_p["total_net_bytes"] <= cm_d["total_net_bytes"]
    assert cm_p["total_flops"] <= cm_d["total_flops"]
    if packed.wire == "packed":
        # packed plans are their own cache entries, keyed on structure
        assert packed is not padded


def test_sparse_output_auto_packs_and_matches(operands):
    a_d, _, b_sp, a_h, _, b_sph = operands
    for alg in api.sparse_algorithms():
        packed = plan_matmul(a_h, b_sph, algorithm=alg, impl="ref",
                             output="sparse")          # wire="auto"
        padded = plan_matmul(a_h, b_sph, algorithm=alg, impl="ref",
                             output="sparse", wire="padded")
        assert packed.wire == "packed" and padded.wire == "padded"
        np.testing.assert_allclose(np.asarray(packed(a_h, b_sph).densify()),
                                   a_d @ b_sp, atol=1e-5)
        np.testing.assert_allclose(np.asarray(packed(a_h, b_sph).densify()),
                                   np.asarray(padded(a_h, b_sph).densify()),
                                   atol=1e-5)
        assert packed.cost_model()["total_net_bytes"] \
            <= padded.cost_model()["total_net_bytes"]


def test_packed_chain_stays_packed(operands):
    """A chained sparse product (whose handle may store structurally
    predicted but numerically zero blocks) re-packs on the next link."""
    a_d, _, _, a_h, _, _ = operands
    c2 = matmul(a_h, a_h, algorithm="ring_c", impl="ref", output="sparse")
    c3 = matmul(c2, a_h, algorithm="ring_c", impl="ref", output="sparse")
    np.testing.assert_allclose(np.asarray(c3.densify()), a_d @ a_d @ a_d,
                               atol=1e-4)


def test_empty_operand_packed_shipments():
    """Capacity-0 operands ship one zero block per tile (wire capacity 1)
    and multiply end-to-end to zeros on the packed wire."""
    e_h = DistBSR.from_dense(np.zeros((16, 16), np.float32), g=G,
                             block_size=4)
    assert e_h.capacity == 0
    assert e_h.packed_operand().wire_capacity == 1
    b_h = DistDense.for_rhs(jnp.ones((16, 4), jnp.float32), e_h)
    for alg in ("ring_c", "summa_ag", "steal3d"):
        plan = plan_matmul(e_h, b_h, algorithm=alg, impl="ref",
                           wire="packed")
        got = np.asarray(plan(e_h, b_h))
        np.testing.assert_array_equal(got, np.zeros((16, 4), np.float32))
        assert plan.cost_model()["total_net_bytes"] \
            <= plan_matmul(e_h, b_h, algorithm=alg, impl="ref",
                           wire="padded").cost_model()["total_net_bytes"]


def test_packed_bytes_monotone_in_real_count():
    """More real blocks => packed wire bytes never shrink, and packed
    stays <= padded at every density."""
    b = jnp.ones((32, 8), jnp.float32)
    prev = 0.0
    for density in (0.01, 0.05, 0.15, 0.4, 0.8):
        a_h = DistBSR.from_dense(random_sparse(32, 32, density, seed=3),
                                 g=G, block_size=4, capacity=64)
        b_h = DistDense.for_rhs(b, a_h)
        cm_p = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                           wire="packed").cost_model()
        cm_d = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                           wire="padded").cost_model()
        assert cm_p["total_net_bytes"] <= cm_d["total_net_bytes"]
        assert cm_p["total_net_bytes"] >= prev
        prev = cm_p["total_net_bytes"]


# ---------------------------------------------------------------------------
# Guards + dispatch
# ---------------------------------------------------------------------------
def test_packed_plan_guards_structure(operands):
    _, _, _, a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       wire="packed")
    other = DistBSR.from_dense(random_sparse(16, 16, 0.15, seed=9), g=G,
                               block_size=4, capacity=a_h.capacity)
    assert other.abstract_key() == a_h.abstract_key()
    with pytest.raises(ValueError, match="structure"):
        plan(other, b_h)
    plan2 = plan_matmul(other, b_h, algorithm="ring_c", impl="ref",
                        wire="packed")
    assert plan2 is not plan


def test_padded_plans_still_share_across_structures(operands):
    """wire='auto' keeps the dense-output path padded, so the bucketed
    plan-sharing property survives the packed-wire default."""
    _, _, _, a_h, b_h, _ = operands
    other = DistBSR.from_dense(random_sparse(16, 16, 0.15, seed=9), g=G,
                               block_size=4, capacity=a_h.capacity)
    p1 = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    p2 = plan_matmul(other, b_h, algorithm="ring_c", impl="ref")
    assert p1 is p2 and p1.wire == "padded"


def test_packed_rejects_dense_operands():
    a = np.random.default_rng(1).standard_normal((8, 8)).astype(np.float32)
    with pytest.raises(ValueError, match="block-sparse"):
        plan_matmul(jnp.asarray(a), jnp.asarray(a), g=G, wire="packed")
    with pytest.raises(ValueError, match="wire"):
        plan_matmul(jnp.asarray(a), jnp.asarray(a), g=G, wire="compressed")


def test_ring_a_dense_b_degrades_to_padded(operands):
    """A schedule with no packable traffic for these operands quietly
    builds its padded plan (same cache entry as wire='padded')."""
    _, _, _, a_h, b_h, _ = operands
    p = plan_matmul(a_h, b_h, algorithm="ring_a", impl="ref", wire="packed")
    assert p.wire == "padded"
    assert p is plan_matmul(a_h, b_h, algorithm="ring_a", impl="ref",
                            wire="padded")


# ---------------------------------------------------------------------------
# Cost model: packing flips the predicted winner
# ---------------------------------------------------------------------------
def test_auto_select_flips_on_packed_wire():
    """Hypersparse A pinned at a large capacity: padded scoring is
    dominated by A's padded stride, so the stationary-A ring (which never
    ships A) wins; packed scoring shrinks A to a few real blocks, so the
    stationary-C ring wins.  auto_select must reflect exactly that."""
    reg = api.AlgorithmRegistry()
    reg.register(api.REGISTRY.get("ring_c"))
    reg.register(api.REGISTRY.get("ring_a"))
    # ~2 real blocks per tile, capacity pinned to 100 (e.g. unified with a
    # much denser matrix for plan sharing)
    a_d = np.zeros((32, 32), np.float32)
    a_d[0, 0] = a_d[17, 21] = 1.0
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4, capacity=100)
    b_h = DistDense.for_rhs(jnp.ones((32, 8), jnp.float32), a_h)
    comm_bound = Machine("probe", 1e18, 1e18, 1e3, 4, hop_latency=0.0)
    choice_padded, scores_padded = api.auto_select(
        a_h, b_h, machine=comm_bound, registry=reg, wire="padded")
    choice_packed, scores_packed = api.auto_select(
        a_h, b_h, machine=comm_bound, registry=reg, wire="packed")
    assert choice_padded == "ring_a"
    assert choice_packed == "ring_c"
    # packing only ever shrinks a schedule's predicted cost
    for name in scores_packed:
        assert scores_packed[name] <= scores_padded[name] * (1 + 1e-9)


def test_plan_records_wire_and_caps(operands):
    _, _, _, a_h, b_h, _ = operands
    p = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref", wire="packed")
    assert p.wire == "packed"
    assert p._wire_caps["a"] == a_h.packed_operand().wire_capacity
    sp = plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                     wire="packed")
    assert sp.steal.wire == "packed"
    assert sp.steal.a_wire_capacity == a_h.packed_operand().wire_capacity
    assert len(sp.steal.a_round_cap) == len(sp.steal.a_deltas)


# ---------------------------------------------------------------------------
# LRU-bounded plan caches (satellite)
# ---------------------------------------------------------------------------
def test_plan_cache_lru_bound_and_eviction_counter(operands):
    _, _, _, a_h, _, _ = operands
    api.clear_plan_cache()
    cache = api._PLAN_CACHE
    old_max = cache.maxsize
    ev0 = cache.evictions
    cache.maxsize = 2
    try:
        plans = {}
        for n in (4, 8, 12):
            b_h = DistDense.for_rhs(jnp.ones((16, n), jnp.float32), a_h)
            plans[n] = plan_matmul(a_h, b_h, algorithm="ring_c",
                                   impl="ref")
        assert api.plan_cache_size() <= 2
        assert cache.evictions >= ev0 + 1
        stats = api.cache_stats()
        assert stats["plans"]["size"] <= 2
        assert stats["plans"]["maxsize"] == 2
        assert stats["plans"]["evictions"] == cache.evictions
        # the evicted (oldest) entry rebuilds on demand as a fresh plan
        b4 = DistDense.for_rhs(jnp.ones((16, 4), jnp.float32), a_h)
        rebuilt = plan_matmul(a_h, b4, algorithm="ring_c", impl="ref")
        assert rebuilt is not plans[4]
        np.testing.assert_allclose(
            np.asarray(rebuilt(a_h, b4)),
            np.asarray(plans[4](a_h, b4)), atol=1e-6)
        # the most recent entry is still cached
        b12 = DistDense.for_rhs(jnp.ones((16, 12), jnp.float32), a_h)
        assert plan_matmul(a_h, b12, algorithm="ring_c",
                           impl="ref") is plans[12]
    finally:
        cache.maxsize = old_max
        api.clear_plan_cache()


def test_steal_cache_lru_bound(operands):
    _, _, _, a_h, b_h, _ = operands
    api.clear_plan_cache()
    cache = api._STEAL_CACHE
    old_max = cache.maxsize
    cache.maxsize = 1
    try:
        plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                    cache=False)
        plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                    wire="packed", cache=False)
        assert len(cache) <= 1
        assert cache.evictions >= 1
    finally:
        cache.maxsize = old_max
        api.clear_plan_cache()


# ---------------------------------------------------------------------------
# Hot-loop hygiene: packed scanned steps are gather-only
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("alg", ["ring_c", "ring_a", "ring_c_bidir"])
@pytest.mark.parametrize("kind", ["spmm", "spgemm"])
def test_packed_scan_step_stays_gather_only(operands, alg, kind):
    """The packed ring steps replace coverage sort / B-densify scatter
    with plan-time static gathers; the scanned jaxpr must stay
    sort/scatter-free like the padded invariant in test_api."""
    from repro.analysis.jaxpr_lint import (scan_body_primitives, scan_eqns,
                                           trace_plan)
    _, _, _, a_h, b_h, _ = operands
    # hypersparse B so the B-pack win check keeps ring_a on the packed path
    b_hyp = DistBSR.from_dense(random_sparse(16, 16, 0.05, seed=2), g=G,
                               block_size=4)
    rhs = b_h if kind == "spmm" else b_hyp
    plan = plan_matmul(a_h, rhs, algorithm=alg, impl="interpret",
                       wire="packed")
    if plan.wire != "packed":
        pytest.skip("no packable traffic on this operand combination")
    jaxpr = trace_plan(plan, a_h, rhs)
    prims = scan_body_primitives(jaxpr)
    assert scan_eqns(jaxpr), \
        "expected a scanned ring loop in the packed plan"
    offenders = {p for p in prims if "sort" in p or "scatter" in p}
    assert not offenders, (
        f"hot-loop bloat in packed {alg}/{kind} scan step: "
        f"{sorted(offenders)}")


# ---------------------------------------------------------------------------
# check_api: repro.core.wire is internal to core/
# ---------------------------------------------------------------------------
def _load_check_api():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / "check_api.py"
    spec = importlib.util.spec_from_file_location("check_api_wire", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_api_flags_wire_import(tmp_path):
    (tmp_path / "tests").mkdir()
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "tests" / "bad.py").write_text(
        "from repro.core.wire import pack_operand\n")
    (tmp_path / "src" / "repro" / "core" / "ok.py").write_text(
        "from repro.core import wire\n")
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 1 and "bad.py" in found[0]
