"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, shape sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bsr import BSR, random_sparse
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n,bs,density", [
    (16, 16, 8, 8, 0.3),
    (32, 16, 16, 8, 0.15),
    (16, 32, 32, 16, 0.4),
    (24, 24, 8, 8, 0.0),       # empty matrix
    (16, 16, 8, 8, 1.0),       # dense
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_spmm_interpret_matches_ref(m, k, n, bs, density, dtype):
    a_d = random_sparse(m, k, density, seed=m + k + n)
    b = np.random.default_rng(0).standard_normal((k, n)).astype(np.float32)
    a = BSR.from_dense(a_d, bs, capacity=None, dtype=dtype)
    b_j = jnp.asarray(b, dtype=dtype)
    want = np.asarray(a.to_dense().astype(jnp.float32)) @ np.asarray(
        b_j.astype(jnp.float32))
    got_ref = ops.bsr_spmm(a, b_j, impl="ref")
    got_pl = ops.bsr_spmm(a, b_j, impl="interpret", block_n=8)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got_ref, np.float32), want,
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32), want,
                               rtol=tol, atol=tol)


def test_bsr_spmm_extra_capacity_padding():
    a_d = random_sparse(16, 16, 0.25, seed=2)
    b = np.random.default_rng(1).standard_normal((16, 8)).astype(np.float32)
    a = BSR.from_dense(a_d, 8).with_capacity(9)
    want = a_d @ b
    got = ops.bsr_spmm(a, jnp.asarray(b), impl="interpret", block_n=8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mk,bs,da,db", [
    (16, 8, 0.4, 0.4),
    (32, 8, 0.15, 0.3),
    (16, 16, 1.0, 1.0),
])
def test_pair_matmul_spgemm_matches_dense(mk, bs, da, db):
    a_d = random_sparse(mk, mk, da, seed=4)
    b_d = random_sparse(mk, mk, db, seed=5)
    a = BSR.from_dense(a_d, bs)
    b = BSR.from_dense(b_d, bs)
    pa, pb, pr, pc, n_real = ops.build_pair_lists(
        a.rows, a.cols, a.nnzb, b.rows, b.cols, b.nnzb,
        a.n_block_rows, b.n_block_cols)
    want = a_d @ b_d
    for impl in ("ref", "interpret"):
        got = ops.bsr_pair_matmul(
            a.blocks, b.blocks, jnp.asarray(pa), jnp.asarray(pb),
            jnp.asarray(pr), jnp.asarray(pc),
            n_block_rows=a.n_block_rows, n_block_cols=b.n_block_cols,
            impl=impl)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_pair_lists_cover_every_output_block():
    a_d = random_sparse(24, 24, 0.05, seed=9)
    a = BSR.from_dense(a_d, 8)
    pa, pb, pr, pc, _ = ops.build_pair_lists(
        a.rows, a.cols, a.nnzb, a.rows, a.cols, a.nnzb, 3, 3)
    covered = set(zip(pr.tolist(), pc.tolist()))
    assert covered == {(r, c) for r in range(3) for c in range(3)}


def test_match_block_pairs_join():
    """The extracted sort-merge join feeds both build_pair_lists and the
    distributed symbolic phase; check it against a brute-force join."""
    rng = np.random.default_rng(3)
    a_cols = rng.integers(0, 6, 20)
    b_rows = rng.integers(0, 6, 15)
    ai, bj = ops.match_block_pairs(a_cols, b_rows)
    want = {(i, j) for i in range(20) for j in range(15)
            if a_cols[i] == b_rows[j]}
    assert set(zip(ai.tolist(), bj.tolist())) == want
    assert (a_cols[ai] == b_rows[bj]).all()


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_pair_accumulate_packed_slots(impl):
    """Sparse-output SpGEMM inner: packed accumulation matches a dense
    scatter oracle, and slots visited only by coverage pairs come out
    exactly zero (the first-visit-zeroing contract)."""
    rng = np.random.default_rng(7)
    n_blocks, bs, n_slots = 12, 8, 5
    blocks_a = rng.standard_normal((n_blocks, bs, bs)).astype(np.float32)
    blocks_b = rng.standard_normal((n_blocks, bs, bs)).astype(np.float32)
    blocks_a[-1] = 0.0                      # a guaranteed zero slot each
    blocks_b[-1] = 0.0
    zero = n_blocks - 1
    # real pairs for slots {0, 2, 3}; slots 1 and 4 covered only by dummies
    pa = np.array([0, 1, zero, 2, 3, 4, zero, zero], np.int32)
    pb = np.array([1, 2, zero, 3, 4, 5, zero, zero], np.int32)
    ps = np.array([0, 0, 1, 2, 3, 3, 4, 4], np.int32)
    got = np.asarray(ops.bsr_pair_accumulate(
        jnp.asarray(blocks_a), jnp.asarray(blocks_b), jnp.asarray(pa),
        jnp.asarray(pb), jnp.asarray(ps), n_slots=n_slots, impl=impl))
    want = np.zeros((n_slots, bs, bs), np.float32)
    for a_i, b_i, s in zip(pa, pb, ps):
        want[s] += blocks_a[a_i] @ blocks_b[b_i]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert np.abs(got[1]).max() == 0.0 and np.abs(got[4]).max() == 0.0
