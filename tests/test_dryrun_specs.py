"""Dry-run input-spec regressions + a miniature end-to-end dry-run cell.

The prefill specs once carried a train-style ``seq+1`` token length; the
odd length degenerated every chunked kernel to length-1 chunks (6300x on
the memory roofline term — EXPERIMENTS.md §Perf cell 2).  Lock the shapes
down, and compile one real cell on a small debug mesh in a subprocess.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_prefill_specs_have_exact_seq_tokens():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import input_specs

    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = SHAPES["prefill_32k"]
    # dense LM: exactly seq tokens (even => chunked kernels stay chunked)
    specs = input_specs(get_config("llama3-8b"), shape, mesh)
    assert specs["tokens"].shape == (shape.batch, shape.seq)
    assert specs["tokens"].shape[1] % 1024 == 0
    # vlm: patches + text fill the sequence exactly
    cfg = get_config("llava-next-mistral-7b")
    specs = input_specs(cfg, shape, mesh)
    assert specs["tokens"].shape[1] + cfg.num_patches == shape.seq
    # audio: frames, not tokens
    cfg = get_config("hubert-xlarge")
    specs = input_specs(cfg, shape, mesh)
    assert specs["frames"].shape == (shape.batch, shape.seq, cfg.frontend_dim)
    # train keeps the +1 (label shift)
    specs = input_specs(get_config("llama3-8b"), SHAPES["train_4k"], mesh)
    assert specs["tokens"].shape == (SHAPES["train_4k"].batch,
                                     SHAPES["train_4k"].seq + 1)


@pytest.mark.slow
def test_dryrun_cell_compiles_on_debug_mesh(tmp_path):
    """Full run_cell path (lower+compile+analyze) on a 2x2 debug mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_DRYRUN_DEVICES"] = "4"
    env["REPRO_DRYRUN_MESH"] = "2,2"
    out = str(tmp_path / "cell.json")
    proc = subprocess.run(
        [sys.executable, "-c",
         "import sys; from repro.launch.dryrun import run_cell; "
         f"r = run_cell('mamba2-130m', 'train_4k', 'single', {out!r}); "
         "sys.exit(0 if r['status'] == 'ok' else 1)"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.load(open(out))
    assert rec["status"] == "ok"
    assert rec["hlo_stats"]["dot_flops"] > 0
    assert rec["roofline"]["bottleneck"] in (
        "compute_s", "memory_s", "collective_s")
