import importlib.util

import pytest

# Property-based test modules need hypothesis; skip collecting them (instead
# of erroring the whole run) on containers that don't ship it.
collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = ["test_data_optim.py", "test_property.py",
                      "test_schedule.py"]


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests")
