"""Plan-based distributed-matmul API tests (single-device, g=1 grid).

Multi-device behaviour (2x2/3x3 grids) is covered by the subprocess
selftests in ``tests/test_distributed.py``; here we verify the API
contract in-process: registry dispatch for every algorithm x operand-kind
combination against dense references, plan reuse (one trace for repeated
calls, vs. a retrace per call on the legacy uncached path), placement-state
caching on DistMatrix handles, bit-identical deprecation shims, mesh and
inner-dimension validation, the cost model, and the examples/benchmarks
API-hygiene guard.
"""
import importlib.util
import pathlib
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import api
from repro.core.api import (REGISTRY, Algorithm, DistBSR, DistDense, matmul,
                            plan_matmul)
from repro.core.bsr import TiledBSR, random_sparse
from repro.core.dist import make_grid_mesh
from repro.core.grid import ProcessGrid

G = 1  # the main pytest process owns a single CPU device


@pytest.fixture
def operands():
    a_d = random_sparse(16, 16, 0.3, seed=0)
    b = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)
    b_sp = random_sparse(16, 16, 0.25, seed=1)
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    b_sph = DistBSR.from_dense(b_sp, g=G, block_size=4)
    return a_d, b, b_sp, a_h, b_h, b_sph


# ---------------------------------------------------------------------------
# Dispatch: every registered algorithm x {spmm, spgemm, dense}
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("alg", api.algorithms())
def test_dispatch_spmm(operands, alg):
    a_d, b, _, a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm=alg, impl="ref")
    assert plan.kind == "spmm"
    got = np.asarray(matmul(a_h, b_h, algorithm=alg, impl="ref"))
    np.testing.assert_allclose(got, a_d @ b, atol=1e-5)


@pytest.mark.parametrize("alg", api.algorithms())
def test_dispatch_spgemm(operands, alg):
    a_d, _, b_sp, a_h, _, b_sph = operands
    plan = plan_matmul(a_h, b_sph, algorithm=alg, impl="ref")
    assert plan.kind == "spgemm"
    got = np.asarray(matmul(a_h, b_sph, algorithm=alg, impl="ref"))
    np.testing.assert_allclose(got, a_d @ b_sp, atol=1e-5)


@pytest.mark.parametrize("alg", api.algorithms())
def test_dispatch_dense(alg):
    a = np.random.default_rng(1).standard_normal((10, 7)).astype(np.float32)
    b = np.random.default_rng(2).standard_normal((7, 5)).astype(np.float32)
    plan = plan_matmul(jnp.asarray(a), jnp.asarray(b), g=G, algorithm=alg)
    assert plan.kind == "dense"
    got = np.asarray(matmul(jnp.asarray(a), jnp.asarray(b), g=G,
                            algorithm=alg))
    # logical-shape crop applies uniformly (the dense path used to skip it)
    assert got.shape == (10, 5)
    np.testing.assert_allclose(got, a @ b, atol=1e-5)


def test_dense_sparse_not_implemented(operands):
    *_, b_sph = operands
    a = jnp.ones((16, 16), jnp.float32)
    with pytest.raises(NotImplementedError):
        matmul(DistDense.from_global(a, G), b_sph)


# ---------------------------------------------------------------------------
# Plan reuse: trace counts
# ---------------------------------------------------------------------------
def test_plan_reuse_traces_once(operands):
    """10 calls of one plan: the executable is traced exactly once."""
    _, _, _, a_h, b_h, _ = operands
    api.clear_plan_cache()
    seen = []
    hook = api.add_trace_hook(lambda plan: seen.append(plan))
    try:
        plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
        outs = [np.asarray(plan(a_h, b_h)) for _ in range(10)]
    finally:
        api.remove_trace_hook(hook)
    assert plan.traces == 1
    assert len(seen) == 1 and seen[0] is plan
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_legacy_fresh_plans_retrace_every_call(operands):
    """cache=False reproduces the legacy per-call behaviour: N traces."""
    _, _, _, a_h, b_h, _ = operands
    n_calls = 4
    seen = []
    hook = api.add_trace_hook(lambda plan: seen.append(plan))
    try:
        for _ in range(n_calls):
            fresh = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                                cache=False)
            fresh(a_h, b_h)
    finally:
        api.remove_trace_hook(hook)
    assert len(seen) == n_calls


def test_shims_share_plan_cache_and_match_bitwise(operands):
    a_d, b, _, a_h, b_h, _ = operands
    from repro.core import spmm as legacy
    api.clear_plan_cache()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old1 = np.asarray(legacy.spmm(a_h.tiled, jnp.asarray(b),
                                      algorithm="ring_c", impl="ref"))
        old2 = np.asarray(legacy.spmm(a_h.tiled, jnp.asarray(b),
                                      algorithm="ring_c", impl="ref"))
    assert api.plan_cache_size() == 1     # both calls hit one shared plan
    new = np.asarray(matmul(a_h, b_h, algorithm="ring_c", impl="ref"))
    np.testing.assert_array_equal(old1, old2)
    np.testing.assert_array_equal(old1, new)   # bit-identical, same engine


def test_shim_spgemm_and_dense_match_bitwise(operands):
    a_d, _, b_sp, a_h, _, b_sph = operands
    from repro.core import spmm as legacy
    da = np.random.default_rng(5).standard_normal((12, 9)).astype(np.float32)
    db = np.random.default_rng(6).standard_normal((9, 6)).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old_sp = np.asarray(legacy.spgemm(a_h.tiled, b_sph.tiled,
                                          algorithm="ring_a", impl="ref"))
        old_d = np.asarray(legacy.dense_matmul(da, db, g=G,
                                               algorithm="ring_a"))
    new_sp = np.asarray(matmul(a_h, b_sph, algorithm="ring_a", impl="ref"))
    new_d = np.asarray(matmul(jnp.asarray(da), jnp.asarray(db), g=G,
                              algorithm="ring_a"))
    np.testing.assert_array_equal(old_sp, new_sp)
    np.testing.assert_array_equal(old_d, new_d)


def test_shims_warn_deprecation(operands):
    _, b, _, a_h, _, _ = operands
    from repro.core import spmm as legacy
    with pytest.warns(DeprecationWarning):
        legacy.spmm(a_h.tiled, jnp.asarray(b), impl="ref")


# ---------------------------------------------------------------------------
# Placement-state caching on handles
# ---------------------------------------------------------------------------
def test_placement_materialized_once(operands):
    _, _, _, a_h, b_h, _ = operands
    t1 = a_h.placed("skew_rows")
    t2 = a_h.placed("skew_rows")
    assert t1 is t2                      # skew applied at most once
    d1 = b_h.placed("skew_cols")
    assert d1 is b_h.placed("skew_cols")
    assert set(a_h.placements()) >= {"skew_rows"}


def test_placement_reused_across_plans(operands):
    _, _, _, a_h, b_h, _ = operands
    matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    placed_before = a_h.placed("skew_rows")
    matmul(a_h, b_h, algorithm="ring_c", impl="ref")   # second multiply
    assert a_h.placed("skew_rows") is placed_before


def test_unknown_placement_rejected(operands):
    _, _, _, a_h, _, _ = operands
    with pytest.raises(ValueError, match="placement"):
        a_h.placed("diagonal")


# ---------------------------------------------------------------------------
# Validation: mesh and inner dimensions
# ---------------------------------------------------------------------------
def test_mesh_wrong_axis_names_rejected(operands):
    _, _, _, a_h, b_h, _ = operands
    bad = make_grid_mesh(1, "r", "c")
    with pytest.raises(ValueError, match="axes"):
        plan_matmul(a_h, b_h, mesh=bad)


def test_mesh_wrong_shape_rejected():
    # operands on a 2x2 grid, mesh is 1x1: caught before any shard_map
    a_t = TiledBSR.from_dense(random_sparse(16, 16, 0.3, seed=2),
                              ProcessGrid(2, 2), block_size=4)
    b = jnp.ones((16, 4), jnp.float32)
    with pytest.raises(ValueError, match="process grid"):
        plan_matmul(a_t, b, mesh=make_grid_mesh(1))


def test_inner_dim_mismatch_needs_allow_pad(operands):
    a_d, _, _, a_h, _, _ = operands
    b_short = np.random.default_rng(7).standard_normal(
        (12, 8)).astype(np.float32)
    with pytest.raises(ValueError, match="allow_pad"):
        matmul(a_h, jnp.asarray(b_short))
    got = np.asarray(matmul(a_h, jnp.asarray(b_short), allow_pad=True,
                            impl="ref"))
    np.testing.assert_allclose(got, a_d[:, :12] @ b_short, atol=1e-5)


def test_inner_dim_overflow_always_rejected(operands):
    _, _, _, a_h, _, _ = operands
    b_long = jnp.ones((20, 8), jnp.float32)
    with pytest.raises(ValueError, match="inner dimensions disagree"):
        matmul(a_h, b_long, allow_pad=True)


def test_plan_rejects_mismatched_operands(operands):
    _, _, _, a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    other = DistDense.from_global(jnp.ones((16, 12), jnp.float32), G)
    with pytest.raises(ValueError, match="plan"):
        plan(a_h, other)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_unknown_algorithm(operands):
    _, _, _, a_h, b_h, _ = operands
    with pytest.raises(ValueError, match="unknown algorithm"):
        matmul(a_h, b_h, algorithm="cannon")


def test_registry_rejects_duplicates():
    alg = REGISTRY.get("ring_c")
    with pytest.raises(ValueError, match="already registered"):
        REGISTRY.register(Algorithm(name="ring_c", body=alg.body))


def test_registry_extension_dispatches(operands):
    """A newly registered algorithm is immediately reachable via matmul."""
    a_d, b, _, a_h, b_h, _ = operands
    ring_c = REGISTRY.get("ring_c")
    REGISTRY.register(Algorithm(
        name="ring_c_clone", body=ring_c.body,
        a_placement=ring_c.a_placement, b_placement=ring_c.b_placement,
        unskew_out=ring_c.unskew_out, wire=ring_c.wire))
    try:
        got = np.asarray(matmul(a_h, b_h, algorithm="ring_c_clone",
                                impl="ref"))
        np.testing.assert_allclose(got, a_d @ b, atol=1e-5)
    finally:
        REGISTRY.unregister("ring_c_clone")
    assert "ring_c_clone" not in REGISTRY


def test_plan_cache_keys_on_allow_pad(operands):
    """allow_pad=True and =False must not share a cached plan."""
    _, _, _, a_h, b_h, _ = operands
    api.clear_plan_cache()
    strict = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    padding = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                          allow_pad=True)
    assert strict is not padding
    b_short = np.random.default_rng(8).standard_normal(
        (12, 8)).astype(np.float32)
    padding(a_h, jnp.asarray(b_short))           # pads: ok
    with pytest.raises(ValueError, match="allow_pad"):
        strict(a_h, jnp.asarray(b_short))        # strict plan still strict


def test_reregistering_algorithm_evicts_stale_plans(operands):
    a_d, b, _, a_h, b_h, _ = operands
    ring_c = REGISTRY.get("ring_c")
    bcast = REGISTRY.get("summa_bcast")
    name = "evict_probe"
    REGISTRY.register(Algorithm(
        name=name, body=ring_c.body, a_placement=ring_c.a_placement,
        b_placement=ring_c.b_placement, unskew_out=ring_c.unskew_out,
        wire=ring_c.wire))
    try:
        p1 = plan_matmul(a_h, b_h, algorithm=name, impl="ref")
        REGISTRY.register(Algorithm(name=name, body=bcast.body),
                          overwrite=True)
        p2 = plan_matmul(a_h, b_h, algorithm=name, impl="ref")
        assert p2 is not p1                      # stale plan evicted
        assert p2.algorithm.a_placement == "natural"
        np.testing.assert_allclose(
            np.asarray(p2(a_h, b_h)), a_d @ b, atol=1e-5)
    finally:
        REGISTRY.unregister(name)


def test_legacy_algorithms_tuple_matches_registry():
    from repro.core import spmm as legacy
    assert legacy.ALGORITHMS == api.algorithms()
    assert set(legacy.ALGORITHMS) == {"summa_bcast", "summa_ag", "ring_c",
                                      "ring_a", "ring_c_bidir", "steal3d"}


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
def test_cost_model_and_roofline(operands):
    _, _, _, a_h, b_h, _ = operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    cm = plan.cost_model(a_h)
    assert cm["flops_per_step"] > 0 and cm["net_bytes_per_step"] > 0
    assert cm["ai_net"] == pytest.approx(
        cm["total_flops"] / cm["total_net_bytes"])
    assert cm["per_stage_imbalance"] >= cm["end_to_end_imbalance"] >= 1.0
    from repro.core.roofline import TPU_V5E
    perf = plan.predicted_perf(TPU_V5E)
    assert 0 < perf["perf"] <= TPU_V5E.arith_peak


def test_cost_model_ring_a_ships_c_not_a(operands):
    _, _, _, a_h, b_h, _ = operands
    ring_a = plan_matmul(a_h, b_h, algorithm="ring_a", impl="ref")
    ring_c = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    assert ring_a.algorithm.wire == ("b", "c")
    assert ring_c.algorithm.wire == ("a", "b")
    assert ring_a.cost_model()["net_bytes_per_step"] != \
        ring_c.cost_model()["net_bytes_per_step"]


# ---------------------------------------------------------------------------
# Balanced tiling (balance="rows"): capacity shrink + epilogue inversion
# ---------------------------------------------------------------------------
def _skewed_rmat(scale=8):
    from repro.core.bsr import rmat_matrix
    return rmat_matrix(scale=scale, edgefactor=8, seed=3)  # unpermuted: skewed


def _manual_balanced_handle(d, block_size, seed=0):
    """A DistBSR carrying an explicit row-block permutation.

    On a 1x1 grid the balancer correctly falls back to the identity (one
    tile — no capacity to shrink), so epilogue-inversion tests manufacture
    the permuted value the way balance="rows" would on a real grid.
    """
    import dataclasses
    nbr = d.shape[0] // block_size
    perm = np.random.default_rng(seed).permutation(nbr)
    dp = d.reshape(nbr, block_size, -1)[perm].reshape(d.shape)
    t = TiledBSR.from_dense(dp, ProcessGrid(1, 1), block_size)
    t = dataclasses.replace(t, row_block_perm=tuple(int(p) for p in perm))
    return DistBSR.from_tiled(t)


def test_balance_rows_shrinks_capacity_and_waste():
    """R-MAT row-block balancing reduces uniform capacity on a 4x4 grid.

    Pure construction — no mesh needed, so the real multi-device geometry
    can be checked in-process.
    """
    d = _skewed_rmat()
    none = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8)
    rows = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8,
                               balance="rows")
    assert rows.capacity < none.capacity
    assert rows.padded_flop_waste() < none.padded_flop_waste()
    assert none.row_block_perm is None
    assert sorted(rows.row_block_perm) == list(range(d.shape[0] // 8))
    # the balanced matrix is a pure row-block permutation of the original
    inv = np.argsort(np.asarray(rows.row_block_perm))
    back = np.asarray(rows.to_dense()).reshape(-1, 8, d.shape[1])[inv]
    np.testing.assert_array_equal(back.reshape(d.shape), d)


@pytest.mark.parametrize("alg", ["ring_c", "ring_a", "ring_c_bidir"])
def test_balanced_plan_matches_unbalanced(alg):
    """Epilogue inverts the carried row permutation: results are allclose.

    (Real-grid balance="rows" plans are checked the same way by selftest
    --check balance on 2x2/3x3 meshes.)"""
    d = _skewed_rmat()
    b = np.random.default_rng(2).standard_normal((256, 16)).astype(np.float32)
    h_none = DistBSR.from_dense(d, g=G, block_size=8)
    h_rows = _manual_balanced_handle(d, 8)
    assert list(h_rows.row_block_perm) != sorted(h_rows.row_block_perm)
    c_none = np.asarray(matmul(h_none, jnp.asarray(b), algorithm=alg,
                               impl="ref"))
    c_rows = np.asarray(matmul(h_rows, jnp.asarray(b), algorithm=alg,
                               impl="ref"))
    np.testing.assert_allclose(c_rows, c_none, atol=1e-4)
    np.testing.assert_allclose(c_rows, d @ b, atol=1e-3)


def test_balance_identity_fallback_on_1x1_grid():
    """One tile -> no capacity to shrink: the balancer must return the
    identity layout (no carried perm) instead of a useless permutation."""
    h = DistBSR.from_dense(_skewed_rmat(), g=1, block_size=8,
                           balance="rows")
    assert h.row_block_perm is None


def test_balanced_spgemm_left_operand(operands):
    a_d, _, b_sp, _, _, b_sph = operands
    a_bal = _manual_balanced_handle(a_d, 4)
    got = np.asarray(matmul(a_bal, b_sph, algorithm="ring_c", impl="ref"))
    np.testing.assert_allclose(got, a_d @ b_sp, atol=1e-5)


def test_balanced_right_operand_rejected(operands):
    _, _, b_sp, a_h, _, _ = operands
    b_bal = _manual_balanced_handle(b_sp, 4)
    with pytest.raises(ValueError, match="right operand"):
        matmul(a_h, b_bal, impl="ref")


def test_from_tiled_balance_keeps_explicit_capacity():
    """Rebuilding with balance must not silently re-derive a capacity the
    caller pinned (abstract keys would stop matching cached plans)."""
    d = _skewed_rmat()
    pinned = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8,
                                 capacity=64)
    h = DistBSR.from_tiled(pinned, balance="rows")
    assert h.capacity == 64


def test_from_tiled_capacity_rejected_when_not_rebuilding():
    """capacity= is only honored on the re-tiling path; silently ignoring
    it would desync abstract keys from sibling pinned handles."""
    t = TiledBSR.from_dense(_skewed_rmat(), ProcessGrid(4, 4), block_size=8)
    with pytest.raises(ValueError, match="capacity"):
        DistBSR.from_tiled(t, capacity=256)


def test_from_tiled_balance_roundtrip():
    d = _skewed_rmat()
    plain = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8)
    h = DistBSR.from_tiled(plain, balance="rows", capacity=None)
    assert h.row_block_perm is not None        # skewed R-MAT: perm kept
    assert h.capacity < plain.capacity         # capacity=None: re-derived
    np.testing.assert_array_equal(
        np.asarray(h.tiled.to_dense()).reshape(-1, 8, 256)[
            np.argsort(np.asarray(h.row_block_perm))].reshape(256, 256), d)
    with pytest.raises(ValueError, match="balance"):
        DistBSR.from_tiled(plain, balance="columns")


# ---------------------------------------------------------------------------
# Auto-scheduling: algorithm="auto" picks the min-cost schedule
# ---------------------------------------------------------------------------
def test_auto_plan_picks_min_score_and_is_correct(operands):
    a_d, b, _, a_h, b_h, _ = operands
    # plan.requested reflects the request that FIRST built the plan; start
    # from an empty cache so earlier tests' explicit-name plans can't alias
    api.clear_plan_cache()
    plan = plan_matmul(a_h, b_h, algorithm="auto", impl="ref")
    assert plan.requested == "auto"
    assert set(plan.auto_scores) == set(api.algorithms())
    best = min(plan.auto_scores, key=plan.auto_scores.get)
    assert plan.algorithm.name == best
    assert plan.auto_scores[plan.algorithm.name] == min(
        plan.auto_scores.values())
    got = np.asarray(plan(a_h, b_h))
    np.testing.assert_allclose(got, a_d @ b, atol=1e-5)


def test_auto_choice_differs_with_sparsity_and_shape():
    """The cost model flips the schedule across operand regimes (the
    Bharadwaj-et-al observation auto-scheduling encodes).  No mesh is
    needed: auto_select scores plans abstractly, so 4x4 grids work
    in-process."""
    from repro.core.bsr import random_sparse
    # tiny, hypersparse A with a wide dense B: communication-dominated
    a_sp = TiledBSR.from_dense(random_sparse(64, 64, 0.05, seed=0),
                               ProcessGrid(4, 4), 8)
    comm_choice, comm_scores = api.auto_select(
        a_sp, jnp.ones((64, 512), jnp.float32))
    # huge dense x dense: compute-dominated
    comp_choice, comp_scores = api.auto_select(
        jnp.ones((4096, 4096), jnp.float32),
        jnp.ones((4096, 4096), jnp.float32), g=4)
    assert comm_choice != comp_choice
    for scores in (comm_scores, comp_scores):
        assert set(scores) == set(api.algorithms())
        assert all(s > 0 for s in scores.values())


def test_auto_select_respects_registration(operands):
    """A (temporarily) registered free-comm algorithm must win auto."""
    _, _, _, a_h, b_h, _ = operands
    ring_c = REGISTRY.get("ring_c")
    REGISTRY.register(Algorithm(
        name="freebie", body=ring_c.body, a_placement=ring_c.a_placement,
        b_placement=ring_c.b_placement, wire=(), wire_amortized=True))
    try:
        choice, scores = api.auto_select(a_h, b_h)
        assert "freebie" in scores
        assert scores["freebie"] == min(scores.values())
    finally:
        REGISTRY.unregister("freebie")


def test_bidir_with_unit_width_tiles(operands):
    """tn == 1 makes one bidir half-panel zero-width; the kernel wrapper
    must short-circuit n == 0 on every impl path."""
    a_d, _, _, a_h, _, _ = operands
    b_thin = np.random.default_rng(11).standard_normal(
        (16, 1)).astype(np.float32)
    for impl in ("ref", "interpret"):
        got = np.asarray(matmul(a_h, jnp.asarray(b_thin),
                                algorithm="ring_c_bidir", impl=impl))
        np.testing.assert_allclose(got, a_d @ b_thin, atol=1e-5)


def test_predicted_cost_positive(operands):
    _, _, _, a_h, b_h, _ = operands
    from repro.core.roofline import TPU_V5E
    for alg in api.algorithms():
        plan = plan_matmul(a_h, b_h, algorithm=alg, impl="ref")
        assert plan.predicted_cost(TPU_V5E) > 0


# ---------------------------------------------------------------------------
# Hot-loop hygiene: no coverage sort / B densification inside the scan
# ---------------------------------------------------------------------------
# The jaxpr-walk primitives live in repro.analysis.jaxpr_lint (shared
# with test_wire / test_overlap and the lint rules themselves).
def _scan_body_primitives(plan, a_h, b_h):
    from repro.analysis.jaxpr_lint import (scan_body_primitives, scan_eqns,
                                           trace_plan)
    jaxpr = trace_plan(plan, a_h, b_h)
    assert scan_eqns(jaxpr), \
        "expected a scanned ring loop in the plan executable"
    return scan_body_primitives(jaxpr)


@pytest.mark.parametrize("alg", ["ring_c", "ring_a", "ring_c_bidir"])
@pytest.mark.parametrize("kind", ["spmm", "spgemm"])
def test_scan_step_free_of_augment_and_densify(operands, alg, kind):
    """The scanned ring step must contain no coverage augmentation (sort /
    concatenate of the block lists) and no B-tile densification
    (scatter-add): both are hoisted to tiling / pre-scan time."""
    _, _, _, a_h, b_h, b_sph = operands
    rhs = b_h if kind == "spmm" else b_sph
    plan = plan_matmul(a_h, rhs, algorithm=alg, impl="interpret")
    prims = _scan_body_primitives(plan, a_h, rhs)
    offenders = {p for p in prims if "sort" in p or "scatter" in p}
    assert not offenders, (
        f"hot-loop bloat in {alg}/{kind} scan step: {sorted(offenders)}")


# ---------------------------------------------------------------------------
# API-hygiene guard (tools/check_api.py rides tier-1 via this test)
# ---------------------------------------------------------------------------
def _load_check_api():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / "check_api.py"
    spec = importlib.util.spec_from_file_location("check_api", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_examples_benchmarks_use_plan_api():
    assert _load_check_api().violations() == []


def test_check_api_flags_deprecated_import(tmp_path):
    (tmp_path / "examples").mkdir()
    (tmp_path / "benchmarks").mkdir()
    (tmp_path / "examples" / "bad.py").write_text(
        "from repro.core.spmm import spmm\n")
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 1 and "bad.py" in found[0]


def test_check_api_flags_xla_flag_writes(tmp_path):
    """XLA_FLAGS has exactly one allowed write site
    (src/repro/runtime/platform.py); direct assignment or setdefault
    anywhere else is flagged."""
    (tmp_path / "examples").mkdir()
    (tmp_path / "src" / "repro" / "runtime").mkdir(parents=True)
    (tmp_path / "examples" / "bad.py").write_text(
        "import os\nos.environ['XLA_FLAGS'] = '--foo'\n")
    (tmp_path / "examples" / "bad2.py").write_text(
        "import os\nos.environ.setdefault('XLA_FLAGS', '--foo')\n")
    (tmp_path / "examples" / "ok.py").write_text(
        "from repro.runtime.platform import set_host_device_count\n"
        "set_host_device_count(4)\n")
    (tmp_path / "src" / "repro" / "runtime" / "platform.py").write_text(
        "import os\nos.environ['XLA_FLAGS'] = '--allowed-here'\n")
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 2
    assert any("bad.py" in f for f in found)
    assert any("bad2.py" in f for f in found)


def test_check_api_flags_kernel_bypass(tmp_path):
    """examples/benchmarks must not bypass plan_matmul by importing the
    Pallas kernel module directly."""
    (tmp_path / "examples").mkdir()
    (tmp_path / "benchmarks").mkdir()
    (tmp_path / "benchmarks" / "bad1.py").write_text(
        "from repro.kernels.bsr_spmm import bsr_spmm_pallas\n")
    (tmp_path / "benchmarks" / "bad2.py").write_text(
        "from repro.kernels import bsr_spmm\n")
    (tmp_path / "benchmarks" / "ok.py").write_text(
        "from repro.kernels import ops\n")
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 2
    assert any("bad1.py" in f for f in found)
    assert any("bad2.py" in f for f in found)


# ---------------------------------------------------------------------------
# Column balance (balance="cols"): B-side compensation + epilogue inversion
# ---------------------------------------------------------------------------
def _manual_cols_balanced_handle(d, block_size, seed=0):
    """A DistBSR carrying an explicit column-block permutation (the 1x1
    analogue of balance="cols"; see _manual_balanced_handle)."""
    import dataclasses
    nbc = d.shape[1] // block_size
    perm = np.random.default_rng(seed).permutation(nbc)
    dp = d.reshape(d.shape[0], nbc, block_size)[:, perm].reshape(d.shape)
    t = TiledBSR.from_dense(dp, ProcessGrid(1, 1), block_size)
    t = dataclasses.replace(t, col_block_perm=tuple(int(p) for p in perm))
    return DistBSR.from_tiled(t)


def test_balance_cols_shrinks_capacity_on_col_skew():
    d = _skewed_rmat().T.copy()              # hubs in columns
    none = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8)
    cols = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8,
                               balance="cols")
    assert cols.capacity < none.capacity
    assert cols.col_block_perm is not None and cols.row_block_perm is None
    # the balanced matrix is a pure column-block permutation of the original
    inv = np.argsort(np.asarray(cols.col_block_perm))
    back = np.asarray(cols.to_dense()).reshape(d.shape[0], -1, 8)[:, inv]
    np.testing.assert_array_equal(back.reshape(d.shape), d)


def test_balance_auto_picks_the_shrinking_axis():
    """Deterministically skewed inputs: all mass in a few row blocks ->
    auto picks rows; transposed -> cols; uniform -> identity."""
    d = np.zeros((64, 64), np.float32)
    d[:16, :] = 1.0                          # grid row 0 owns everything
    grid = ProcessGrid(4, 4)
    rowy = TiledBSR.from_dense(d, grid, 4, balance="auto")
    assert rowy.row_block_perm is not None and rowy.col_block_perm is None
    coly = TiledBSR.from_dense(d.T.copy(), grid, 4, balance="auto")
    assert coly.col_block_perm is not None and coly.row_block_perm is None
    uniform = TiledBSR.from_dense(np.ones((64, 64), np.float32), grid, 4,
                                  balance="auto")
    assert uniform.row_block_perm is None and uniform.col_block_perm is None


@pytest.mark.parametrize("alg", ["ring_c", "ring_a", "summa_bcast"])
def test_cols_balanced_left_operand_compensated(alg):
    """C = (A P)(P^T B) = A B: the planner permutes B's row blocks instead
    of touching the output (the ROADMAP's 'invert on B')."""
    d = _skewed_rmat()
    b = np.random.default_rng(4).standard_normal((256, 16)).astype(
        np.float32)
    h = _manual_cols_balanced_handle(d, 8)
    assert list(h.col_block_perm) != sorted(h.col_block_perm)
    got = np.asarray(matmul(h, jnp.asarray(b), algorithm=alg, impl="ref"))
    np.testing.assert_allclose(got, d @ b, atol=1e-3)


def test_cols_balanced_left_with_sparse_rhs(operands):
    a_d, _, b_sp, _, _, b_sph = operands
    a_bal = _manual_cols_balanced_handle(a_d, 4)
    got = np.asarray(matmul(a_bal, b_sph, algorithm="ring_c", impl="ref"))
    np.testing.assert_allclose(got, a_d @ b_sp, atol=1e-4)


def test_cols_balanced_right_operand_inverted_on_output(operands):
    """A cols-balanced RIGHT operand permutes C's column blocks; the
    shared epilogue inverts them before the crop."""
    a_d, _, b_sp, a_h, _, _ = operands
    b_bal = _manual_cols_balanced_handle(b_sp, 4)
    got = np.asarray(matmul(a_h, b_bal, algorithm="ring_c", impl="ref"))
    np.testing.assert_allclose(got, a_d @ b_sp, atol=1e-4)


def test_cols_balance_compensation_cached(operands):
    """The compensated right operand is materialized once per (handle,
    permutation), like placement states."""
    a_d, _, _, _, b_h, _ = operands
    a_bal = _manual_cols_balanced_handle(a_d, 4, seed=3)
    matmul(a_bal, b_h, algorithm="ring_c", impl="ref")
    comp = b_h._col_compensated[a_bal.col_block_perm]
    matmul(a_bal, b_h, algorithm="ring_c", impl="ref")
    assert b_h._col_compensated[a_bal.col_block_perm] is comp


def test_sparse_output_with_balance_fails_fast_and_actionably():
    """plan_matmul must reject balance= operands for sparse outputs up
    front, naming both workarounds (output="dense" / balance="none") —
    not with a generic error deep in plan construction (ISSUE-4
    satellite)."""
    d = _skewed_rmat()
    for h in (_manual_balanced_handle(d, 8),
              _manual_cols_balanced_handle(d, 8)):
        with pytest.raises(ValueError, match=r'output="dense"') as ei:
            plan_matmul(h, DistBSR.from_dense(d, g=G, block_size=8),
                        output="sparse")
        assert 'balance="none"' in str(ei.value)
    # auto degrades to a dense output instead of failing
    h = _manual_balanced_handle(d, 8)
    plan = plan_matmul(h, DistBSR.from_dense(d, g=G, block_size=8),
                       output="auto", algorithm="ring_c", impl="ref")
    assert plan.output == "dense"


def test_densify_inverts_balance_perms():
    d = _skewed_rmat()
    h_rows = _manual_balanced_handle(d, 8)
    h_cols = _manual_cols_balanced_handle(d, 8)
    np.testing.assert_array_equal(np.asarray(h_rows.densify()), d)
    np.testing.assert_array_equal(np.asarray(h_cols.densify()), d)


def test_from_tiled_balance_cols_roundtrip():
    d = _skewed_rmat().T.copy()
    plain = TiledBSR.from_dense(d, ProcessGrid(4, 4), block_size=8)
    h = DistBSR.from_tiled(plain, balance="cols", capacity=None)
    assert h.col_block_perm is not None
    assert h.capacity < plain.capacity
    np.testing.assert_array_equal(np.asarray(h.densify()), d)


def test_recommended_balance_follows_algorithm():
    assert api.recommended_balance("ring_a") == "cols"
    assert api.recommended_balance("ring_c") == "rows"
    assert api.recommended_balance("summa_bcast") == "rows"
    with pytest.raises(ValueError, match="unknown algorithm"):
        api.recommended_balance("cannon")


def test_check_api_flags_symbolic_outside_core(tmp_path):
    """core.symbolic is internal to repro/core: imports in examples or
    sibling src packages are flagged, core itself is allowed."""
    (tmp_path / "examples").mkdir()
    (tmp_path / "benchmarks").mkdir()
    pkg = tmp_path / "src" / "repro"
    (pkg / "kernels").mkdir(parents=True)
    (pkg / "core").mkdir()
    (pkg / "kernels" / "bad.py").write_text(
        "from repro.core.symbolic import symbolic_spgemm\n")
    (pkg / "core" / "ok.py").write_text(
        "from repro.core import symbolic\n")
    (tmp_path / "examples" / "bad2.py").write_text(
        "import repro.core.symbolic\n")
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 2
    assert any("kernels" in f and "bad.py" in f for f in found)
    assert any("bad2.py" in f for f in found)
