"""Observability subsystem tests (``repro.obs``).

Covers the contracts the obs layer makes:

* **Disabled tracing is a no-op**: ``span()`` returns one shared inert
  object, no events accumulate, and instrumented plan calls take the
  early-return path.
* **Spans nest and are thread-safe**: interval containment and recorded
  depth reconstruct the stack; concurrent recorders lose no events.
* **Chrome-trace schema**: ``export_trace`` round-trips through JSON with
  every event carrying ``ph``/``ts``/``dur``/``name``/``pid``/``tid``,
  and ``validate_trace`` catches violations.
* **Registry semantics**: labeled series identity, snapshot rendering,
  reset-keeps-registrations, pull-time callbacks, kind conflicts.
* **Drift math**: ratio is geomean(measured/predicted), rmse is exact;
  ``fit_from_registry`` recovers known machine constants from synthetic
  drift records.
* **Instrumented plan path**: traced ``plan_matmul`` + ``MatmulPlan``
  calls emit plan-build and per-multiply spans, record drift, and the
  ``jax.named_scope`` wrapper adds zero retraces; the scope label
  survives into compiled HLO (``scope_op_counts``).
* **Serving spans**: a ServeEngine run under tracing emits
  admission/prefill/decode-step spans.
* **check_api timing rule**: raw paired ``perf_counter`` reads without a
  blocking call are flagged outside the allowlisted modules.
"""
import importlib.util
import json
import math
import pathlib
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import api, roofline
from repro.core.api import DistBSR, DistDense
from repro.core.bsr import random_sparse


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Tracing/drift state is process-global; leave it as we found it."""
    obs.disable()
    obs.clear_trace()
    obs.reset_drift()
    yield
    obs.disable()
    obs.clear_trace()
    obs.reset_drift()


def _g1_handles(m=32, seed=11):
    a_d = random_sparse(m, m, 0.2, seed=seed)
    b = np.random.default_rng(seed).standard_normal((m, 8)).astype(
        np.float32)
    a_h = DistBSR.from_dense(a_d, g=1, block_size=4)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    return a_d, b, a_h, b_h


# ---------------------------------------------------------------------------
# tracing: disabled no-op, nesting, threads, export schema
# ---------------------------------------------------------------------------
def test_disabled_span_is_shared_noop():
    assert not obs.enabled()
    s1, s2 = obs.span("a", k=1), obs.span("b")
    assert s1 is s2                       # one shared inert object
    with s1 as sp:
        sp.note(extra="ignored")          # must not raise
    assert obs.events() == []


def test_spans_nest_with_containment_and_depth():
    obs.enable(clear=True)
    with obs.span("outer", phase="build"):
        with obs.span("inner"):
            pass
        with obs.span("inner"):
            pass
    obs.disable()
    evs = obs.events()
    assert [e["name"] for e in evs] == ["inner", "inner", "outer"]
    outer = evs[-1]
    assert outer["args"]["depth"] == 0 and outer["args"]["phase"] == "build"
    for inner in evs[:2]:
        assert inner["args"]["depth"] == 1
        # interval containment (what Perfetto uses to rebuild the stack)
        assert inner["ts"] >= outer["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3


def test_span_note_attaches_mid_span_attrs():
    obs.enable(clear=True)
    with obs.span("x", a=1) as sp:
        sp.note(b=2)
    obs.disable()
    (ev,) = obs.events()
    assert ev["args"]["a"] == 1 and ev["args"]["b"] == 2


def test_tracing_is_thread_safe():
    obs.enable(clear=True)
    n_threads, per_thread = 8, 50

    def work(i):
        for j in range(per_thread):
            with obs.span(f"t{i}", j=j):
                pass

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    obs.disable()
    evs = obs.events()
    assert len(evs) == n_threads * per_thread       # nothing lost
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], set()).add(e["tid"])
    # each thread's spans carry a single consistent tid (the OS may
    # recycle pthread ids across short-lived threads, so tids need not
    # be globally distinct)
    assert len(by_name) == n_threads
    assert all(len(v) == 1 for v in by_name.values())


def test_export_trace_roundtrips_valid_chrome_json(tmp_path):
    obs.enable(clear=True)
    with obs.span("s", tag="v"):
        obs.instant("marker", n=3)
    obs.disable()
    path = tmp_path / "trace.json"
    obs.export_trace(str(path))
    trace = json.loads(path.read_text())
    assert obs.validate_trace(trace) == []
    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"]["dropped_events"] == 0
    for ev in trace["traceEvents"]:
        for k in obs.REQUIRED_EVENT_KEYS:
            assert k in ev


def test_validate_trace_flags_schema_violations():
    assert obs.validate_trace({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [{"ph": "X", "ts": "zero", "dur": 1.0,
                            "name": "x", "pid": 0}]}
    problems = obs.validate_trace(bad)
    assert any("missing key 'tid'" in p for p in problems)
    assert any("ts not numeric" in p for p in problems)


def test_clear_trace_and_enable_clear():
    obs.enable(clear=True)
    with obs.span("a"):
        pass
    assert len(obs.events()) == 1
    obs.enable(clear=True)                 # re-enable clears the buffer
    assert obs.events() == []
    obs.disable()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_registry_instrument_identity_and_labels():
    reg = obs.MetricsRegistry()
    c = reg.counter("hits", cache="plans")
    c.inc()
    c.inc(2.5)
    assert reg.counter("hits", cache="plans") is c       # stateless call site
    other = reg.counter("hits", cache="symbolic")
    assert other is not c and other.value == 0.0
    assert c.value == 3.5
    assert len(reg.series("hits")) == 2


def test_registry_snapshot_rendering():
    reg = obs.MetricsRegistry()
    reg.counter("n").inc(4)
    reg.gauge("level").set(0.5)
    h = reg.histogram("lat", path="decode")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["n"] == 4                           # unlabeled -> value
    assert snap["level"] == 0.5
    row = snap["lat"]["path=decode"]                # labeled -> {labels: ...}
    assert row["count"] == 4 and row["sum"] == 10.0
    assert row["mean"] == 2.5 and row["min"] == 1.0 and row["max"] == 4.0
    assert row["p50"] == 2.5


def test_registry_reset_keeps_registrations_and_callbacks():
    reg = obs.MetricsRegistry()
    c = reg.counter("n")
    c.inc(7)
    reg.register_callback("pull", lambda: {"x": 1})
    reg.reset()
    assert reg.counter("n") is c and c.value == 0.0      # same instrument
    assert reg.snapshot() == {"n": 0.0, "pull": {"x": 1}}


def test_registry_kind_conflict_raises():
    reg = obs.MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError, match="already registered as counter"):
        reg.gauge("m")


def test_histogram_percentiles_interpolate():
    h = obs.Histogram("h", {})
    for v in (10.0, 20.0, 30.0, 40.0):
        h.observe(v)
    assert h.percentile(0) == 10.0
    assert h.percentile(100) == 40.0
    assert h.percentile(50) == 25.0
    assert math.isnan(obs.Histogram("e", {}).percentile(50))


def test_default_registry_exposes_plan_caches_callback():
    snap = obs.registry().snapshot()
    assert "plan_caches" in snap
    assert set(snap["plan_caches"]) == {"plans", "symbolic", "density",
                                        "steal"}


def test_steal3d_planning_feeds_registry():
    reg = obs.registry()
    moved = reg.counter("steal3d.moved_tile_bytes")
    built = reg.counter("steal3d.plans_built", wire="padded")
    m0, b0 = moved.value, built.value
    a_d, b, a_h, b_h = _g1_handles(m=32, seed=13)
    plan = api.plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref",
                           cache=False)
    np.testing.assert_allclose(np.asarray(plan(a_h, b_h)), a_d @ b,
                               rtol=0, atol=1e-4)
    assert built.value >= b0 + 1      # this build was counted
    assert moved.value >= m0          # bytes only ever accumulate


# ---------------------------------------------------------------------------
# plan-cache counters window (cache_stats(reset=True))
# ---------------------------------------------------------------------------
def test_cache_stats_reset_windows_counters():
    a_d, b, a_h, b_h = _g1_handles()
    api.clear_plan_cache()
    api.cache_stats(reset=True)                    # open a fresh window
    api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")   # hit
    stats = api.cache_stats(reset=True)            # read + close window
    assert stats["plans"]["misses"] >= 1 and stats["plans"]["hits"] >= 1
    after = api.cache_stats()
    assert after["plans"]["hits"] == 0 and after["plans"]["misses"] == 0
    assert after["plans"]["size"] >= 1             # entries survive the reset


# ---------------------------------------------------------------------------
# drift: math, report keys, fit-from-registry
# ---------------------------------------------------------------------------
def test_drift_ratio_and_rmse_exact():
    obs.record_drift("algx", "padded", "off", predicted_s=1.0,
                     measured_s=2.0)
    obs.record_drift("algx", "padded", "off", predicted_s=1.0,
                     measured_s=8.0)
    report = obs.drift_report()
    d = report["algx/padded/off"]
    assert d["n"] == 2
    assert d["ratio"] == pytest.approx(4.0)        # geomean(2, 8)
    assert d["rmse_s"] == pytest.approx(5.0)       # sqrt((1 + 49)/2)
    assert d["predicted_mean_s"] == pytest.approx(1.0)
    assert d["measured_mean_s"] == pytest.approx(5.0)


def test_drift_series_keyed_by_alg_wire_overlap():
    obs.record_drift("a1", "padded", "off", 1.0, 1.0)
    obs.record_drift("a1", "packed", "off", 1.0, 1.0)
    obs.record_drift("a2", "padded", "auto", 1.0, 1.0)
    assert set(obs.drift_report()) == {"a1/padded/off", "a1/packed/off",
                                       "a2/padded/auto"}
    assert len(obs.drift_records()) == 3
    obs.reset_drift()
    assert obs.drift_report() == {} and obs.drift_records() == []


def _load_fit_machine():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / "fit_machine.py"
    spec = importlib.util.spec_from_file_location("fit_machine", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fit_from_registry_recovers_known_machine():
    """Synthesize drift records whose measured times follow the alpha-beta
    model under known (net_bw, hop_latency); the registry fit must recover
    them (2 records, 2 unknowns -> exact up to lstsq fp error)."""
    fm = _load_fit_machine()
    alg = next(a for a in api.REGISTRY
               if a.style == "bsp" and a.cost_fn is None)
    base = roofline.TPU_V5E
    net_bw_true, alpha_true = 123e9, 3e-6
    for steps, byts, flops in ((4, 1.0e8, 1e9), (8, 8.0e8, 2e9)):
        cm = {"steps": steps, "total_net_bytes": byts, "total_flops": flops,
              "ai_local": 10.0}
        t_comp = cm["total_flops"] / roofline.local_peak(cm["ai_local"],
                                                         base)
        n_msgs = alg.msgs_per_step if alg.msgs_per_step is not None \
            else len(alg.wire)
        msgs = n_msgs * (1.0 if alg.wire_amortized else steps)
        measured = t_comp + (byts / alg.duplex) / net_bw_true \
            + msgs * alpha_true
        obs.record_drift(alg.name, "padded", "off",
                         predicted_s=measured, measured_s=measured, cm=cm)
    fitted, diag = fm.fit_from_registry(base)
    assert diag["n_used"] == 2
    assert fitted.net_bw == pytest.approx(net_bw_true, rel=1e-3)
    assert fitted.hop_latency == pytest.approx(alpha_true, rel=1e-3)


def test_fit_from_registry_needs_records():
    fm = _load_fit_machine()
    with pytest.raises(ValueError, match="usable records"):
        fm.fit_from_registry()


# ---------------------------------------------------------------------------
# instrumented plan path
# ---------------------------------------------------------------------------
def test_traced_plan_emits_spans_and_drift_with_zero_retraces():
    a_d, b, a_h, b_h = _g1_handles(seed=17)
    obs.enable(clear=True)
    obs.reset_drift()
    plan = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                           cache=False)
    for _ in range(3):
        out = plan(a_h, b_h)
    obs.disable()
    np.testing.assert_allclose(np.asarray(out), a_d @ b, rtol=0, atol=1e-4)
    names = [e["name"] for e in obs.events()]
    assert "plan_build" in names
    assert "plan_build.executable" in names
    assert names.count("multiply.ring_c") == 3
    # the named_scope wrapper + span plumbing must not retrace
    assert plan.traces == 1
    d = obs.drift_report()[f"ring_c/{plan.wire}/{plan.overlap}"]
    assert d["n"] == 3 and d["ratio"] > 0
    rec = obs.drift_records()[0]
    assert rec["cm"]["total_flops"] > 0            # cm kept for re-fitting
    # multiply spans carry the blocking measured time
    mults = [e for e in obs.events() if e["name"] == "multiply.ring_c"]
    assert all(e["args"]["measured_s"] > 0 for e in mults)


def test_untraced_plan_records_nothing():
    a_d, b, a_h, b_h = _g1_handles(seed=19)
    plan = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                           cache=False)
    out = plan(a_h, b_h)
    np.testing.assert_allclose(np.asarray(out), a_d @ b, rtol=0, atol=1e-4)
    assert obs.events() == [] and obs.drift_records() == []


def test_named_scope_label_survives_into_hlo():
    from repro.launch.hlo_analysis import scope_op_counts

    def body(x):
        with jax.named_scope("plan.ring_c.padded"):
            return (x @ x) + 1.0

    text = jax.jit(body).lower(
        jnp.ones((8, 8), jnp.float32)).compile().as_text()
    counts = scope_op_counts(text, scope="plan.ring_c")
    assert counts.get("plan.ring_c.padded", 0) >= 1
    # unfiltered counts see the same component among others
    assert scope_op_counts(text)["plan.ring_c.padded"] >= 1


# ---------------------------------------------------------------------------
# serving spans
# ---------------------------------------------------------------------------
def test_serve_engine_emits_admission_and_decode_spans():
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.serving import ServeEngine

    cfg = get_config("llama3-8b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=48)
    for _ in range(2):
        eng.submit(rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
                   max_new_tokens=2)
    obs.enable(clear=True)
    eng.run()
    obs.disable()
    names = {e["name"] for e in obs.events()}
    assert {"serve.admit", "serve.prefill", "serve.decode_step"} <= names
    prefill = [e for e in obs.events() if e["name"] == "serve.prefill"]
    admits = [e for e in obs.events() if e["name"] == "serve.admit"]
    assert len(prefill) == len(admits) == 2        # one per admitted request
    steps = [e for e in obs.events() if e["name"] == "serve.decode_step"]
    assert steps and all(e["args"]["step_s"] > 0 for e in steps)
    assert all(e["args"]["prefill_s"] > 0 for e in admits)


def test_serving_metrics_rides_its_own_registry():
    from repro.serving import ServingMetrics

    m = ServingMetrics()
    m.submitted(0, arrival=0.0, prompt_len=8)
    m.prefill_done(0, 0.5)
    snap = m.registry.snapshot()
    assert snap["serve.prefill_s"] == 0.5
    # windows are isolated: the process-wide registry is untouched
    assert "serve.prefill_s" not in obs.registry().snapshot()


# ---------------------------------------------------------------------------
# trace_view summarizer
# ---------------------------------------------------------------------------
def _load_tool(name):
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_view_summarize_aggregates_per_name():
    tv = _load_tool("trace_view")
    evs = [
        {"ph": "X", "name": "a", "dur": 1000.0, "ts": 0},
        {"ph": "X", "name": "a", "dur": 3000.0, "ts": 10},
        {"ph": "X", "name": "b", "dur": 500.0, "ts": 20},
        {"ph": "M", "name": "meta", "ts": 0},           # ignored
    ]
    rows = tv.summarize(evs)
    assert [r["name"] for r in rows] == ["a", "b"]      # total desc
    a = rows[0]
    assert a["count"] == 2 and a["total_ms"] == 4.0
    assert a["mean_ms"] == 2.0 and a["max_ms"] == 3.0
    assert tv.slowest(evs, 1)[0]["dur"] == 3000.0
    out = tv.render({"traceEvents": evs,
                     "otherData": {"dropped_events": 2}})
    assert "WARNING: 2 events dropped" in out


# ---------------------------------------------------------------------------
# check_api: raw perf_counter timing ban
# ---------------------------------------------------------------------------
def _load_check_api():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / "check_api.py"
    spec = importlib.util.spec_from_file_location("check_api", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_api_flags_unblocked_perf_counter_pairs(tmp_path):
    (tmp_path / "benchmarks").mkdir()
    (tmp_path / "src" / "repro" / "obs").mkdir(parents=True)
    bad = (
        "import time\n"
        "def t(fn):\n"
        "    t0 = time.perf_counter()\n"
        "    fn()\n"
        "    return time.perf_counter() - t0\n"
    )
    good = (
        "import time, jax\n"
        "def t(fn):\n"
        "    t0 = time.perf_counter()\n"
        "    jax.block_until_ready(fn())\n"
        "    return time.perf_counter() - t0\n"
    )
    single = (
        "import time\n"
        "def stamp():\n"
        "    return time.perf_counter()\n"
    )
    (tmp_path / "benchmarks" / "bad.py").write_text(bad)
    (tmp_path / "benchmarks" / "good.py").write_text(good)
    (tmp_path / "benchmarks" / "single.py").write_text(single)
    # same smeared pattern inside the obs package itself is allowlisted
    (tmp_path / "src" / "repro" / "obs" / "impl.py").write_text(bad)
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 1 and "bad.py" in found[0]
    assert "perf_counter" in found[0]
