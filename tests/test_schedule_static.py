"""Static scheduler tests that run without hypothesis (tier-1 everywhere).

``tests/test_schedule.py`` carries the property-based suite but is skipped
on containers without hypothesis; the ISSUE-2 coverage contract for
``core/schedule.py`` — permutation validity, the LPT 4/3 makespan bound
against brute-force optima, and stage_imbalance vs an explicit device loop
— lives here so it always rides tier-1.
"""
import itertools

import numpy as np
import pytest

from repro.core.schedule import (balance_row_perm, invert_perm, lpt_assign,
                                 makespan, stage_imbalance)


# ---------------------------------------------------------------------------
# balance_row_perm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,grid_rows,seed", [
    (16, 4, 0), (32, 4, 1), (24, 3, 2), (8, 8, 3), (12, 1, 4),
])
def test_balance_row_perm_is_valid_permutation(n, grid_rows, seed):
    rng = np.random.default_rng(seed)
    nnz = rng.pareto(1.2, size=n) + 0.01        # heavy-tailed like R-MAT
    perm = balance_row_perm(nnz, grid_rows)
    assert sorted(perm.tolist()) == list(range(n))


@pytest.mark.parametrize("n,grid_rows,seed", [(16, 4, 0), (24, 3, 5)])
def test_balance_row_perm_preserves_per_grid_row_counts(n, grid_rows, seed):
    """Each grid row receives exactly n/grid_rows row blocks, and the
    balanced max grid-row load never exceeds the identity layout's."""
    rng = np.random.default_rng(seed)
    nnz = rng.pareto(1.2, size=n) + 0.01
    perm = balance_row_perm(nnz, grid_rows)
    per = n // grid_rows
    loads = nnz[perm].reshape(grid_rows, per).sum(axis=1)
    identity = nnz.reshape(grid_rows, per).sum(axis=1)
    assert all(len(perm[g * per:(g + 1) * per]) == per
               for g in range(grid_rows))
    assert loads.max() <= identity.max() + 1e-9
    # total work is conserved
    assert loads.sum() == pytest.approx(nnz.sum())


def test_balance_row_perm_rejects_indivisible():
    with pytest.raises(ValueError, match="divide"):
        balance_row_perm(np.ones(10), 4)


def test_invert_perm_roundtrip():
    rng = np.random.default_rng(9)
    perm = rng.permutation(17)
    inv = invert_perm(perm)
    assert (inv[perm] == np.arange(17)).all()
    assert (perm[inv] == np.arange(17)).all()


# ---------------------------------------------------------------------------
# LPT: the 4/3 bound against brute-force optima
# ---------------------------------------------------------------------------
def _opt_makespan(costs, n_workers):
    """Exact optimal makespan by exhaustive assignment (small n only)."""
    best = float("inf")
    for assign in itertools.product(range(n_workers), repeat=len(costs)):
        loads = np.zeros(n_workers)
        np.add.at(loads, np.asarray(assign), costs)
        best = min(best, loads.max())
    return best


@pytest.mark.parametrize("seed", range(8))
def test_lpt_within_four_thirds_of_optimal(seed):
    rng = np.random.default_rng(seed)
    n_items = int(rng.integers(4, 9))
    n_workers = int(rng.integers(2, 4))
    costs = rng.pareto(1.3, size=n_items) + 0.05
    assign = lpt_assign(costs, n_workers)
    lpt_max, _ = makespan(costs, assign, n_workers)
    opt = _opt_makespan(costs, n_workers)
    # Graham: LPT <= (4/3 - 1/(3m)) OPT
    assert lpt_max <= (4.0 / 3.0 - 1.0 / (3 * n_workers)) * opt + 1e-9
    assert lpt_max >= opt - 1e-9


# ---------------------------------------------------------------------------
# stage_imbalance vs explicit brute-force device loop
# ---------------------------------------------------------------------------
def _stage_imbalance_bruteforce(tile_costs):
    g = tile_costs.shape[0]
    totals = np.zeros((g, g))
    per_stage = 0.0
    for t in range(g):
        stage = np.zeros((g, g))
        for i in range(g):
            for j in range(g):
                stage[i, j] = tile_costs[i, (i + j + t) % g]
        per_stage += stage.max()
        totals += stage
    avg = totals.mean()
    if avg == 0:
        return 1.0, 1.0
    return per_stage / avg, totals.max() / avg


@pytest.mark.parametrize("g,seed", [(2, 0), (4, 1), (8, 2)])
def test_stage_imbalance_matches_bruteforce(g, seed):
    rng = np.random.default_rng(seed)
    costs = rng.pareto(1.0, size=(g, g)) + 0.05
    got = stage_imbalance(costs)
    want = _stage_imbalance_bruteforce(costs)
    assert got[0] == pytest.approx(want[0])
    assert got[1] == pytest.approx(want[1])


def test_stage_imbalance_zero_costs():
    assert stage_imbalance(np.zeros((4, 4))) == (1.0, 1.0)
