"""Static 3D work-grid dispatch: assignment properties + planner + plans.

Covers the ISSUE-4 contract for ``schedule.assign_3d_lpt`` (every (i,k,j)
item assigned exactly once, locality constraint respected, makespan never
worse than owner-computes), the ``core.steal3d`` plan builder's invariants
(pair conservation, index bounds, move/reduce round consistency), the
``steal3d`` algorithm end-to-end on a g=1 mesh against ``ring_c`` across
the dispatch matrix (real grids run in ``selftest --check steal3d`` via
``tests/test_distributed.py``), the auto-select cost entry, and the
satellite regressions (``steal_simulation`` zero guard, empty-operand
capacity-0 fast path).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import api
from repro.core.api import DistBSR, DistDense, matmul, plan_matmul
from repro.core.bsr import TiledBSR, random_sparse, rmat_matrix
from repro.core.grid import ProcessGrid, bucket_capacity
from repro.core.schedule import (assign_3d_lpt, steal_simulation,
                                 stage_imbalance)

G = 1  # the main pytest process owns a single CPU device


# ---------------------------------------------------------------------------
# assign_3d_lpt
# ---------------------------------------------------------------------------
def _pareto_flops(g, seed, j_dep=False):
    rng = np.random.default_rng(seed)
    cost_ik = rng.pareto(1.1, size=(g, g)) + 0.01     # heavy-tailed R-MAT-ish
    if j_dep:
        return np.broadcast_to(cost_ik[:, :, None], (g, g, g)) \
            * (rng.random((g, g, g)) + 0.5)
    return np.broadcast_to(cost_ik[:, :, None], (g, g, g)).copy()


@pytest.mark.parametrize("g,seed", [(2, 0), (4, 1), (4, 2), (8, 3)])
@pytest.mark.parametrize("locality", ["none", "random", "locality"])
def test_assign_3d_every_item_assigned_once(g, seed, locality):
    flops = _pareto_flops(g, seed, j_dep=True)
    asg = assign_3d_lpt(flops, g, locality=locality)
    assert asg.dev.shape == (g, g, g)
    assert asg.dev.min() >= 0 and asg.dev.max() < g * g
    # loads reconstruct exactly from the assignment + penalty convention
    penalty = {"none": 1.0, "random": 1.0 + asg.comm_penalty,
               "locality": 1.0 + asg.comm_penalty / 3.0}[locality]
    ii, _, jj = np.meshgrid(np.arange(g), np.arange(g), np.arange(g),
                            indexing="ij")
    owner = ii * g + jj
    eff = np.where(asg.dev == owner, flops, flops * penalty)
    loads = np.zeros(g * g)
    np.add.at(loads, asg.dev.ravel(), eff.ravel())
    np.testing.assert_allclose(loads, asg.loads)
    assert asg.makespan == pytest.approx(loads.max())


@pytest.mark.parametrize("g,seed", [(2, 0), (4, 1), (4, 5), (8, 2)])
def test_assign_3d_locality_constraint(g, seed):
    """Under locality, item (i, k, j) only lands in grid row i or col j."""
    asg = assign_3d_lpt(_pareto_flops(g, seed), g, locality="locality")
    r, c = asg.dev // g, asg.dev % g
    i = np.arange(g)[:, None, None]
    j = np.arange(g)[None, None, :]
    assert bool(((r == i) | (c == j)).all())


@pytest.mark.parametrize("g,seed", [(2, 0), (4, 1), (4, 7), (8, 2), (8, 9)])
@pytest.mark.parametrize("locality", ["random", "locality"])
def test_assign_3d_makespan_never_worse_than_owner(g, seed, locality):
    asg = assign_3d_lpt(_pareto_flops(g, seed, j_dep=True), g,
                        locality=locality)
    assert asg.makespan <= asg.owner_makespan + 1e-9
    assert asg.gain() >= 1.0


def test_assign_3d_skew_beats_owner_computes():
    """One hub grid row owning most of the work: stealing must help."""
    g = 4
    flops = np.ones((g, g, g))
    flops[0] = 50.0                       # grid row 0 is the hub
    asg = assign_3d_lpt(flops, g, locality="locality")
    assert asg.n_moved > 0
    assert asg.makespan < asg.owner_makespan
    # the simulation's equilibrium agrees that stealing wins here
    sim = steal_simulation(flops[:, :, 0], steal="locality")
    none = steal_simulation(flops[:, :, 0], steal="none")
    assert sim < none


def test_assign_3d_owner_mode_and_zero_items():
    g = 3
    flops = np.zeros((g, g, g))
    flops[1, 1, 1] = 5.0
    owner = assign_3d_lpt(flops, g, locality="none")
    assert owner.n_moved == 0
    loc = assign_3d_lpt(flops, g, locality="locality")
    # zero-cost items never move; the single real item stays feasible
    assert (loc.dev[flops == 0] ==
            owner.dev[flops == 0]).all()


def test_assign_3d_max_stolen_caps_offowner_items():
    g = 4
    flops = np.ones((g, g, g))
    flops[0] = 100.0
    asg = assign_3d_lpt(flops, g, locality="locality", max_stolen=1)
    ii, _, jj = np.meshgrid(np.arange(g), np.arange(g), np.arange(g),
                            indexing="ij")
    owner = ii * g + jj
    stolen_per_dev = np.zeros(g * g, dtype=int)
    np.add.at(stolen_per_dev, asg.dev[asg.dev != owner].ravel(), 1)
    assert stolen_per_dev.max() <= 1


def test_assign_3d_validates_inputs():
    with pytest.raises(ValueError, match="flops_ikj"):
        assign_3d_lpt(np.ones((2, 3, 2)), 2)
    with pytest.raises(ValueError, match="locality"):
        assign_3d_lpt(np.ones((2, 2, 2)), 2, locality="quantum")


# ---------------------------------------------------------------------------
# Satellite regressions: steal_simulation zero guard, empty fast path
# ---------------------------------------------------------------------------
def test_steal_simulation_all_empty_returns_one_not_nan():
    """All-empty tile_costs (legal for hypersparse operands) used to
    divide by loads.mean() == 0 and return NaN."""
    z = np.zeros((4, 4))
    for steal in ("none", "random", "locality"):
        v = steal_simulation(z, steal=steal)
        assert v == 1.0 and not np.isnan(v)
    assert stage_imbalance(z) == (1.0, 1.0)   # the guard steal_sim now copies


def test_bucket_capacity_zero_is_zero():
    assert bucket_capacity(0) == 0
    assert bucket_capacity(1) == 1


def test_empty_operand_capacity_zero_through_plan():
    """A genuinely empty DistBSR allocates no phantom block storage and
    multiplies to zeros end-to-end through plan_matmul (satellite)."""
    empty = DistBSR.from_dense(np.zeros((32, 32), np.float32), g=G,
                               block_size=4)
    assert empty.capacity == 0
    # store_capacity is the coverage blocks only: the cheap empty path
    assert empty.tiled.store_capacity == empty.tiled.tile_shape[0] // 4
    b_h = DistDense.for_rhs(jnp.ones((32, 8), jnp.float32), empty)
    for alg in api.algorithms():
        got = np.asarray(matmul(empty, b_h, algorithm=alg, impl="ref"))
        np.testing.assert_array_equal(got, np.zeros((32, 8), np.float32))
    # sparse output of an empty product also keeps capacity 0
    c = matmul(empty, empty, algorithm="ring_c", impl="ref",
               output="sparse")
    assert c.capacity == 0
    np.testing.assert_array_equal(np.asarray(c.densify()),
                                  np.zeros((32, 32), np.float32))


# ---------------------------------------------------------------------------
# Plan builder invariants (host-side, real 4x4 geometry, no mesh needed)
# ---------------------------------------------------------------------------
def _skewed_handle(g=4, scale=8, bs=8):
    return DistBSR.from_dense(rmat_matrix(scale=scale, edgefactor=8, seed=3),
                              g=g, block_size=bs)


def _steal_plan_4x4():
    a_h = _skewed_handle()
    b_h = DistDense.for_rhs(jnp.ones((a_h.shape[1], 32), jnp.float32), a_h)
    geom = api._geometry(a_h, b_h, impl=None, axis_row="row",
                         axis_col="col")
    return a_h, api._steal_plan_for(a_h, b_h, geom)


def test_steal_plan_pair_conservation_and_bounds():
    """Every real A block of every (i, k) tile appears exactly g times
    across the fleet's pair lists (once per output column j), plus one
    coverage pair per output slot per device."""
    a_h, sp = _steal_plan_4x4()
    g = sp.g
    counts = np.asarray(a_h.counts)
    total_real = int(counts.sum()) * g
    pa, ps = sp.aux["pa"], sp.aux["ps"]
    # zero/coverage pairs all reference the appended zero tile's slots
    zero_base = (g + sum(sp.a_move_cap)) * sp.store_a
    real_mask = pa < zero_base
    assert int(real_mask.sum()) == total_real
    assert pa.max() < zero_base + sp.store_a
    assert ps.min() >= 0 and ps.max() < sp.n_slots
    assert sp.aux["pb"].min() >= 0
    assert sp.aux["pb"].max() < (g + sum(sp.b_move_cap)) * sp.b_chunks
    # slot lists are nondecreasing per device (the kernel contract) and
    # every slot is covered on every device
    for r in range(g):
        for c in range(g):
            s = ps[r, c]
            assert (np.diff(s) >= 0).all()
            assert len(np.unique(s)) == sp.n_slots
    # the pair capacity is the (bucketed) realized makespan: it must beat
    # the owner-computes rings' uniform g x store padding on skewed input
    ring_work = g * a_h.tiled.store_capacity
    assert sp.pair_capacity < ring_work


def test_steal_plan_makespan_and_cost_fields():
    _, sp = _steal_plan_4x4()
    asg = sp.assignment
    assert asg.makespan <= asg.owner_makespan
    cm = sp.cost
    for key in ("total_flops", "total_net_bytes", "ai_net", "ai_local",
                "n_msgs", "gather_bytes", "moved_tile_bytes",
                "reduce_bytes", "lpt_makespan", "owner_makespan"):
        assert key in cm
    assert cm["total_net_bytes"] == pytest.approx(
        cm["gather_bytes"] + cm["moved_tile_bytes"] + cm["reduce_bytes"])
    assert cm["n_msgs"] >= 2.0


def test_steal_plan_memoized_on_structure():
    a_h = _skewed_handle()
    b_h = DistDense.for_rhs(jnp.ones((a_h.shape[1], 32), jnp.float32), a_h)
    geom = api._geometry(a_h, b_h, impl=None, axis_row="row",
                         axis_col="col")
    api.clear_plan_cache()
    sp1 = api._steal_plan_for(a_h, b_h, geom)
    sp2 = api._steal_plan_for(a_h, b_h, geom)
    assert sp1 is sp2


# ---------------------------------------------------------------------------
# Dispatch matrix (g=1; real grids in selftest --check steal3d)
# ---------------------------------------------------------------------------
@pytest.fixture
def operands():
    a_d = random_sparse(16, 16, 0.3, seed=0)
    b = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)
    b_sp = random_sparse(16, 16, 0.25, seed=1)
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    b_sph = DistBSR.from_dense(b_sp, g=G, block_size=4)
    return a_d, b, b_sp, a_h, b_h, b_sph


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_steal3d_allclose_ring_c_spmm(operands, impl):
    a_d, b, _, a_h, b_h, _ = operands
    got = np.asarray(matmul(a_h, b_h, algorithm="steal3d", impl=impl))
    ring = np.asarray(matmul(a_h, b_h, algorithm="ring_c", impl=impl))
    np.testing.assert_allclose(got, ring, atol=1e-5)
    np.testing.assert_allclose(got, a_d @ b, atol=1e-5)


def test_steal3d_allclose_ring_c_spgemm(operands):
    a_d, _, b_sp, a_h, _, b_sph = operands
    got = np.asarray(matmul(a_h, b_sph, algorithm="steal3d", impl="ref"))
    ring = np.asarray(matmul(a_h, b_sph, algorithm="ring_c", impl="ref"))
    np.testing.assert_allclose(got, ring, atol=1e-5)
    np.testing.assert_allclose(got, a_d @ b_sp, atol=1e-5)


def test_steal3d_allclose_ring_c_dense():
    a = np.random.default_rng(1).standard_normal((10, 7)).astype(np.float32)
    b = np.random.default_rng(2).standard_normal((7, 5)).astype(np.float32)
    got = np.asarray(matmul(jnp.asarray(a), jnp.asarray(b), g=G,
                            algorithm="steal3d"))
    ring = np.asarray(matmul(jnp.asarray(a), jnp.asarray(b), g=G,
                             algorithm="ring_c"))
    assert got.shape == (10, 5)
    np.testing.assert_allclose(got, ring, atol=1e-5)


def test_steal3d_plan_traces_once_and_rejects_structure_mismatch(operands):
    _, _, _, a_h, b_h, _ = operands
    api.clear_plan_cache()
    plan = plan_matmul(a_h, b_h, algorithm="steal3d", impl="ref")
    for _ in range(3):
        plan(a_h, b_h)
    assert plan.traces == 1
    # same abstract shapes, different structure: the cached plan must not
    # serve it, and calling it directly must fail fast
    other = DistBSR.from_dense(
        random_sparse(16, 16, 0.02, seed=9), g=G, block_size=4,
        capacity=a_h.capacity)
    assert other.abstract_key() == a_h.abstract_key()
    assert other.structure_key() != a_h.structure_key()
    plan2 = plan_matmul(other, b_h, algorithm="steal3d", impl="ref")
    assert plan2 is not plan
    with pytest.raises(ValueError, match="structure"):
        plan(other, b_h)


def test_steal3d_sparse_output_refused(operands):
    _, _, _, a_h, _, b_sph = operands
    with pytest.raises(ValueError, match="sparse-output"):
        plan_matmul(a_h, b_sph, algorithm="steal3d", output="sparse")


# ---------------------------------------------------------------------------
# auto_select integration: the equilibrium score
# ---------------------------------------------------------------------------
def test_auto_scores_include_steal3d(operands):
    _, _, _, a_h, b_h, _ = operands
    choice, scores = api.auto_select(a_h, b_h)
    assert "steal3d" in scores
    assert scores["steal3d"] > 0


def test_auto_picks_steal3d_when_stealing_wins_on_skew():
    """Skewed R-MAT on a 4x4 grid where the simulation says stealing wins:
    in the compute-bound regime (the CI harness machine) the steal3d cost
    entry — scored with the realized equilibrium makespan — beats every
    owner-computes schedule, so auto selects it.  Scoring is mesh-free, so
    the real 4x4 geometry runs in-process."""
    from repro.core.roofline import HOST_CPU, TPU_V5E
    a_h = _skewed_handle(scale=11, bs=16)
    b_h = DistDense.for_rhs(
        jnp.ones((a_h.shape[1], 256), jnp.float32), a_h)
    counts = np.asarray(a_h.counts, dtype=np.float64)
    sim_steal = steal_simulation(counts, steal="locality")
    sim_none = steal_simulation(counts, steal="none")
    assert sim_steal < sim_none           # stealing wins in the simulation
    choice, scores = api.auto_select(a_h, b_h, machine=HOST_CPU)
    assert choice == "steal3d"
    assert scores["steal3d"] == min(scores.values())
    # on the net-bound nominal v5e constants, shipping extra tiles to
    # steal work must NOT look free — auto keeps an owner-computes ring
    v5e_choice, v5e_scores = api.auto_select(a_h, b_h, machine=TPU_V5E)
    assert v5e_choice != "steal3d"


def test_steal3d_cost_scales_with_makespan_not_capacity():
    """The steal3d flop term tracks the LPT makespan: a skewed matrix's
    steal3d cost model must charge fewer executed flops than ring_c's
    uniform g x store padding.  (Cost models are mesh-free.)"""
    a_h = _skewed_handle()
    b_h = DistDense.for_rhs(jnp.ones((a_h.shape[1], 32), jnp.float32), a_h)
    geom = api._geometry(a_h, b_h, impl=None, axis_row="row",
                         axis_col="col")
    sp = api._steal_plan_for(a_h, b_h, geom)
    ring_cm = api._cost_model(api.REGISTRY.get("ring_c"), geom,
                              a_h.abstract_key(), b_h.abstract_key())
    assert sp.cost["total_flops"] < ring_cm["total_flops"]
