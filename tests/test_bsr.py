"""BSR / TiledBSR format tests (vs scipy + dense oracles)."""
import numpy as np
import pytest
import scipy.sparse as sps

from repro.core.bsr import BSR, TiledBSR, random_sparse, rmat_edges, rmat_matrix
from repro.core.grid import ProcessGrid


@pytest.mark.parametrize("m,n,bs,density", [
    (16, 16, 4, 0.2),
    (32, 24, 8, 0.05),
    (17, 13, 4, 0.3),     # non-multiple shapes exercise padding
    (8, 8, 8, 1.0),       # fully dense
    (8, 8, 4, 0.0),       # empty
])
def test_bsr_dense_roundtrip(m, n, bs, density):
    d = random_sparse(m, n, density, seed=m * n)
    a = BSR.from_dense(d, bs)
    back = np.asarray(a.to_dense())[:m, :n]
    np.testing.assert_allclose(back, d, rtol=0, atol=0)


def test_bsr_from_scipy_matches_dense():
    d = random_sparse(24, 24, 0.1, seed=3)
    sp = sps.csr_matrix(d)
    a1 = BSR.from_scipy(sp, 8)
    a2 = BSR.from_dense(d, 8)
    np.testing.assert_array_equal(np.asarray(a1.to_dense()),
                                  np.asarray(a2.to_dense()))
    assert a1.nnzb == a2.nnzb


def test_bsr_capacity_padding_is_inert():
    d = random_sparse(16, 16, 0.2, seed=7)
    a = BSR.from_dense(d, 4)
    a2 = a.with_capacity(a.capacity + 7)
    np.testing.assert_array_equal(np.asarray(a.to_dense()),
                                  np.asarray(a2.to_dense()))
    # padding keeps rows sorted (kernel contract)
    r = np.asarray(a2.rows)
    assert (np.diff(r) >= 0).all()


def test_tiled_bsr_roundtrip_and_metrics():
    d = random_sparse(32, 32, 0.15, seed=11)
    g = ProcessGrid(2, 2)
    t = TiledBSR.from_dense(d, g, block_size=4)
    np.testing.assert_allclose(np.asarray(t.to_dense())[:32, :32], d)
    assert t.load_imbalance() >= 1.0
    assert 0.0 <= t.padded_flop_waste() < 1.0
    # per-tile extraction agrees with the slice of the dense matrix
    tm, tn = t.tile_shape
    for i in range(2):
        for j in range(2):
            tile = np.asarray(t.tile(i, j).to_dense())
            np.testing.assert_allclose(
                tile, np.asarray(t.to_dense())[i*tm:(i+1)*tm, j*tn:(j+1)*tn])


def test_tiled_bsr_stores_preaugmented_sorted_tiles():
    """TiledBSR's stored arrays satisfy the kernel coverage contract:
    every block-row present in every tile, rows sorted, extra blocks zero —
    so the ring bodies can skip per-step augmentation entirely."""
    d = random_sparse(32, 32, 0.1, seed=13)
    g = ProcessGrid(2, 2)
    t = TiledBSR.from_dense(d, g, block_size=4)
    tile_nbr = t.tile_shape[0] // t.block_size
    assert t.store_capacity == t.capacity + tile_nbr
    for i in range(2):
        for j in range(2):
            rows = np.asarray(t.rows[i, j])
            assert (np.diff(rows) >= 0).all()                 # sorted
            assert set(rows.tolist()) == set(range(tile_nbr))  # covered
    # real nonzero block count matches counts (augmented blocks are zero)
    nz_blocks = (np.abs(np.asarray(t.blocks)).sum(axis=(3, 4)) != 0).sum()
    assert nz_blocks == int(np.asarray(t.counts).sum())


def test_tiled_bsr_balance_rows_permutes_and_roundtrips():
    d = rmat_matrix(6, 8, seed=2)           # 64x64, skewed toward low rows
    g = ProcessGrid(4, 4)
    plain = TiledBSR.from_dense(d, g, block_size=4)
    bal = TiledBSR.from_dense(d, g, block_size=4, balance="rows")
    assert bal.capacity <= plain.capacity
    assert bal.load_imbalance() <= plain.load_imbalance() + 1e-9
    perm = np.asarray(bal.row_block_perm)
    assert sorted(perm.tolist()) == list(range(64 // 4))
    # inverting the row-block permutation recovers the original matrix
    back = np.asarray(bal.to_dense()).reshape(-1, 4, 64)[np.argsort(perm)]
    np.testing.assert_array_equal(back.reshape(64, 64), d)
    with pytest.raises(ValueError, match="balance"):
        TiledBSR.from_dense(d, g, block_size=4, balance="diag")


def test_tiled_bsr_balance_never_increases_capacity():
    """Row balancing equalizes grid-row totals, which can worsen the
    per-tile max on some inputs; from_dense must fall back to the identity
    layout then (seeds 3 and 31 regress without the fallback)."""
    g = ProcessGrid(4, 4)
    for seed in range(40):
        d = random_sparse(64, 64, 0.08, seed=seed)
        plain = TiledBSR.from_dense(d, g, block_size=4)
        bal = TiledBSR.from_dense(d, g, block_size=4, balance="rows")
        assert bal.capacity <= plain.capacity, f"seed {seed}"
        if bal.row_block_perm is None:   # identity fallback: same layout
            np.testing.assert_array_equal(np.asarray(bal.counts),
                                          np.asarray(plain.counts))


def test_tiled_bsr_capacity_too_small_message():
    d = random_sparse(32, 32, 0.5, seed=1)
    with pytest.raises(ValueError, match="max tile nnzb"):
        TiledBSR.from_dense(d, ProcessGrid(2, 2), block_size=4, capacity=2)


def test_rmat_shapes_and_determinism():
    e1 = rmat_edges(6, 4, seed=5)
    e2 = rmat_edges(6, 4, seed=5)
    assert e1.shape == (4 << 6, 2)
    np.testing.assert_array_equal(e1, e2)
    assert e1.max() < (1 << 6)
    m = rmat_matrix(5, 4, seed=1)
    assert m.shape == (32, 32)
    # R-MAT with a=0.6 skews mass toward low indices
    half = m[:16, :16].sum()
    assert half > m[16:, 16:].sum()
