"""Serving-subsystem tests.

Covers the four contracts the serving layer makes:

* **Continuous batching is invisible**: a ServeEngine with fewer decode
  slots than requests (slots recycled mid-run, mixed prompt lengths,
  bucket-padded prefill) decodes exactly what unbatched
  ``lm.greedy_decode`` does, on both the dense and the sparse hot path.
* **Bucketed shapes share plans**: after one tenant warms a bucket, a
  second tenant with a *different* prompt of bucketed-equal shape drives
  zero new executable traces through ``plan_matmul`` — pure plan-cache
  hits (``api.add_trace_hook`` counts traces).
* **Eviction rebuilds, never corrupts**: with the plan LRU shrunk below
  the working set, alternating buckets churn the cache (evictions grow)
  yet every decoded stream still matches the dense reference.
* **Zero drops at the smoke capacity factor**: the MoE dropped-token
  stat threaded into the metrics layer reads 0 end-to-end.

Plus unit tests for the batcher (bucketing, padding soundness per model
family) and the metrics math, and the ``check_api`` ban on importing
``repro.serving.engine`` directly.
"""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import api
from repro.models import lm, transformer as tf
from repro.serving import (ServeEngine, ServingMetrics, bucket_for,
                           effective_bucket, percentile)

MAX_LEN = 48


def _params(arch, seed=0):
    cfg = get_config(arch, smoke=True)
    return cfg, tf.init_params(cfg, jax.random.PRNGKey(seed))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


def _reference(params, cfg, toks, steps):
    out = lm.greedy_decode(params, {"tokens": jnp.asarray(toks[None])},
                           cfg, steps=steps, max_len=MAX_LEN)
    return np.asarray(out)[0]


# ---------------------------------------------------------------------------
# batcher: bucketing + padding soundness
# ---------------------------------------------------------------------------
def test_bucket_for_rounds_up():
    assert bucket_for(1) == 8
    assert bucket_for(8) == 8
    assert bucket_for(9) == 16
    assert bucket_for(512) == 512
    with pytest.raises(ValueError, match="exceeds largest bucket"):
        bucket_for(513)


def test_padding_soundness_per_family():
    """Global attention pads to the bucket; recurrent layers ('r'/'m')
    fold pad tokens into their state, so they degrade to exact length."""
    attn = get_config("llama3-8b", smoke=True)
    assert effective_bucket(attn, 12, MAX_LEN) == 16
    rec = get_config("recurrentgemma-2b", smoke=True)
    assert effective_bucket(rec, 12, MAX_LEN) == 12
    # exact-at-bucket lengths never pad, so they're fine for everyone
    assert effective_bucket(rec, 8, MAX_LEN) == 8


def test_batcher_rejects_overflowing_request():
    cfg = get_config("llama3-8b", smoke=True)
    eng = ServeEngine(cfg, params={}, max_len=16)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(np.zeros(12, np.int32), max_new_tokens=8)


# ---------------------------------------------------------------------------
# metrics math
# ---------------------------------------------------------------------------
def test_percentile_linear_interpolation():
    assert np.isnan(percentile([], 50))
    assert percentile([3.0], 99) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.5
    assert percentile([1.0, 2.0, 3.0, 4.0], 0) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0


def test_metrics_lifecycle_aggregates():
    m = ServingMetrics()
    t0 = m.start()
    m.submitted(0, t0, prompt_len=4)
    m.admitted(0, bucket_len=8)
    m.prefill_done(0, 0.5)
    m.decode_step_done(0.1, [0], dropped=0.0)
    m.decode_step_done(0.3, [0], dropped=0.0)
    m.finished(0)
    m.stop()
    s = m.summary()
    assert s["completed"] == 1
    assert s["tokens"] == 3                       # 1 prefill + 2 decode
    assert s["decode_steps"] == 2
    assert s["prefill_s"] == pytest.approx(0.5)
    assert s["decode_s"] == pytest.approx(0.4)
    assert s["tpot_p50_s"] == pytest.approx(0.2)  # mean of the 2 steps
    assert s["ttft_p50_s"] >= 0.0
    assert s["dropped_mean"] == 0.0 and s["dropped_max"] == 0.0


# ---------------------------------------------------------------------------
# continuous batching == unbatched dense reference
# ---------------------------------------------------------------------------
def test_dense_engine_matches_reference():
    """3 requests through 2 slots: slot recycling mid-run, mixed prompt
    lengths (12/9 pad to bucket 16, 8 is exact), per-request positions."""
    cfg, params = _params("llama3-8b")
    prompts = _prompts(cfg, (12, 9, 8))
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=MAX_LEN)
    for toks in prompts:
        eng.submit(toks, max_new_tokens=4)
    results = eng.run()
    for rid, toks in enumerate(prompts):
        np.testing.assert_array_equal(
            results[rid], _reference(params, cfg, toks, 4),
            err_msg=f"request {rid}")
    assert eng.summary()["completed"] == 3


def test_engine_replan_drains_without_corrupting_streams():
    """A replanner tripping mid-run drains in-flight requests, refits
    once, and every decoded stream still matches the reference — serving
    degrades gracefully instead of swapping plans under a request."""
    from repro import obs

    class StubReplanner:
        def __init__(self):
            self.checks = 0
            self.refits = 0

        def should_replan(self):
            self.checks += 1
            return ({"ring_c/padded/False": "ratio=4.00"}
                    if self.checks == 3 else {})

        def refit(self, trips):
            self.refits += 1
            return None, {}, 0

    cfg, params = _params("llama3-8b")
    prompts = _prompts(cfg, (12, 9, 8))
    rp = StubReplanner()
    obs.reset_all()
    obs.enable(clear=True)
    try:
        eng = ServeEngine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                          replanner=rp)
        for toks in prompts:
            eng.submit(toks, max_new_tokens=4)
        results = eng.run()
        snap = obs.registry().snapshot()
    finally:
        obs.disable()
    assert rp.refits == 1 and eng.replans == 1
    assert snap["serve.replans"] == 1.0
    assert snap["serve.replan_s"]["count"] == 1
    for rid, toks in enumerate(prompts):
        np.testing.assert_array_equal(
            results[rid], _reference(params, cfg, toks, 4),
            err_msg=f"request {rid}")


def test_dense_engine_no_padding_family():
    """Recurrent models serve at exact lengths (padding unsound) and still
    match the reference."""
    cfg, params = _params("recurrentgemma-2b")
    prompts = _prompts(cfg, (11, 7))
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=MAX_LEN)
    for toks in prompts:
        eng.submit(toks, max_new_tokens=3)
    results = eng.run()
    for rid, toks in enumerate(prompts):
        np.testing.assert_array_equal(
            results[rid], _reference(params, cfg, toks, 3),
            err_msg=f"request {rid}")


def test_sparse_engine_matches_reference_and_drops_nothing():
    """MoE dispatch + prefill attention scoring on the DistBSR/plan_matmul
    path: decoded tokens equal the dense reference and the dropped-token
    stat is zero at the smoke configs' default capacity factor."""
    cfg, params = _params("olmoe-1b-7b")
    prompts = _prompts(cfg, (12, 9))
    api.clear_plan_cache()
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                      sparse=True)
    for toks in prompts:
        eng.submit(toks, max_new_tokens=3)
    results = eng.run()
    for rid, toks in enumerate(prompts):
        np.testing.assert_array_equal(
            results[rid], _reference(params, cfg, toks, 3),
            err_msg=f"request {rid}")
    s = eng.summary()
    assert s["decode_steps"] > 0
    assert s["dropped_mean"] == 0.0 and s["dropped_max"] == 0.0


# ---------------------------------------------------------------------------
# plan-cache sharing across tenants
# ---------------------------------------------------------------------------
def test_second_tenant_reuses_first_tenants_plans():
    """Two tenants, different prompts, bucketed-equal shape (12 and 9 both
    pad to 16): after tenant A warms the bucket, tenant B's entire sparse
    prefill runs through cached MatmulPlans — zero new executable traces,
    only hits."""
    cfg, params = _params("llama3-8b")
    a, b = _prompts(cfg, (12, 9))
    api.clear_plan_cache()
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                      sparse=True)
    eng.submit(a, max_new_tokens=3)
    eng.run()                                     # tenant A warms bucket 16
    before = api.cache_stats()["plans"]
    assert before["misses"] > 0                   # A actually built plans
    seen = []
    hook = api.add_trace_hook(lambda plan: seen.append(plan))
    try:
        eng.submit(b, max_new_tokens=3)
        results = eng.run()
    finally:
        api.remove_trace_hook(hook)
    after = api.cache_stats()["plans"]
    assert seen == [], "tenant B should not trace any new executable"
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]
    np.testing.assert_array_equal(results[1], _reference(params, cfg, b, 3))


def test_plan_cache_eviction_rebuilds_under_churn():
    """Shrink the plan LRU below one bucket's working set and alternate
    buckets: plans churn (evictions grow, misses on re-entry) but every
    decoded stream still matches the dense reference."""
    cfg, params = _params("llama3-8b")
    prompts = _prompts(cfg, (6, 20, 7))           # buckets 8, 32, 8
    cache = api._PLAN_CACHE
    old_max = cache.maxsize
    api.clear_plan_cache()
    cache.maxsize = 1
    try:
        eng = ServeEngine(cfg, params=params, max_batch=1, max_len=MAX_LEN,
                          sparse=True)
        for toks in prompts:
            eng.submit(toks, max_new_tokens=2)
        results = eng.run()
        stats = api.cache_stats()["plans"]
        assert stats["evictions"] > 0
        assert stats["size"] <= 1
        for rid, toks in enumerate(prompts):
            np.testing.assert_array_equal(
                results[rid], _reference(params, cfg, toks, 2),
                err_msg=f"request {rid}")
    finally:
        cache.maxsize = old_max
        api.clear_plan_cache()


def test_sparse_prefill_trace_count_stable_across_tenants():
    """The jitted sparse-prefill segments (router / expert FFN / QKV+RoPE /
    masked softmax / output projection) trace once per bucket: a second
    tenant with a different prompt of bucketed-equal shape adds zero new
    traces to any segment."""
    from repro.serving import segment_trace_counts
    cfg, params = _params("olmoe-1b-7b")
    a, b = _prompts(cfg, (12, 9))                 # both pad to bucket 16
    api.clear_plan_cache()
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                      sparse=True)
    eng.submit(a, max_new_tokens=3)
    eng.run()                                     # tenant A warms bucket 16
    warm = segment_trace_counts()
    assert warm["route"] > 0 and warm["expert_ffn"] > 0
    assert warm["qkv_rope"] > 0 and warm["probs"] > 0 and warm["out_proj"] > 0
    eng.submit(b, max_new_tokens=3)
    results = eng.run()
    assert segment_trace_counts() == warm, \
        "same-bucket tenant must not retrace any prefill segment"
    np.testing.assert_array_equal(results[1], _reference(params, cfg, b, 3))


# ---------------------------------------------------------------------------
# check_api: repro.serving.engine is internal to serving/
# ---------------------------------------------------------------------------
def _load_check_api():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / "check_api.py"
    spec = importlib.util.spec_from_file_location("check_api_serving", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_api_flags_engine_import(tmp_path):
    (tmp_path / "examples").mkdir()
    (tmp_path / "src" / "repro" / "serving").mkdir(parents=True)
    (tmp_path / "examples" / "bad.py").write_text(
        "from repro.serving.engine import ServeEngine\n")
    (tmp_path / "examples" / "bad2.py").write_text(
        "from repro.serving import engine\n")
    (tmp_path / "src" / "repro" / "serving" / "ok.py").write_text(
        "from .engine import ServeEngine\n")
    (tmp_path / "examples" / "ok2.py").write_text(
        "from repro.serving import ServeEngine\n")
    found = _load_check_api().violations(str(tmp_path))
    assert len(found) == 2
    assert any("bad.py" in f for f in found)
    assert any("bad2.py" in f for f in found)
