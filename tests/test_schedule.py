"""Static workstealing scheduler tests (+ hypothesis properties)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bsr import TiledBSR, rmat_matrix
from repro.core.grid import ProcessGrid
from repro.core.schedule import (balance_row_perm, lpt_assign, makespan,
                                 stage_imbalance, steal_simulation)


def test_lpt_beats_owner_computes_on_skewed_costs():
    rng = np.random.default_rng(0)
    costs = rng.pareto(1.5, size=64) + 0.1      # heavy-tailed like R-MAT tiles
    naive_max, naive_avg = makespan(costs, np.arange(64) % 16, 16)
    a = lpt_assign(costs, 16)
    lpt_max, lpt_avg = makespan(costs, a, 16)
    assert abs(naive_avg - lpt_avg) < 1e-9      # same total work
    assert lpt_max <= naive_max                 # never worse
    assert lpt_max / lpt_avg < naive_max / naive_avg


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=200),
       st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_lpt_properties(costs, n_workers):
    a = lpt_assign(costs, n_workers)
    # every item assigned to a valid worker exactly once
    assert a.shape == (len(costs),)
    assert ((0 <= a) & (a < n_workers)).all()
    mx, avg = makespan(costs, a, n_workers)
    # LPT is a 4/3 + 1/(3m) approximation; a loose sanity bound:
    assert mx <= max(sum(costs) / n_workers + max(costs), 1e-12) + 1e-9


def test_balance_row_perm_reduces_capacity_waste():
    # R-MAT matrices concentrate nnz in low row blocks (a=0.6)
    d = rmat_matrix(8, 8, seed=3)
    g = ProcessGrid(4, 4)
    before = TiledBSR.from_dense(d, g, block_size=8)
    nbr_global = before.shape[0] // before.block_size
    per_row = np.zeros(nbr_global)
    # nnz per global row-block
    for rb in range(nbr_global):
        per_row[rb] = np.count_nonzero(
            d[rb * before.block_size:(rb + 1) * before.block_size])
    perm = balance_row_perm(per_row, 4)
    assert sorted(perm.tolist()) == list(range(nbr_global))
    d_perm = d.reshape(nbr_global, before.block_size, -1)[perm].reshape(d.shape)
    after = TiledBSR.from_dense(d_perm, g, block_size=8)
    assert after.capacity <= before.capacity
    assert after.load_imbalance() <= before.load_imbalance() + 1e-9


def test_stage_imbalance_sync_amplification():
    """Per-stage (BSP) imbalance >= end-to-end (async) imbalance — Fig. 1."""
    rng = np.random.default_rng(1)
    costs = rng.pareto(1.0, size=(16, 16)) + 0.05
    per_stage, end_to_end = stage_imbalance(costs)
    assert per_stage >= end_to_end - 1e-9
    assert end_to_end >= 1.0


def test_stage_imbalance_uniform_is_balanced():
    per_stage, end_to_end = stage_imbalance(np.ones((8, 8)))
    assert per_stage == pytest.approx(1.0)
    assert end_to_end == pytest.approx(1.0)


def test_steal_simulation_ordering():
    rng = np.random.default_rng(2)
    costs = rng.pareto(1.2, size=(8, 8)) + 0.01
    none = steal_simulation(costs, "none")
    rand = steal_simulation(costs, "random", comm_penalty=0.5)
    loc = steal_simulation(costs, "locality", comm_penalty=0.5)
    assert rand <= none + 1e-9          # stealing never hurts the makespan
    assert loc <= none + 1e-9
    assert loc < none                   # skewed input: stealing really wins
    # with free communication the 2D grid's larger feasible set can only
    # help (the 3D grid's edge is cheaper moves, not a better makespan)
    assert steal_simulation(costs, "random", comm_penalty=0.0) <= \
        steal_simulation(costs, "locality", comm_penalty=0.0) + 1e-9
