"""Symbolic/numeric SpGEMM tests.

The symbolic phase is pure host-side numpy, so its structural properties
(predicted mask == dense-product mask) are checked on real multi-tile
grids in-process; numeric sparse-output execution runs on the g=1 mesh
(multi-device grids are covered by ``selftest --check spgemm_sparse`` via
``tests/test_distributed.py``).  Also home to the capacity-bucketed
plan-cache test and the ``tools/fit_machine.py`` recovery test.
"""
import dataclasses
import importlib.util
import pathlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import api
from repro.core.api import DistBSR, DistDense, matmul, plan_matmul
from repro.core.bsr import TiledBSR, random_sparse, rmat_matrix
from repro.core.grid import ProcessGrid, bucket_capacity

G = 1  # the main pytest process owns a single CPU device


def _tiled_pair(kind: str, g: int, bs: int):
    if kind == "rmat":
        a_d = rmat_matrix(scale=6, edgefactor=4, seed=1)
        b_d = rmat_matrix(scale=6, edgefactor=4, seed=2)
    else:
        a_d = random_sparse(48, 48, 0.12, seed=3)
        b_d = random_sparse(48, 48, 0.2, seed=4)
    grid = ProcessGrid(g, g)
    return (a_d, b_d, TiledBSR.from_dense(a_d, grid, bs),
            TiledBSR.from_dense(b_d, grid, bs))


def _block_mask(d, shape, bs):
    """Block mask of a matrix on the padded grid."""
    padded = np.zeros(shape)
    padded[:d.shape[0], :d.shape[1]] = np.abs(d)
    nbr, nbc = shape[0] // bs, shape[1] // bs
    return padded.reshape(nbr, bs, nbc, bs).sum(axis=(1, 3)) != 0


# ---------------------------------------------------------------------------
# Symbolic phase: structural properties (host-side, any grid size)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["random", "rmat"])
@pytest.mark.parametrize("g,bs", [(1, 4), (2, 4), (4, 8)])
def test_predicted_mask_is_block_product_and_covers_result(kind, g, bs):
    """The predicted structure equals the boolean product of the operands'
    block masks — the exact block-granularity structure — and therefore
    covers the true product's mask (block structure is an upper bound:
    two nonzero blocks whose scalar supports don't align multiply to a
    zero block, which R-MAT inputs exercise)."""
    a_d, b_d, a_t, b_t = _tiled_pair(kind, g, bs)
    sym = api.symbolic_spgemm(a_t, b_t)
    a_shape, b_shape = a_t.shape, b_t.shape
    a_mask = _block_mask(a_d, a_shape, bs)
    b_mask = _block_mask(b_d, b_shape, bs)
    want = (a_mask.astype(int) @ b_mask.astype(int)) > 0
    got = sym.block_mask()
    np.testing.assert_array_equal(got, want)
    assert int(sym.c_counts.sum()) == int(want.sum())
    assert sym.density() == pytest.approx(want.mean())
    # no false negatives vs the actual product (abs: no cancellation)
    true_mask = _block_mask(np.abs(a_d) @ np.abs(b_d),
                            (a_shape[0], b_shape[1]), bs)
    assert (got | true_mask == got).all()


@pytest.mark.parametrize("kind", ["random", "rmat"])
def test_predicted_density_prefix_matches_full_phase(kind):
    """The structure-only density (what output="auto" consults — no pair
    lists built) must equal the full symbolic phase's density exactly."""
    _, _, a_t, b_t = _tiled_pair(kind, 2, 4)
    sym = api.symbolic_spgemm(a_t, b_t)
    assert api.predicted_density(a_t, b_t) == sym.density()


def test_symbolic_layout_satisfies_storage_contract():
    """The predicted C layout must satisfy the TiledBSR storage contract
    (row-sorted, every block-row covered, uniform store capacity) so the
    numeric result chains straight into further multiplies."""
    _, _, a_t, b_t = _tiled_pair("rmat", 2, 4)
    sym = api.symbolic_spgemm(a_t, b_t)
    assert sym.store_capacity == sym.capacity + sym.tile_nbr
    assert sym.capacity == bucket_capacity(int(sym.c_counts.max()))
    for i in range(sym.g):
        for j in range(sym.g):
            rows = sym.c_rows[i, j]
            assert (np.diff(rows) >= 0).all()
            assert set(rows.tolist()) == set(range(sym.tile_nbr))


def test_symbolic_pair_lists_sorted_and_covering():
    """Pair slots are nondecreasing and every output slot is visited (the
    packed kernel's first-visit-zeroing contract)."""
    _, _, a_t, b_t = _tiled_pair("random", 2, 4)
    sym = api.symbolic_spgemm(a_t, b_t)
    for i in range(sym.g):
        for j in range(sym.g):
            for k in range(sym.g):
                ps = sym.pair_slot[i, j, k]
                assert (np.diff(ps) >= 0).all()
                assert set(ps.tolist()) == set(range(sym.store_capacity))


def test_symbolic_validates_operands():
    grid = ProcessGrid(2, 2)
    a4 = TiledBSR.from_dense(random_sparse(32, 32, 0.2, seed=0), grid, 4)
    a8 = TiledBSR.from_dense(random_sparse(32, 32, 0.2, seed=0), grid, 8)
    small = TiledBSR.from_dense(random_sparse(16, 16, 0.2, seed=0), grid, 4)
    with pytest.raises(ValueError, match="block size"):
        api.symbolic_spgemm(a4, a8)
    with pytest.raises(ValueError, match="inner"):
        api.symbolic_spgemm(a4, small)
    with pytest.raises(ValueError, match="capacity"):
        api.symbolic_spgemm(a4, a4, capacity=1)


# ---------------------------------------------------------------------------
# Numeric sparse output (g=1 mesh; multi-device in selftest)
# ---------------------------------------------------------------------------
@pytest.fixture
def sparse_operands():
    a_d = random_sparse(16, 16, 0.15, seed=0)
    b_d = random_sparse(16, 16, 0.25, seed=1)
    a_h = DistBSR.from_dense(a_d, g=G, block_size=4)
    b_h = DistBSR.from_dense(b_d, g=G, block_size=4)
    return a_d, b_d, a_h, b_h


@pytest.mark.parametrize("alg", api.sparse_algorithms())
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_sparse_output_allclose_dense_output(sparse_operands, alg, impl):
    a_d, b_d, a_h, b_h = sparse_operands
    plan = plan_matmul(a_h, b_h, algorithm=alg, impl=impl, output="sparse")
    assert plan.kind == "spgemm" and plan.output == "sparse"
    c = plan(a_h, b_h)
    assert isinstance(c, DistBSR)
    assert c.logical_shape == (16, 16)
    dense = np.asarray(matmul(a_h, b_h, algorithm=alg, impl=impl))
    np.testing.assert_allclose(np.asarray(c.densify()), dense, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c.densify()), a_d @ b_d,
                               atol=1e-5)


def test_chained_cube_stays_packed(sparse_operands):
    """A @ A @ A chains through DistBSR handles — no densify, no re-tile —
    and the product handle works as either operand."""
    a_d, _, a_h, _ = sparse_operands
    c2 = matmul(a_h, a_h, algorithm="ring_c", impl="ref", output="sparse")
    c3 = matmul(c2, a_h, algorithm="ring_c", impl="ref", output="sparse")
    assert isinstance(c2, DistBSR) and isinstance(c3, DistBSR)
    np.testing.assert_allclose(np.asarray(c3.densify()), a_d @ a_d @ a_d,
                               atol=1e-4)
    c3r = matmul(a_h, c2, algorithm="ring_c", impl="ref", output="sparse")
    np.testing.assert_allclose(np.asarray(c3r.densify()),
                               np.asarray(c3.densify()), atol=1e-4)


def test_sparse_plan_traces_once_and_caches(sparse_operands):
    _, _, a_h, b_h = sparse_operands
    api.clear_plan_cache()
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       output="sparse")
    for _ in range(4):
        plan(a_h, b_h)
    assert plan.traces == 1
    assert plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       output="sparse") is plan
    # the dense-output plan for the same operands is a different plan
    dense_plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    assert dense_plan is not plan and dense_plan.output == "dense"


def test_sparse_plan_guards_structure(sparse_operands):
    """Pair lists are baked per structure: same abstract shapes but a
    different sparsity pattern must not silently reuse the executable."""
    a_d, b_d, a_h, b_h = sparse_operands
    plan = plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                       output="sparse")
    other = DistBSR.from_dense(random_sparse(16, 16, 0.15, seed=9), g=G,
                               block_size=4,
                               capacity=a_h.capacity)  # same abstract key
    assert other.abstract_key() == a_h.abstract_key()
    with pytest.raises(ValueError, match="structure"):
        plan(other, b_h)
    plan2 = plan_matmul(other, b_h, algorithm="ring_c", impl="ref",
                        output="sparse")
    assert plan2 is not plan   # structure key separates the cache entries


def test_output_auto_picks_by_predicted_density():
    hyper = DistBSR.from_dense(random_sparse(512, 512, 0.0008, seed=3),
                               g=G, block_size=8)
    p = plan_matmul(hyper, hyper, impl="ref", output="auto")
    assert p.output == "sparse"
    densish = DistBSR.from_dense(random_sparse(16, 16, 0.6, seed=4), g=G,
                                 block_size=4)
    p2 = plan_matmul(densish, densish, impl="ref", output="auto")
    assert p2.output == "dense"
    # threshold override flips the decision
    p3 = plan_matmul(densish, densish, impl="ref", output="auto",
                     sparse_threshold=1.0)
    assert p3.output == "sparse"
    # spmm (dense rhs) silently stays dense under "auto"
    b = DistDense.for_rhs(jnp.ones((16, 8), jnp.float32), densish)
    assert plan_matmul(densish, b, impl="ref", output="auto").output \
        == "dense"
    # an explicitly requested dense-only algorithm keeps auto on dense
    p4 = plan_matmul(hyper, hyper, algorithm="ring_a", impl="ref",
                     output="auto")
    assert p4.output == "dense" and p4.algorithm.name == "ring_a"


def test_sparse_output_validation(sparse_operands):
    _, _, a_h, b_h = sparse_operands
    with pytest.raises(ValueError, match="DistBSR"):
        plan_matmul(a_h, jnp.ones((16, 8), jnp.float32), output="sparse")
    other_bs = DistBSR.from_dense(random_sparse(16, 16, 0.2, seed=5), g=G,
                                  block_size=8)
    with pytest.raises(ValueError, match="block size"):
        plan_matmul(a_h, other_bs, output="sparse")
    with pytest.raises(ValueError, match="sparse-output body"):
        plan_matmul(a_h, b_h, algorithm="ring_a", output="sparse")
    with pytest.raises(ValueError, match="output"):
        plan_matmul(a_h, b_h, output="packed")


def test_sparse_rejects_balanced_operands():
    d = rmat_matrix(scale=6, edgefactor=8, seed=2)
    nbr = d.shape[0] // 4
    perm = np.random.default_rng(0).permutation(nbr)
    dp = d.reshape(nbr, 4, -1)[perm].reshape(d.shape)
    t = dataclasses.replace(
        TiledBSR.from_dense(dp, ProcessGrid(1, 1), 4),
        row_block_perm=tuple(int(p) for p in perm))
    bal = DistBSR.from_tiled(t)
    plain = DistBSR.from_dense(d, g=G, block_size=4)
    with pytest.raises(ValueError, match="balance"):
        plan_matmul(bal, plain, output="sparse")


def test_sparse_cost_model_charges_packed_output(sparse_operands):
    """auto_select(output='sparse') scores only sparse-capable schedules,
    against B-stays-sparse wire traffic and packed C bytes."""
    _, _, a_h, b_h = sparse_operands
    choice, scores = api.auto_select(a_h, b_h, output="sparse")
    assert set(scores) == set(api.sparse_algorithms())
    assert choice == min(scores, key=scores.get)
    # hypersparse operands: the sparse model must charge less wire (B rides
    # packed blocks, no densified tile) and fewer executed flops
    hyper = DistBSR.from_dense(random_sparse(512, 512, 0.0008, seed=3),
                               g=G, block_size=8)
    sparse_plan = plan_matmul(hyper, hyper, algorithm="ring_c", impl="ref",
                              output="sparse")
    dense_plan = plan_matmul(hyper, hyper, algorithm="ring_c", impl="ref")
    cm_s, cm_d = sparse_plan.cost_model(), dense_plan.cost_model()
    assert cm_s["net_bytes_per_step"] < cm_d["net_bytes_per_step"]
    assert cm_s["flops_per_step"] < cm_d["flops_per_step"]
    sym = sparse_plan.symbolic
    assert sym.flops() <= 2 * sym.pair_capacity * sym.block_size ** 3 \
        * sym.g ** 3


# ---------------------------------------------------------------------------
# Capacity-bucketed plan cache (satellite)
# ---------------------------------------------------------------------------
def test_bucket_capacity_series():
    # 0 is its own bucket: an empty operand must not allocate phantom
    # block storage (ISSUE-4 satellite)
    assert bucket_capacity(0) == 0
    assert bucket_capacity(1) == 1
    for c in (3, 17, 146, 150, 705):
        b = bucket_capacity(c)
        assert b >= c and b <= max(2, int(np.ceil(c * 1.25)))
    # values inside one bucket gap coincide (the plan-sharing property)
    assert bucket_capacity(170) == bucket_capacity(185) == 185
    assert bucket_capacity(149) == bucket_capacity(150)
    with pytest.raises(ValueError):
        bucket_capacity(-1)


def test_bucketed_handles_share_one_plan_and_trace():
    """Near-identical sparsity patterns (capacities 246..253 minimal) round
    up to one bucket, so their plans — and the jitted executable — are
    shared: one trace total across both matrices."""
    h1 = DistBSR.from_dense(random_sparse(64, 64, 0.2, seed=0), g=G,
                            block_size=4)
    h2 = DistBSR.from_dense(random_sparse(64, 64, 0.2, seed=1), g=G,
                            block_size=4)
    exact1 = DistBSR.from_dense(random_sparse(64, 64, 0.2, seed=0), g=G,
                                block_size=4, capacity=None)
    assert h1.capacity == h2.capacity > exact1.capacity
    assert h1.abstract_key() == h2.abstract_key()
    b = DistDense.for_rhs(jnp.ones((64, 8), jnp.float32), h1)
    api.clear_plan_cache()
    seen = []
    hook = api.add_trace_hook(lambda plan: seen.append(plan))
    try:
        p1 = api.plan_matmul(h1, b, algorithm="ring_c", impl="ref")
        p1(h1, b)
        p2 = api.plan_matmul(h2, b, algorithm="ring_c", impl="ref")
        p2(h2, b)
    finally:
        api.remove_trace_hook(hook)
    assert p1 is p2
    assert len(seen) == 1 and p1.traces == 1
    assert api.plan_cache_size() == 1


# ---------------------------------------------------------------------------
# Machine fitting (tools/fit_machine.py satellite)
# ---------------------------------------------------------------------------
def _load_fit_machine():
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" \
        / "fit_machine.py"
    spec = importlib.util.spec_from_file_location("fit_machine", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fit_machine_recovers_synthetic_constants():
    """Generate measured times from a known Machine via the cost model
    itself; the least-squares fit must recover its net constants."""
    import dataclasses as dc

    from repro.core.roofline import TPU_V5E

    fm = _load_fit_machine()
    true = dc.replace(TPU_V5E, net_bw=7.5e9, hop_latency=3e-5)
    a_h = DistBSR.from_dense(random_sparse(128, 128, 0.1, seed=5), g=G,
                             block_size=8)
    records = []
    for n_cols in (32, 256, 1024):
        b_h = DistDense.for_rhs(jnp.ones((128, n_cols), jnp.float32), a_h)
        geom = api._geometry(a_h, b_h, impl=None, axis_row="row",
                             axis_col="col")
        for name in api.algorithms():
            alg = api.REGISTRY.get(name)
            if alg.cost_fn is not None:  # steal3d: structure-dependent
                continue                 # cost, not the generic model
            cm = api._cost_model(alg, geom, a_h.abstract_key(),
                                 b_h.abstract_key())
            records.append({"cm": cm, "alg": alg, "source": name,
                            "measured": api._predicted_time(cm, alg, true)})
    # bsp records are exactly linear in the unknowns; rings only when
    # comm-bound — fit() drops the rest
    fitted, diag = fm.fit(records, TPU_V5E)
    assert fitted.net_bw == pytest.approx(true.net_bw, rel=0.05)
    assert fitted.hop_latency == pytest.approx(true.hop_latency, rel=0.05)
    assert diag["n_used"] >= 2


def test_fit_machine_roundtrips_preset(tmp_path):
    from repro.core import roofline
    m = roofline.Machine("probe", 1e12, 1e11, 1e9, 4, 2e-6)
    path = str(tmp_path / "machine.json")
    roofline.save_machine(m, path)
    assert roofline.load_machine(path) == m
