"""Checkpoint manager: roundtrip, atomicity, GC, async, elastic reshape."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager


def _tree():
    return ({"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
            {"step": jnp.asarray(3), "mu": {"w": jnp.zeros((3, 4)),
                                            "b": jnp.zeros((4,))}})


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    params, opt = _tree()
    mgr.save(5, params, opt, extra={"loss": 1.25})
    step, (p2, o2), extra = mgr.restore(None, (params, opt))
    assert step == 5
    assert extra["loss"] == 1.25
    for a, b in zip(jax.tree.leaves((params, opt)), jax.tree.leaves((p2, o2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    params, opt = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, params, opt)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    params, opt = _tree()
    mgr.save(7, params, opt)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    params, opt = _tree()
    mgr.save(1, params, opt)
    bad = ({"w": params["w"]}, opt)  # missing 'b'
    with pytest.raises(ValueError):
        mgr.restore(None, bad)


def test_partial_write_is_invisible(tmp_path):
    """A staging dir without manifest must not count as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    params, opt = _tree()
    mgr.save(1, params, opt)
    os.makedirs(tmp_path / "step_9" , exist_ok=True)  # crashed writer stub
    assert mgr.latest_step() == 1
