"""Balanced-tiling benchmark: R-MAT scale-11 SpMM on a 4x4 grid.

The acceptance experiment for sparsity-aware capacity planning: an
unpermuted R-MAT matrix (a=0.6 piles nonzeros into low row blocks) tiled
with ``balance="none"`` vs ``balance="rows"``.  Balancing spreads nonzero
blocks across grid rows, shrinking the uniform tile capacity — i.e. the
block products every device *executes* per ring step — so the balanced
plan is measurably faster, while the carried row permutation is inverted
in the epilogue and results stay allclose.

Runs in its own process (16 fake CPU devices must be configured before jax
imports).  Prints a single JSON object; ``benchmarks/run.py --json`` embeds
it in BENCH_kernels.json.

Usage:  python -m benchmarks.balance_bench [--scale 11] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEVICES = 16  # 4x4 grid


# obs.timed blocks on fn's result before reading the clock (async
# dispatch can't smear) — the check_api-sanctioned timing helper.
from repro.obs import timed as _timed  # noqa: E402


def main() -> int:
    p = argparse.ArgumentParser()
    # scale-11 / 256 dense columns keeps the per-step einsum well above the
    # ~30ms shard_map dispatch floor of 16 fake CPU devices, so the
    # capacity reduction (the real flop saving) dominates the measurement;
    # bs=16 keeps 32x32 block slots per tile — enough block-level
    # granularity for row balancing to bite (bs=32 leaves few slots and the
    # hub tile saturates either way).
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--n-cols", type=int, default=256)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--smoke", action="store_true",
                   help="scale-8 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 8, 1
        args.block_size, args.n_cols = 16, 32

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import jax.numpy as jnp  # noqa: E402  (after flag setup)
    import numpy as np

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.roofline import TPU_V5E

    g = 4
    # No vertex relabeling: keep the R-MAT hub skew that makes uniform
    # capacity worst (the load-balancing target).
    a_dense = rmat_matrix(scale=args.scale, edgefactor=8, seed=0)
    b = np.random.default_rng(0).standard_normal(
        (a_dense.shape[1], args.n_cols)).astype(np.float32)
    mesh = make_grid_mesh(g)

    out = {"rmat_scale": args.scale, "g": g, "block_size": args.block_size,
           "n_cols": args.n_cols, "balance": {}}
    results = {}
    plans = {}
    # Phase 1: build + warm every (balance, algorithm) plan.  All tracing,
    # compilation and buffer churn happens here, before any timing.
    for balance in ("none", "rows"):
        t0 = time.perf_counter()
        a_h = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size,
                                 balance=balance)
        t_tile = time.perf_counter() - t0
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        entry = {
            "tiling_s": t_tile,
            "capacity": a_h.capacity,
            "store_capacity": a_h.tiled.store_capacity,
            "padded_flop_waste": a_h.tiled.padded_flop_waste(),
            "load_imbalance": a_h.tiled.load_imbalance(),
            "algorithms": {},
        }
        for alg in api.algorithms():
            t0 = time.perf_counter()
            plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                   impl="ref", cache=False)
            t_build = time.perf_counter() - t0
            t0 = time.perf_counter()
            c = plan(a_h, b_h)
            c.block_until_ready()
            t_first = time.perf_counter() - t0
            entry["algorithms"][alg] = {
                "plan_build_s": t_build,
                "first_call_s": t_first,
                "predicted_s_v5e": plan.predicted_cost(TPU_V5E),
            }
            plans[(balance, alg)] = (plan, a_h, b_h)
            if alg == "ring_c":
                results[balance] = np.asarray(c)
        choice, scores = api.auto_select(a_h, b_h, machine=TPU_V5E)
        entry["auto_choice"] = choice
        entry["auto_scores"] = scores
        out["balance"][balance] = entry
    # Phase 2: steady-state timing, balanced/unbalanced interleaved within
    # each repeat so machine drift hits both equally; min over repeats
    # (host-process scheduling noise on 16 fake CPU devices swamps a mean).
    times = {key: [] for key in plans}
    for _ in range(args.repeats):
        for key, (plan, a_h, b_h) in plans.items():
            times[key].append(
                _timed(lambda: plan(a_h, b_h).block_until_ready()))
    for (balance, alg), ts in times.items():
        out["balance"][balance]["algorithms"][alg]["per_multiply_s"] = min(ts)

    out["allclose_balanced_vs_none"] = bool(np.allclose(
        results["none"], results["rows"], atol=1e-4))
    none, rows = out["balance"]["none"], out["balance"]["rows"]
    out["waste_reduction"] = (none["padded_flop_waste"]
                              - rows["padded_flop_waste"])
    t_n = none["algorithms"]["ring_c"]["per_multiply_s"]
    t_r = rows["algorithms"]["ring_c"]["per_multiply_s"]
    out["ring_c_speedup_balanced"] = t_n / t_r if t_r else float("nan")
    json.dump(out, sys.stdout, indent=1)
    print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
