"""Paper Table 2: component breakdown (Comp / Comm / Acc / Load-imb).

The paper instruments wall-time per component on Summit/DGX-2.  Here the
breakdown is *modelled* per algorithm from the tile structure and machine
constants (the same cost decomposition the paper tabulates), for an R-MAT
matrix on a 10x10-style grid (we use the largest square grid available):

  Comp  = max-device local flops / local peak
  Comm  = per-iteration tile bytes / net bw (x iterations)
  Acc   = C-tile routing bytes (stationary-A only)
  LoadI = end-to-end (async) or per-stage (BSP) imbalance penalty
"""
from __future__ import annotations

import numpy as np


def run(scale: int = 12, g: int = 10, width: int = 256):
    from repro.core.bsr import rmat_edges
    from repro.core.roofline import SUMMIT_V100, spmm_local_ai, local_peak
    from repro.core.schedule import stage_imbalance
    import scipy.sparse as sps

    e = rmat_edges(scale, 8, seed=3)
    n = 1 << scale
    a = sps.csr_matrix((np.ones(len(e), np.float32), (e[:, 0], e[:, 1])),
                       shape=(n, n))
    a.data[:] = 1.0
    ts = n // g
    nnz_tile = np.zeros((g, g))
    rows_idx = np.repeat(np.arange(n), np.diff(a.indptr))
    np.add.at(nnz_tile, (np.minimum(rows_idx // ts, g - 1),
                         np.minimum(a.indices // ts, g - 1)), 1.0)
    mach = SUMMIT_V100
    w = mach.word_bytes
    d = a.nnz / n / n
    flops_tile = 2.0 * nnz_tile * (width / g)     # per k-stage local flops
    per_stage, end_to_end = stage_imbalance(nnz_tile)
    peak = local_peak(spmm_local_ai(n, n, width, g * g, d, w), mach)

    out = []
    for alg, n_comm_tiles, acc_tiles, imb in (
            ("summa_bcast", 2 * g, 0, per_stage),
            ("ring_c", 2 * g, 0, end_to_end),
            ("ring_a", g, g, end_to_end)):
        comp = flops_tile.sum() / (g * g) / peak * g  # avg per-device, all k
        a_bytes = w * (2 * nnz_tile.mean() + ts + 1)
        b_bytes = w * ts * (width / g)
        comm = n_comm_tiles * (a_bytes + b_bytes) / mach.net_bw
        acc = acc_tiles * (w * ts * (width / g)) / mach.net_bw
        load = comp * (imb - 1.0)
        out.append((f"table2,{alg},comp", comp * 1e6, "us"))
        out.append((f"table2,{alg},comm", comm * 1e6, "us"))
        out.append((f"table2,{alg},acc", acc * 1e6, "us"))
        out.append((f"table2,{alg},load_imb", load * 1e6, "us"))
    return out


def main():
    for name, val, unit in run():
        print(f"{name},{val:.2f},{unit}")


if __name__ == "__main__":
    main()
