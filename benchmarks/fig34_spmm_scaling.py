"""Paper Figs. 3/4: SpMM strong scaling, all algorithms.

CPU measurement of algorithmic behaviour: wall-time of each distributed
algorithm on 1/4/9(/16) fake host devices for an R-MAT matrix at dense
widths N in {128, 512} (the paper's widths), plus model-predicted Summit /
TPU-v5e times for the same tiling.  Uses the plan-based API: the DistMatrix
handles and MatmulPlan are built once per (algorithm, width), so the timed
loop measures pure communication + compute — the paper's steady state —
not per-call setup.  Run in a subprocess per device count (jax locks the
device count at first init); this module is invoked by benchmarks.run
in-process for the current device count or standalone:

  XLA_FLAGS=--xla_force_host_platform_device_count=9 \
  PYTHONPATH=src python -m benchmarks.fig34_spmm_scaling
"""
from __future__ import annotations

import time

import numpy as np


def run(scale: int = 10, widths=(128, 512), repeats: int = 3):
    import jax
    import jax.numpy as jnp

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.roofline import SUMMIT_V100, TPU_V5E, spmm_model

    n_dev = len(jax.devices())
    g = int(np.sqrt(n_dev))
    rows = []
    a = rmat_matrix(scale, 8, seed=1)
    m = a.shape[0]
    density = float(a.mean())
    for width in widths:
        b = np.random.default_rng(0).standard_normal(
            (m, width)).astype(np.float32)
        mesh = make_grid_mesh(g)
        a_h = DistBSR.from_dense(a, g=g, block_size=16)
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        for alg in api.algorithms():
            plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                   impl="ref")
            fn = lambda: plan(a_h, b_h).block_until_ready()
            fn()  # compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn()
            dt = (time.perf_counter() - t0) / repeats
            rows.append((f"fig34,spmm,{alg},p={n_dev},n={width}",
                         dt * 1e6, "us_per_call"))
        pred = spmm_model(m, m, width, max(n_dev, 1), density, SUMMIT_V100)
        flops = 2 * density * m * m * width
        rows.append((f"fig34,model_summit,p={n_dev},n={width}",
                     flops / max(pred["perf"], 1) / max(n_dev, 1) * 1e6,
                     "us_predicted"))
        pred_t = spmm_model(m, m, width, max(n_dev, 1), density, TPU_V5E)
        rows.append((f"fig34,model_tpuv5e,p={n_dev},n={width}",
                     flops / max(pred_t["perf"], 1) / max(n_dev, 1) * 1e6,
                     "us_predicted"))
    return rows


def main():
    for name, val, unit in run():
        print(f"{name},{val:.1f},{unit}")


if __name__ == "__main__":
    main()
