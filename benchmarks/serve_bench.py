"""Synthetic many-user serving trace through ServeEngine.

The acceptance experiment for the serving subsystem: Poisson arrivals with
mixed prompt lengths are served by a ``ServeEngine`` with the sparse hot
path on — MoE expert dispatch and prefill attention scoring run as
``DistBSR``/``plan_matmul`` products — and the run records p50/p99
TTFT/TPOT, tokens/sec, plans-per-second and the plan-cache hit rate into
``BENCH_kernels.json`` (section ``serve_trace``) via ``run.py --json``.

The run *asserts* the serving contract and exits non-zero on violation,
so the ``--smoke`` tier-1 path enforces it in CI:

* every decoded stream equals the unbatched dense-reference
  ``lm.greedy_decode`` of the same prompt (continuous batching, bucket
  padding and the sparse path change nothing observable);
* plan-cache hits outnumber misses over the trace (bucketed shapes make
  tenants share plans);
* zero dropped tokens at the smoke configs' default capacity factor.

Runs on a single device (g=1 process grid).  Prints one JSON object.

Usage:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys

PROMPT_LENS = (6, 10, 12, 16, 20, 28)    # buckets 8 / 16 / 16 / 16 / 32 / 32


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="olmoe-1b-7b")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--gen-len", type=int, default=6)
    p.add_argument("--mean-interarrival-s", type=float, default=0.05)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="4-request quick pass for tier-1")
    args = p.parse_args()
    if args.smoke:
        args.requests, args.gen_len = 4, 3

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import api
    from repro.models import lm, transformer as tf
    from repro.serving import ServeEngine

    cfg = get_config(args.arch, smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    # Poisson process: exponential interarrivals, mixed prompt lengths
    arrivals = np.cumsum(rng.exponential(args.mean_interarrival_s,
                                         args.requests))
    lens = rng.choice(PROMPT_LENS, args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(n),)).astype(np.int32)
               for n in lens]

    api.clear_plan_cache()
    engine = ServeEngine(cfg, params=params, max_batch=4, max_len=64,
                         sparse=True)
    for toks, at in zip(prompts, arrivals):
        engine.submit(toks, max_new_tokens=args.gen_len, arrival=float(at))
    results = engine.run()
    stats = engine.summary()

    failures = []
    for rid, toks in enumerate(prompts):
        ref = np.asarray(lm.greedy_decode(
            params, {"tokens": jnp.asarray(toks[None])}, cfg,
            steps=args.gen_len, max_len=64))[0]
        if not (results[rid] == ref).all():
            failures.append(f"request {rid} diverges from dense reference")
    plans = stats["plan_cache"]
    if plans["hits"] <= plans["misses"]:
        failures.append(f"plan-cache hits ({plans['hits']}) <= misses "
                        f"({plans['misses']}): no cross-request sharing")
    if stats["dropped_max"] > 0:
        failures.append(f"dropped tokens at default capacity factor "
                        f"({stats['dropped_max']})")

    out = {
        "arch": args.arch,
        "requests": args.requests,
        "gen_len": args.gen_len,
        "mean_interarrival_s": args.mean_interarrival_s,
        "prompt_lens": [int(n) for n in lens],
        "ttft_p50_s": stats["ttft_p50_s"],
        "ttft_p99_s": stats["ttft_p99_s"],
        "tpot_p50_s": stats["tpot_p50_s"],
        "tpot_p99_s": stats["tpot_p99_s"],
        "tokens_per_s": stats["tokens_per_s"],
        "decode_tok_per_s": stats["decode_tok_per_s"],
        "prefill_s": stats["prefill_s"],
        "decode_s": stats["decode_s"],
        "plan_lookups": stats["plan_lookups"],
        "plans_per_second": stats["plans_per_second"],
        "plan_cache": plans,
        "plan_cache_hit_rate": stats["plan_cache_hit_rate"],
        "dropped_mean": stats["dropped_mean"],
        "dropped_max": stats["dropped_max"],
        "matches_dense_reference": not any("diverges" in f
                                           for f in failures),
        "hits_gt_misses": plans["hits"] > plans["misses"],
    }
    json.dump(out, sys.stdout, indent=1)
    print()
    if failures:
        print("serve_bench FAILED: " + "; ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
