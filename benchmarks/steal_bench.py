"""Static work-stealing benchmark: skewed R-MAT SpMM on a 4x4 grid.

The acceptance experiment for the steal3d static dispatch: an unpermuted
R-MAT matrix (a=0.6 piles nonzeros into hub tiles) multiplied through every
owner-computes schedule — including the balanced-tiling variant of ring_c,
the strongest owner-computes contender — and through ``steal3d``, whose
plan executes the LPT equilibrium of the paper's SS3.4 locality-aware work
stealing.  The owner-computes rings execute ``g x store_capacity`` block
products per device (uniform padding: every device pays the hub tile's
capacity every step); steal3d executes its pair-list length — the stealing
equilibrium's makespan — so on skewed input it is measurably faster while
results stay allclose.  Also records the ``steal_simulation`` predictions,
the assignment statistics, the roofline moved-tile traffic split, and the
``algorithm="auto"`` choice under both the harness machine (compute-bound:
picks steal3d) and nominal v5e constants (net-bound: keeps a ring).

Runs in its own process (16 fake CPU devices must be configured before jax
imports).  Prints a single JSON object; ``benchmarks/run.py --json`` embeds
it in BENCH_kernels.json.

Usage:  python -m benchmarks.steal_bench [--scale 11] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEVICES = 16  # 4x4 grid


# obs.timed blocks on fn's result before reading the clock (async
# dispatch can't smear) — the check_api-sanctioned timing helper.
from repro.obs import timed as _timed  # noqa: E402


def main() -> int:
    p = argparse.ArgumentParser()
    # Same geometry as balance_bench (scale-11 R-MAT, 256 dense columns,
    # bs=16): the per-step einsum sits well above the shard_map dispatch
    # floor of 16 fake CPU devices, so executed block products — the
    # quantity the stealing equilibrium shrinks — dominate the measurement.
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--n-cols", type=int, default=256)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--smoke", action="store_true",
                   help="scale-8 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 8, 1
        args.block_size, args.n_cols = 8, 64

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import jax.numpy as jnp  # noqa: E402  (after flag setup)
    import numpy as np

    from repro.core import api, roofline
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.roofline import HOST_CPU, TPU_V5E
    from repro.core.schedule import steal_simulation

    g = 4
    a_dense = rmat_matrix(scale=args.scale, edgefactor=8, seed=0)
    b = np.random.default_rng(0).standard_normal(
        (a_dense.shape[1], args.n_cols)).astype(np.float32)
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size)
    a_bal = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size,
                               balance="rows")
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    b_bal = DistDense.for_rhs(jnp.asarray(b), a_bal)

    counts = np.asarray(a_h.counts, dtype=np.float64)
    out = {"rmat_scale": args.scale, "g": g,
           "block_size": args.block_size, "n_cols": args.n_cols,
           "a_capacity": a_h.capacity,
           "store_capacity": a_h.tiled.store_capacity,
           "steal_simulation": {
               "none": steal_simulation(counts, "none"),
               "random": steal_simulation(counts, "random",
                                          comm_penalty=1.0),
               "locality": steal_simulation(counts, "locality",
                                            comm_penalty=1.0),
           },
           "algorithms": {}}
    out["simulation_predicts_stealing_wins"] = \
        out["steal_simulation"]["locality"] < out["steal_simulation"]["none"]

    api.clear_plan_cache()
    # Phase 1: build + warm every plan (tracing/compilation happens here).
    plans, results = {}, {}
    contenders = [(alg, a_h, b_h) for alg in api.algorithms()]
    contenders.append(("ring_c[balanced]", a_bal, b_bal))
    for name, ah, bh in contenders:
        alg = name.split("[")[0]
        t0 = time.perf_counter()
        plan = api.plan_matmul(ah, bh, mesh=mesh, algorithm=alg,
                               impl="ref", cache=False)
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        c = plan(ah, bh)
        c.block_until_ready()
        t_first = time.perf_counter() - t0
        out["algorithms"][name] = {
            "plan_build_s": t_build,
            "first_call_s": t_first,
            "predicted_s_v5e": plan.predicted_cost(TPU_V5E),
            "predicted_s_host": plan.predicted_cost(HOST_CPU),
        }
        plans[name] = (plan, ah, bh)
        results[name] = np.asarray(c)

    # Phase 2: steady-state timing, schedules interleaved within each
    # repeat; min over repeats (subprocess scheduling noise on 16 fake
    # devices swamps a mean).
    times = {key: [] for key in plans}
    for _ in range(args.repeats):
        for key, (plan, ah, bh) in plans.items():
            times[key].append(
                _timed(lambda: plan(ah, bh).block_until_ready()))
    for key, ts in times.items():
        out["algorithms"][key]["per_multiply_s"] = min(ts)

    # Assignment + roofline detail for the steal3d plan.
    splan = plans["steal3d"][0].steal
    asg = splan.assignment
    cm = dict(splan.cost)
    out["steal3d"] = {
        "owner_makespan": asg.owner_makespan,
        "lpt_makespan": asg.makespan,
        "equilibrium_gain": asg.gain(),
        "n_moved_items": asg.n_moved,
        "pair_capacity": splan.pair_capacity,
        "owner_ring_block_products": g * a_h.tiled.store_capacity,
        "move_rounds": len(splan.a_deltas) + len(splan.b_deltas),
        "reduce_rounds": len(splan.row_deltas) + len(splan.col_deltas),
        "roofline_host": roofline.steal3d_model(
            cm["total_flops"], cm["gather_bytes"], cm["moved_tile_bytes"],
            cm["reduce_bytes"], cm["ai_local"], HOST_CPU),
    }

    out["allclose_steal3d_vs_ring_c"] = bool(np.allclose(
        results["steal3d"], results["ring_c"], atol=1e-4))
    owner_names = [n for n in out["algorithms"] if n != "steal3d"]
    best_owner = min(owner_names,
                     key=lambda n: out["algorithms"][n]["per_multiply_s"])
    t_owner = out["algorithms"][best_owner]["per_multiply_s"]
    t_steal = out["algorithms"]["steal3d"]["per_multiply_s"]
    out["best_owner_computes"] = best_owner
    out["steal3d_speedup_vs_best_owner"] = t_owner / t_steal \
        if t_steal else float("nan")

    # What the planner does on its own: compute-bound harness machine ->
    # steal3d; net-bound nominal v5e -> an owner-computes ring.
    choice_host, scores_host = api.auto_select(a_h, b_h, machine=HOST_CPU)
    choice_v5e, _ = api.auto_select(a_h, b_h, machine=TPU_V5E)
    out["auto"] = {"choice_host_cpu": choice_host,
                   "choice_tpu_v5e": choice_v5e,
                   "scores_host_cpu": scores_host}

    json.dump(out, sys.stdout, indent=1)
    print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
