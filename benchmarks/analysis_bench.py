"""Static analysis benchmark: verifier wall time on healthy plans.

Runs the full static verifier (``repro.analysis``) over the plan matrix
the other benchmarks execute — every registered algorithm through padded
and packed wire on a skewed R-MAT SpMM, plus a sparse-output SpGEMM —
and records per-plan wall time for the schedule checker and the jaxpr
lint, asserting **zero findings** on every healthy plan (the clean-plan
contract the mutation tests invert).

Also measures the acceptance criterion for the ``validate=`` plumbing:
on a *cached* plan, ``plan_matmul(validate="fast")`` must add < 5% over
``validate="off"`` — the per-plan verdict is memoized, so a cache hit
pays one set lookup, not a re-verification.

Runs in its own process (16 fake CPU devices must be configured before
jax imports).  Prints a single JSON object; ``benchmarks/run.py --json``
embeds it as the ``analysis`` section of BENCH_kernels.json and the
``--smoke`` tier-1 path asserts the zero-findings + overhead contract.

Usage:  python -m benchmarks.analysis_bench [--scale 11] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEVICES = 16  # 4x4 grid

ALGORITHMS = ("ring_c", "ring_a", "ring_c_bidir", "summa_ag",
              "summa_bcast", "steal3d")


def main() -> int:  # analysis: allow(source.perf-counter-discipline)
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--n-cols", type=int, default=256)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--smoke", action="store_true",
                   help="scale-8 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 8, 3
        args.block_size, args.n_cols = 8, 64

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import jax.numpy as jnp  # noqa: E402  (after flag setup)
    import numpy as np

    from repro import analysis
    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh

    g = 4
    a_dense = rmat_matrix(scale=args.scale, edgefactor=8, seed=0)
    b = np.random.default_rng(0).standard_normal(
        (a_dense.shape[1], args.n_cols)).astype(np.float32)
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)

    out = {"rmat_scale": args.scale, "g": g,
           "block_size": args.block_size, "n_cols": args.n_cols,
           "plans": {}}
    failures = []
    total_findings = 0

    def verify(tag, plan, lhs, rhs):  # analysis: allow(source.perf-counter-discipline)
        nonlocal total_findings
        t0 = time.perf_counter()
        f_sched = analysis.check_plan(plan, lhs, rhs)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        jaxpr = analysis.trace_plan(plan, lhs, rhs)
        f_lint = analysis.lint_plan(plan, jaxpr=jaxpr)
        t_lint = time.perf_counter() - t0
        found = f_sched + f_lint
        out["plans"][tag] = {
            "schedule_check_s": t_sched,
            "jaxpr_lint_s": t_lint,
            "findings": len(found),
        }
        total_findings += len(found)
        for f in found:
            failures.append(f"{tag}: {f}")

    api.clear_plan_cache()
    for alg in ALGORITHMS:
        for wire in ("padded", "packed"):
            plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                   impl="ref", wire=wire, cache=False)
            verify(f"{alg}/{wire}", plan, a_h, b_h)
    plan = api.plan_matmul(a_h, a_h, mesh=mesh, algorithm="ring_c",
                           impl="ref", output="sparse", cache=False)
    verify("ring_c/sparse-output", plan, a_h, a_h)

    # cached plan-build overhead of validate="fast": warm the plan cache
    # and the per-plan verdict memo, then time pure cache-hit rebuilds.
    # Modes are interleaved per trial (min-of-trials each) so host load
    # drift during the run cannot land on one side of the comparison.
    api.clear_plan_cache()
    kw = dict(mesh=mesh, algorithm="ring_c", impl="ref")
    api.plan_matmul(a_h, b_h, validate="fast", **kw)   # warm both caches
    n_calls = 500

    def hit_times():  # analysis: allow(source.perf-counter-discipline)
        samples = {"off": [], "fast": []}
        for _ in range(max(args.repeats, 5) * 2):
            for mode in ("off", "fast"):
                t0 = time.perf_counter()
                for _ in range(n_calls):
                    api.plan_matmul(a_h, b_h, validate=mode, **kw)
                samples[mode].append(
                    (time.perf_counter() - t0) / n_calls)
        return samples

    samples = hit_times()
    t_off, t_fast = min(samples["off"]), min(samples["fast"])
    # overhead as the median of paired per-trial ratios: pairing cancels
    # host load drift across the run, the median kills preemption spikes
    ratios = sorted(f / o for o, f in zip(samples["off"],
                                          samples["fast"]) if o)
    overhead = ratios[len(ratios) // 2] - 1.0 if ratios else float("inf")
    out["validate_fast"] = {
        "cached_build_s_off": t_off,
        "cached_build_s_fast": t_fast,
        "overhead": overhead,
        "overhead_ok": overhead < 0.05,
    }
    if overhead >= 0.05:
        failures.append(
            f"validate='fast' adds {overhead:.1%} to cached plan build "
            "(contract: < 5%)")

    out["total_findings"] = total_findings
    out["clean"] = total_findings == 0
    json.dump(out, sys.stdout, indent=1)
    print()
    if failures:
        print("analysis_bench FAILED: " + "; ".join(failures),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
