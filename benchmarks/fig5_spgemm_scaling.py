"""Paper Fig. 5: SpGEMM strong scaling (C = A @ A), all algorithms.

Same protocol as fig34 but sparse x sparse, on the current device count.
"""
from __future__ import annotations

import time

import numpy as np


def run(scale: int = 9, repeats: int = 3):
    import jax
    import jax.numpy as jnp

    from repro.core import spmm as dspmm
    from repro.core.bsr import TiledBSR, rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.grid import ProcessGrid

    n_dev = len(jax.devices())
    g = int(np.sqrt(n_dev))
    rows = []
    a = rmat_matrix(scale, 8, seed=2)
    grid = ProcessGrid(g, g)
    mesh = make_grid_mesh(g)
    a_t = TiledBSR.from_dense(a, grid, block_size=16)
    for alg in dspmm.ALGORITHMS:
        fn = lambda: dspmm.spgemm(a_t, a_t, mesh=mesh, algorithm=alg,
                                  impl="ref").block_until_ready()
        fn()
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn()
        dt = (time.perf_counter() - t0) / repeats
        rows.append((f"fig5,spgemm,{alg},p={n_dev}", dt * 1e6, "us_per_call"))
    rows.append((f"fig5,load_imbalance,p={n_dev}",
                 a_t.load_imbalance(), "max_over_avg_nnzb"))
    rows.append((f"fig5,padded_flop_waste,p={n_dev}",
                 a_t.padded_flop_waste(), "fraction"))
    return rows


def main():
    for name, val, unit in run():
        print(f"{name},{val:.2f},{unit}")


if __name__ == "__main__":
    main()
