"""Paper Fig. 5: SpGEMM strong scaling (C = A @ A), all algorithms.

Same protocol as fig34 but sparse x sparse, on the current device count,
through the plan-based API (one DistBSR handle for both operands; plans
built outside the timed loop).
"""
from __future__ import annotations

import time

import numpy as np


def run(scale: int = 9, repeats: int = 3):
    import jax

    from repro.core import api
    from repro.core.api import DistBSR
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh

    n_dev = len(jax.devices())
    g = int(np.sqrt(n_dev))
    rows = []
    a = rmat_matrix(scale, 8, seed=2)
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a, g=g, block_size=16)
    for alg in api.algorithms():
        plan = api.plan_matmul(a_h, a_h, mesh=mesh, algorithm=alg,
                               impl="ref")
        fn = lambda: plan(a_h, a_h).block_until_ready()
        fn()
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn()
        dt = (time.perf_counter() - t0) / repeats
        rows.append((f"fig5,spgemm,{alg},p={n_dev}", dt * 1e6, "us_per_call"))
    rows.append((f"fig5,load_imbalance,p={n_dev}",
                 a_h.tiled.load_imbalance(), "max_over_avg_nnzb"))
    rows.append((f"fig5,padded_flop_waste,p={n_dev}",
                 a_h.tiled.padded_flop_waste(), "fraction"))
    return rows


def main():
    for name, val, unit in run():
        print(f"{name},{val:.2f},{unit}")


if __name__ == "__main__":
    main()
