"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,unit`` CSV.  Figures needing multiple device counts are
run in subprocesses (jax locks the host device count at first init); the
rest run in-process.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig1 fig2  # subset
"""
from __future__ import annotations

import os
import subprocess
import sys

SCALING_DEVICE_COUNTS = (1, 4, 9)


def _run_subprocess(module: str, devices: int) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-m", module], env=env,
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        tail = out.stderr.strip().splitlines()[-1] if out.stderr else "?"
        print(f"{module},ERROR,{tail}")
    else:
        sys.stdout.write(out.stdout)


def main() -> None:
    which = set(sys.argv[1:]) or {"fig1", "fig2", "fig34", "fig5", "table2",
                                  "kernels"}
    if "fig1" in which:
        from benchmarks import fig1_load_imbalance
        fig1_load_imbalance.main()
    if "fig2" in which:
        from benchmarks import fig2_roofline
        fig2_roofline.main()
    if "fig34" in which:
        for p in SCALING_DEVICE_COUNTS:
            _run_subprocess("benchmarks.fig34_spmm_scaling", p)
    if "fig5" in which:
        for p in SCALING_DEVICE_COUNTS:
            _run_subprocess("benchmarks.fig5_spgemm_scaling", p)
    if "table2" in which:
        from benchmarks import table2_breakdown
        table2_breakdown.main()
    if "kernels" in which:
        from benchmarks import kernels_bench
        kernels_bench.main()


if __name__ == "__main__":
    main()
