"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,unit`` CSV.  Figures needing multiple device counts are
run in subprocesses (jax locks the host device count at first init); the
rest run in-process.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig1 fig2  # subset
  PYTHONPATH=src python -m benchmarks.run --smoke    # quick CI pass
  PYTHONPATH=src python -m benchmarks.run --json     # write BENCH_kernels.json

``--json`` runs the kernel micro-bench plus the balanced-tiling and
dense-vs-sparse-output SpGEMM experiments (R-MAT on a 4x4 grid, each in a
16-device subprocess) and writes ``BENCH_kernels.json`` at the repo root:
plan build time, per-multiply time, padded-flop waste, output footprint
and predicted-vs-measured cost per algorithm — the perf-trajectory
baseline for future PRs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SCALING_DEVICE_COUNTS = (1, 4, 9)
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _subprocess_env(devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _run_subprocess(module: str, devices: int, *extra_args: str,
                    quiet: bool = False) -> str:
    out = subprocess.run(
        [sys.executable, "-m", module, *extra_args],
        env=_subprocess_env(devices), capture_output=True, text=True,
        timeout=1200)
    if out.returncode != 0:
        tail = out.stderr.strip().splitlines()[-1] if out.stderr else "?"
        print(f"{module},ERROR,{tail}")
        return ""
    if not quiet:
        sys.stdout.write(out.stdout)
    return out.stdout


def _write_json(smoke: bool) -> None:
    from benchmarks import kernels_bench
    # "smoke" marks reduced-scale payloads so trajectory comparisons never
    # mistake a quick CI pass for the full baseline.
    payload = {"smoke": smoke,
               "kernels": kernels_bench.run_json(smoke=smoke)}
    # The balance and spgemm experiments configure 16 fake devices before
    # importing jax, so each runs in its own process printing one JSON
    # object.
    extra = ("--smoke",) if smoke else ()
    raw = _run_subprocess("benchmarks.balance_bench", 16, *extra, quiet=True)
    try:
        payload["balance_rmat_4x4"] = json.loads(raw) if raw else {
            "error": "balance bench failed"}
    except json.JSONDecodeError as e:
        payload["balance_rmat_4x4"] = {"error": f"unparseable output: {e}"}
        raw = ""   # degrade like the empty-output case (exit 1 below)
    raw_sp = _run_subprocess("benchmarks.spgemm_bench", 16, *extra,
                             quiet=True)
    try:
        payload["spgemm_rmat_4x4"] = json.loads(raw_sp) if raw_sp else {
            "error": "spgemm bench failed"}
    except json.JSONDecodeError as e:
        payload["spgemm_rmat_4x4"] = {"error": f"unparseable output: {e}"}
        raw_sp = ""
    raw = raw and raw_sp   # both experiments must land in the baseline
    # Smoke and error payloads go to sibling files so neither a quick CI
    # pass nor a failed run can clobber the committed full-scale baseline.
    if smoke:
        name = "BENCH_kernels_smoke.json"
    elif not raw:
        name = "BENCH_kernels_error.json"
    else:
        name = "BENCH_kernels.json"
    path = os.path.join(REPO_ROOT, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    if not raw:
        # don't let CI record a baseline missing the headline experiment
        sys.exit(1)


def main() -> None:
    argv = sys.argv[1:]
    unknown = [a for a in argv if a.startswith("-")
               and a not in ("--smoke", "--json")]
    if unknown:
        sys.exit(f"unknown flags {unknown}; supported: --smoke --json")
    smoke = "--smoke" in argv
    as_json = "--json" in argv
    which = {a for a in argv if not a.startswith("-")}
    if which and (smoke or as_json):
        sys.exit(f"figure selectors {sorted(which)} cannot be combined "
                 "with --smoke/--json (fixed payloads)")
    if as_json:
        _write_json(smoke)
        return
    if smoke:
        # Quick self-contained pass for tools/run_tier1.sh: kernel oracle
        # rows + one scale-8 balance check + one scale-9 sparse-output
        # spgemm check, no multi-minute figure sweeps.
        from benchmarks import kernels_bench
        kernels_bench.main(smoke=True)
        ok = True
        for module in ("benchmarks.balance_bench", "benchmarks.spgemm_bench"):
            raw = _run_subprocess(module, 16, "--smoke", quiet=True)
            name = module.rsplit(".", 1)[1]
            print(f"smoke,{name},{'ok' if raw else 'FAILED'}")
            ok = ok and bool(raw)
        if not ok:
            sys.exit(1)
        return
    which = which or {"fig1", "fig2", "fig34", "fig5", "table2", "kernels"}
    # fall through: full figure sweep (optionally filtered by name)
    if "fig1" in which:
        from benchmarks import fig1_load_imbalance
        fig1_load_imbalance.main()
    if "fig2" in which:
        from benchmarks import fig2_roofline
        fig2_roofline.main()
    if "fig34" in which:
        for p in SCALING_DEVICE_COUNTS:
            _run_subprocess("benchmarks.fig34_spmm_scaling", p)
    if "fig5" in which:
        for p in SCALING_DEVICE_COUNTS:
            _run_subprocess("benchmarks.fig5_spgemm_scaling", p)
    if "table2" in which:
        from benchmarks import table2_breakdown
        table2_breakdown.main()
    if "kernels" in which:
        from benchmarks import kernels_bench
        kernels_bench.main()


if __name__ == "__main__":
    main()
