"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,unit`` CSV.  Figures needing multiple device counts are
run in subprocesses (jax locks the host device count at first init); the
rest run in-process.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig1 fig2  # subset
  PYTHONPATH=src python -m benchmarks.run --smoke    # quick CI pass
  PYTHONPATH=src python -m benchmarks.run --json     # write BENCH_kernels.json

``--json`` runs the kernel micro-bench plus the balanced-tiling,
dense-vs-sparse-output SpGEMM, static-work-stealing, padded-vs-packed
wire and overlap-A/B experiments (R-MAT on a 4x4 grid, each in a
16-device subprocess) and writes ``BENCH_kernels.json`` at the repo
root: plan build time, per-multiply time, padded-flop waste, output
footprint, ``wire_bytes_padded`` vs ``wire_bytes_packed``,
per-schedule ``comm_exposed`` with overlap on vs off, and
predicted-vs-measured cost per algorithm — the perf-trajectory
baseline for future PRs.  The ``elastic`` section (``elastic_bench``,
9-device subprocess) records time-to-recover from a 5-of-9 device loss
against a cold rebuild, plus post-recovery per-multiply time.  It also
captures a ``serve_trace`` section (``serve_bench``: Poisson arrivals
through the sparse ``ServeEngine``) with p50/p99 TTFT/TPOT,
plans-per-second and the plan-cache hit rate.  Each
baseline refresh also re-fits the network constants of the cost model
(``tools/fit_machine.py``) from its own records and embeds the calibrated
preset plus per-record predicted-vs-measured drift under ``machine_fit``.
"""
from __future__ import annotations

import importlib.util
import json
import os
import subprocess
import sys

SCALING_DEVICE_COUNTS = (1, 4, 9)
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _subprocess_env(devices: int) -> dict:
    from repro.runtime.platform import subprocess_env
    env = subprocess_env(devices, overlap=True)
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _run_subprocess(module: str, devices: int, *extra_args: str,
                    quiet: bool = False) -> str:
    out = subprocess.run(
        [sys.executable, "-m", module, *extra_args],
        env=_subprocess_env(devices), capture_output=True, text=True,
        timeout=1200)
    if out.returncode != 0:
        tail = out.stderr.strip().splitlines()[-1] if out.stderr else "?"
        print(f"{module},ERROR,{tail}")
        return ""
    if not quiet:
        sys.stdout.write(out.stdout)
    return out.stdout


def _load_fit_machine():
    """Import tools/fit_machine.py (tools/ is not a package)."""
    path = os.path.join(REPO_ROOT, "tools", "fit_machine.py")
    spec = importlib.util.spec_from_file_location("fit_machine", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _machine_fit_section(payload: dict) -> dict:
    """Re-fit Machine.net_bw/hop_latency from this payload's records and
    report per-record predicted-vs-measured drift (ROADMAP "Machine
    fitting in CI").  Never raises: a failed fit is recorded, not fatal.
    """
    try:
        from repro.core import roofline
        from repro.core.api import _predicted_time
        fm = _load_fit_machine()
        records = fm.collect_records(payload)
        fitted, diag = fm.fit(records, roofline.TPU_V5E)
        eff, ov_diag = fm.fit_overlap_eff(payload)
        if eff is not None:
            import dataclasses
            fitted = dataclasses.replace(fitted, overlap_eff=eff)
        diag.update(ov_diag)
        drift = []
        for rec in records:
            pred_nominal = _predicted_time(rec["cm"], rec["alg"],
                                           roofline.TPU_V5E)
            pred_fit = _predicted_time(rec["cm"], rec["alg"], fitted)
            drift.append({
                "source": rec["source"],
                "measured_s": rec["measured"],
                "predicted_s_nominal": pred_nominal,
                "predicted_s_fit": pred_fit,
                "drift_nominal": rec["measured"] / pred_nominal
                if pred_nominal else float("nan"),
                "drift_fit": rec["measured"] / pred_fit
                if pred_fit else float("nan"),
            })
        return {**diag, "records": drift}
    except Exception as e:                     # noqa: BLE001 (diagnostic)
        return {"error": f"{type(e).__name__}: {e}"}


def _write_json(smoke: bool) -> None:
    from benchmarks import kernels_bench
    # "smoke" marks reduced-scale payloads so trajectory comparisons never
    # mistake a quick CI pass for the full baseline.
    payload = {"smoke": smoke,
               "kernels": kernels_bench.run_json(smoke=smoke)}
    # The balance, spgemm and steal experiments configure 16 fake devices
    # before importing jax, so each runs in its own process printing one
    # JSON object.
    extra = ("--smoke",) if smoke else ()
    all_ok = True
    # serve_bench drives the single-device serving engine; the rest are
    # 16-device grid experiments.
    for module, section, devices in (
            ("benchmarks.balance_bench", "balance_rmat_4x4", 16),
            ("benchmarks.spgemm_bench", "spgemm_rmat_4x4", 16),
            ("benchmarks.steal_bench", "steal_rmat_4x4", 16),
            ("benchmarks.wire_bench", "wire_rmat_4x4", 16),
            ("benchmarks.overlap_bench", "overlap_rmat_4x4", 16),
            ("benchmarks.analysis_bench", "analysis", 16),
            ("benchmarks.elastic_bench", "elastic", 9),
            ("benchmarks.serve_bench", "serve_trace", 1)):
        raw = _run_subprocess(module, devices, *extra, quiet=True)
        try:
            payload[section] = json.loads(raw) if raw else {
                "error": f"{module} failed"}
        except json.JSONDecodeError as e:
            payload[section] = {"error": f"unparseable output: {e}"}
            raw = ""   # degrade like the empty-output case (exit 1 below)
        all_ok = all_ok and bool(raw)
    # traced pass: per-algorithm predicted-vs-measured drift ratios from
    # the live obs registry + Chrome-trace schema check (in-process, g=1)
    try:
        payload["obs_drift"] = kernels_bench.obs_drift_section(smoke=smoke)
    except Exception as e:                     # noqa: BLE001 (diagnostic)
        payload["obs_drift"] = {"error": f"{type(e).__name__}: {e}"}
        all_ok = False
    # every baseline refresh re-fits the cost model's network constants
    # from its own records and records the drift
    payload["machine_fit"] = _machine_fit_section(payload)
    raw = all_ok       # all experiments must land in the baseline
    # Smoke and error payloads go to sibling files so neither a quick CI
    # pass nor a failed run can clobber the committed full-scale baseline.
    if smoke:
        name = "BENCH_kernels_smoke.json"
    elif not raw:
        name = "BENCH_kernels_error.json"
    else:
        name = "BENCH_kernels.json"
    path = os.path.join(REPO_ROOT, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    if not raw:
        # don't let CI record a baseline missing the headline experiment
        sys.exit(1)


def main() -> None:
    argv = sys.argv[1:]
    unknown = [a for a in argv if a.startswith("-")
               and a not in ("--smoke", "--json")]
    if unknown:
        sys.exit(f"unknown flags {unknown}; supported: --smoke --json")
    smoke = "--smoke" in argv
    as_json = "--json" in argv
    which = {a for a in argv if not a.startswith("-")}
    if which and (smoke or as_json):
        sys.exit(f"figure selectors {sorted(which)} cannot be combined "
                 "with --smoke/--json (fixed payloads)")
    if as_json:
        _write_json(smoke)
        return
    if smoke:
        # Quick self-contained pass for tools/run_tier1.sh: kernel oracle
        # rows + one scale-8 balance check + one scale-9 sparse-output
        # spgemm check + one scale-8 steal3d check, no multi-minute figure
        # sweeps.
        from benchmarks import kernels_bench
        kernels_bench.main(smoke=True)
        ok = True
        # analysis_bench asserts the static verifier finds nothing on
        # healthy plans and validate="fast" stays under the 5% cached
        # plan-build overhead budget;
        # wire_bench additionally *asserts* packed wire bytes <= padded and
        # packed results allclose to padded; overlap_bench asserts the
        # overlap A-B contract (double-buffered results allclose to bulk,
        # exposed comm no worse beyond measurement tolerance);
        # elastic_bench asserts the device-loss recovery contract
        # (recovered product allclose, time-to-recover within slack of a
        # cold rebuild, replan counters recorded); serve_bench asserts
        # the serving contract (dense-reference match, plan hits >
        # misses, zero dropped tokens) — all exit non-zero on violation
        for module, devices in (("benchmarks.balance_bench", 16),
                                ("benchmarks.spgemm_bench", 16),
                                ("benchmarks.steal_bench", 16),
                                ("benchmarks.wire_bench", 16),
                                ("benchmarks.overlap_bench", 16),
                                ("benchmarks.analysis_bench", 16),
                                ("benchmarks.elastic_bench", 9),
                                ("benchmarks.serve_bench", 1)):
            raw = _run_subprocess(module, devices, "--smoke", quiet=True)
            name = module.rsplit(".", 1)[1]
            print(f"smoke,{name},{'ok' if raw else 'FAILED'}")
            ok = ok and bool(raw)
        # traced obs pass: exports a Chrome trace, schema-validates it,
        # reports per-algorithm drift ratios, and asserts tracing
        # disabled leaves per-multiply timings within noise of untraced
        import tempfile
        from benchmarks import kernels_bench as kb
        with tempfile.NamedTemporaryFile(suffix=".json",
                                         delete=False) as tf:
            trace_path = tf.name
        try:
            sec = kb.obs_drift_section(smoke=True, trace_path=trace_path)
            obs_ok = sec["trace_valid"] and sec["disabled_overhead_ok"] \
                and bool(sec["drift"])
            ratios = ";".join(f"{a}={d['ratio']:.1f}"
                              for a, d in sorted(sec["drift"].items()))
            print(f"smoke,obs_trace,{'ok' if obs_ok else 'FAILED'};"
                  f"events={sec['trace_events']};{ratios}")
        finally:
            os.unlink(trace_path)
        ok = ok and obs_ok
        # exercise the machine-fit wiring against the committed baseline
        # (a full refresh re-fits from its own fresh records)
        baseline = os.path.join(REPO_ROOT, "BENCH_kernels.json")
        if os.path.exists(baseline):
            with open(baseline) as f:
                fit = _machine_fit_section(json.load(f))
            fit_ok = "error" not in fit
            detail = (f"net_bw={fit['net_bw']:.2e}" if fit_ok
                      else fit["error"])
            print(f"smoke,fit_machine,{'ok' if fit_ok else 'FAILED'};"
                  f"{detail}")
            ok = ok and fit_ok
        if not ok:
            sys.exit(1)
        return
    which = which or {"fig1", "fig2", "fig34", "fig5", "table2", "kernels"}
    # fall through: full figure sweep (optionally filtered by name)
    if "fig1" in which:
        from benchmarks import fig1_load_imbalance
        fig1_load_imbalance.main()
    if "fig2" in which:
        from benchmarks import fig2_roofline
        fig2_roofline.main()
    if "fig34" in which:
        for p in SCALING_DEVICE_COUNTS:
            _run_subprocess("benchmarks.fig34_spmm_scaling", p)
    if "fig5" in which:
        for p in SCALING_DEVICE_COUNTS:
            _run_subprocess("benchmarks.fig5_spgemm_scaling", p)
    if "table2" in which:
        from benchmarks import table2_breakdown
        table2_breakdown.main()
    if "kernels" in which:
        from benchmarks import kernels_bench
        kernels_bench.main()


if __name__ == "__main__":
    main()
