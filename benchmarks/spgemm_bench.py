"""Symbolic/numeric SpGEMM benchmark: dense- vs sparse-output graph squaring.

The acceptance experiment for the sparse-output subsystem: an R-MAT
adjacency matrix squared (A @ A) and cubed (A @ A @ A) on a 4x4 grid, once
through the legacy dense-output SpGEMM path (every device materializes a
dense C tile; the result is a dense array that would need re-tiling to
multiply again) and once through ``output="sparse"`` (symbolic phase
predicts C's block structure, the numeric phase scatter-accumulates into
packed blocks, and the result is a ``DistBSR`` that chains directly into
the next multiply).  For hypersparse products the sparse path wins on both
output footprint and per-multiply time; both are recorded, along with the
symbolic-phase cost and the chained-cube timings.

Runs in its own process (16 fake CPU devices must be configured before jax
imports).  Prints a single JSON object; ``benchmarks/run.py --json`` embeds
it in BENCH_kernels.json.

Usage:  python -m benchmarks.spgemm_bench [--scale 12] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEVICES = 16  # 4x4 grid


# obs.timed blocks on fn's result before reading the clock (async
# dispatch can't smear) — the check_api-sanctioned timing helper.
from repro.obs import timed as _timed  # noqa: E402


def main() -> int:
    p = argparse.ArgumentParser()
    # scale-13 / edgefactor-1 / bs=8 keeps the product block-hypersparse
    # (predicted C density ~0.09, well under the output="auto" threshold) —
    # the graph-squaring regime the sparse path is for, and large enough
    # that the dense path's x(tile columns) cost factor dominates its
    # footprint advantage on this host harness too.  R-MAT's a=0.6
    # clustering fills blocks fast: at edgefactor 4 even A @ A is ~70%
    # block-dense and a dense output is the right call (which the bench's
    # "auto" record then shows).
    p.add_argument("--scale", type=int, default=13)
    p.add_argument("--edgefactor", type=int, default=1)
    p.add_argument("--block-size", type=int, default=8)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--smoke", action="store_true",
                   help="scale-9 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 9, 2

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import numpy as np

    from repro.core import api
    from repro.core.api import DistBSR
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.roofline import TPU_V5E

    g = 4
    a_dense = rmat_matrix(scale=args.scale, edgefactor=args.edgefactor,
                          seed=0)
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size)
    m = a_dense.shape[0]

    out = {"rmat_scale": args.scale, "edgefactor": args.edgefactor, "g": g,
           "block_size": args.block_size,
           "a_capacity": a_h.capacity,
           "a_footprint_bytes": a_h.footprint_bytes(),
           "dense_bytes": int(m * m * 4),
           "output": {}}

    api.clear_plan_cache()
    t0 = time.perf_counter()
    sym = api.symbolic_spgemm(a_h.tiled, a_h.tiled)
    out["symbolic_phase_s"] = time.perf_counter() - t0
    out["predicted_c_density"] = sym.density()
    out["c_capacity"] = sym.capacity
    out["pair_capacity"] = sym.pair_capacity
    out["total_real_pairs"] = sym.total_real_pairs()

    results = {}
    plans = {}
    # Phase 1: build + warm both output modes (all tracing/compilation
    # happens here, before any steady-state timing).
    for output in ("dense", "sparse"):
        t0 = time.perf_counter()
        plan = api.plan_matmul(a_h, a_h, mesh=mesh, algorithm="ring_c",
                               impl="ref", output=output, cache=False)
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        c = plan(a_h, a_h)
        (c.tiled.blocks if output == "sparse" else c).block_until_ready()
        t_first = time.perf_counter() - t0
        if output == "sparse":
            results[output] = np.asarray(c.densify())
            out_bytes = c.footprint_bytes()
        else:
            results[output] = np.asarray(c)
            out_bytes = int(results[output].nbytes)
        out["output"][output] = {
            "plan_build_s": t_build,
            "first_call_s": t_first,
            "output_bytes": out_bytes,
            "predicted_s_v5e": plan.predicted_cost(TPU_V5E),
        }
        plans[output] = plan

    # Phase 2: steady-state per-multiply timing, modes interleaved within
    # each repeat; min over repeats (subprocess scheduling noise on 16
    # fake devices swamps a mean).
    times = {key: [] for key in plans}
    for _ in range(args.repeats):
        for output, plan in plans.items():
            if output == "sparse":
                fn = lambda: plan(a_h, a_h).tiled.blocks.block_until_ready()
            else:
                fn = lambda: plan(a_h, a_h).block_until_ready()
            times[output].append(_timed(fn))
    for output, ts in times.items():
        out["output"][output]["per_multiply_s"] = min(ts)

    out["allclose_dense_vs_sparse"] = bool(np.allclose(
        results["dense"], results["sparse"], atol=1e-2))
    d, s = out["output"]["dense"], out["output"]["sparse"]
    out["sparse_speedup"] = d["per_multiply_s"] / s["per_multiply_s"] \
        if s["per_multiply_s"] else float("nan")
    out["footprint_ratio"] = d["output_bytes"] / s["output_bytes"] \
        if s["output_bytes"] else float("nan")

    # Chained cube A @ A @ A: the sparse product handle is the next left
    # operand — no densify, no re-tile.
    c2 = plans["sparse"](a_h, a_h)
    plan3 = api.plan_matmul(c2, a_h, mesh=mesh, algorithm="ring_c",
                            impl="ref", output="sparse", cache=False)
    c3 = plan3(c2, a_h)
    c3.tiled.blocks.block_until_ready()
    t_chain = min(_timed(
        lambda: plan3(c2, a_h).tiled.blocks.block_until_ready())
        for _ in range(args.repeats))
    out["chain"] = {
        "c2_capacity": c2.capacity,
        "c3_capacity": c3.capacity,
        "c3_footprint_bytes": c3.footprint_bytes(),
        "per_multiply_s": t_chain,
        "predicted_c3_density": plan3.symbolic.density(),
    }

    # What the planner would do on its own.
    auto_plan = api.plan_matmul(a_h, a_h, mesh=mesh, algorithm="auto",
                                impl="ref", output="auto", cache=False)
    choice, scores = api.auto_select(a_h, a_h, machine=TPU_V5E,
                                     output="sparse")
    out["auto"] = {"output": auto_plan.output,
                   "algorithm": auto_plan.algorithm.name,
                   "sparse_choice": choice, "sparse_scores": scores}

    json.dump(out, sys.stdout, indent=1)
    print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
