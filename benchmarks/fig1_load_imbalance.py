"""Paper Fig. 1: end-to-end vs per-stage load imbalance, R-MAT scale 17.

Reproduces the claim that synchronizing between stages amplifies a ~1.2x
end-to-end flop imbalance to ~2.3x per-stage, on a 16x16 process grid —
squaring an R-MAT(a=0.6, b=c=d=0.4/3, edgefactor 8, scale 17) matrix.

Exact SpGEMM flop counting: flops of A[i,k] @ B[k,j] =
2 * sum over nonzeros (r, c) of A[i,k] of nnz(B row c restricted to column
tile j) — the full 3D (i, k, j) decomposition, then scheduled with the
paper's iteration offset k = (i + j + t) % g.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sps

from repro.core.bsr import rmat_edges
from repro.core.schedule import lpt_assign, makespan, stage_imbalance_3d


def rmat_csr(scale: int, edgefactor: int = 8, seed: int = 0,
             permute: bool = True) -> sps.csr_matrix:
    """R-MAT adjacency.  ``permute`` applies the Graph500-style random
    vertex relabeling (without it, hub vertices pile up at low indices and
    imbalance is far above the paper's figures)."""
    e = np.unique(rmat_edges(scale, edgefactor, seed=seed), axis=0)
    n = 1 << scale
    if permute:
        perm = np.random.default_rng(seed + 1).permutation(n)
        e = perm[e]
    m = sps.csr_matrix(
        (np.ones(len(e), np.float32), (e[:, 0], e[:, 1])), shape=(n, n))
    m.data[:] = 1.0
    return m


def tile_flops_3d(a: sps.csr_matrix, g: int) -> np.ndarray:
    """flops[i, k, j] of A[i,k] @ A[k,j] for C = A @ A."""
    n = a.shape[0]
    ts = n // g
    # P[c, j] = nnz of row c of B(=A) inside column tile j
    col_tile = np.minimum(a.indices // ts, g - 1)
    rows_idx = np.repeat(np.arange(n), np.diff(a.indptr))
    P = np.zeros((n, g))
    np.add.at(P, (rows_idx, col_tile), 1.0)
    # flops[i, k, :] += 2 * P[c, :] for each nonzero (r, c) of A
    flops = np.zeros((g, g, g))
    np.add.at(flops, (np.minimum(rows_idx // ts, g - 1), col_tile),
              2.0 * P[a.indices])
    return flops


def run(scale: int = 17, g: int = 16, seed: int = 0):
    rows = []
    for permute in (True, False):
        tag = "" if permute else ",unpermuted"
        a = rmat_csr(scale, 8, seed, permute=permute)
        fl = tile_flops_3d(a, g)
        per_stage, end_to_end = stage_imbalance_3d(fl)
        # idealized workstealing: any device may claim any (i,k,j) item
        assign = lpt_assign(fl.flatten(), g * g)
        mx, avg = makespan(fl.flatten(), assign, g * g)
        rows += [
            (f"fig1,end_to_end_imbalance{tag}", end_to_end),
            (f"fig1,per_stage_imbalance{tag}", per_stage),
            (f"fig1,amplification{tag}", per_stage / end_to_end),
            (f"fig1,lpt_steal_imbalance{tag}", mx / avg),
        ]
    return rows


def main():
    for name, val in run():
        print(f"{name},{val:.4f},max_over_avg")


if __name__ == "__main__":
    main()
