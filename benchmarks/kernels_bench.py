"""Local Pallas kernel micro-bench (interpret mode on CPU) + oracle check.

On real TPU hardware the same harness times the compiled kernels; here
interpret-mode wall time is only a correctness-path proxy, so we also report
the jnp-reference time (the number that matters on CPU) and the kernel's
modelled MXU utilization on v5e.

Also measures:

* the repeated-multiply story of the plan-based API: the same SpMM called
  10 times through one reused MatmulPlan (setup + trace amortized away) vs.
  10 fresh plans (the legacy per-call behaviour);
* the vectorized SpGEMM symbolic phase (``ops.build_pair_lists``): since
  PR 2 a numpy sort-merge join + lexsort, not a python dict-of-lists loop —
  the timing row below tracks it (~11x faster at 5k stored blocks than the
  loop it replaced, with the gap growing in the pair count);
* per-algorithm plan build / multiply / predicted-vs-measured cost, exported
  as JSON by ``benchmarks/run.py --json`` (the perf trajectory baseline).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np


def _time(fn, repeats: int = 3) -> float:
    # obs.timed blocks on fn's result before reading the clock (async
    # dispatch can't smear) — the check_api-sanctioned timing helper.
    from repro.obs import timed
    return timed(fn, repeats=repeats, warmup=1)


def _plan_reuse_rows(calls: int = 10):
    import jax.numpy as jnp

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import random_sparse

    a_d = random_sparse(256, 256, 0.1, seed=3)
    b = np.random.default_rng(3).standard_normal((256, 64)).astype(np.float32)
    a_h = DistBSR.from_dense(a_d, g=1, block_size=32)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)

    plan = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    plan(a_h, b_h).block_until_ready()      # compile once
    t0 = time.perf_counter()
    for _ in range(calls):
        plan(a_h, b_h).block_until_ready()
    t_reuse = (time.perf_counter() - t0) / calls

    t0 = time.perf_counter()
    for _ in range(calls):
        fresh = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                                cache=False)
        fresh(a_h, b_h).block_until_ready()
    t_fresh = (time.perf_counter() - t0) / calls

    return [
        (f"plan,spmm_reuse,{calls}calls", t_reuse * 1e6,
         f"us_per_call;traces={plan.traces}"),
        (f"plan,spmm_fresh,{calls}calls", t_fresh * 1e6,
         f"us_per_call;speedup={t_fresh / max(t_reuse, 1e-12):.1f}x"),
    ]


def _pair_list_rows(nnzb: int = 20_000, nbr: int = 512, nbc: int = 512):
    """Time the vectorized SpGEMM symbolic phase (host-side numpy).

    Hypersparse block grid (~40 matched B blocks per A block) — the output
    pair count, which dominates both the join and the lexsort, stays
    O(nnzb), like a real SpGEMM tile.  The replaced dict-of-lists python
    loop measured ~11x slower at 5k blocks on this harness (and scaled
    with the python-level pair count, not numpy throughput).
    """
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    a_rows = np.sort(rng.integers(0, nbr, nnzb)).astype(np.int32)
    a_cols = rng.integers(0, nbc, nnzb).astype(np.int32)
    b_rows = np.sort(rng.integers(0, nbr, nnzb)).astype(np.int32)
    b_cols = rng.integers(0, nbc, nnzb).astype(np.int32)

    t = _time(lambda: ops.build_pair_lists(
        a_rows, a_cols, nnzb, b_rows, b_cols, nnzb, nbr, nbc), repeats=3)
    n_pairs = ops.build_pair_lists(
        a_rows, a_cols, nnzb, b_rows, b_cols, nnzb, nbr, nbc)[4]
    return [(f"symbolic,build_pair_lists,{nnzb}blk", t * 1e3,
             f"ms;pairs={n_pairs};vectorized=numpy_join+lexsort")]


def _algorithm_rows(smoke: bool = False) -> Dict:
    """Per-algorithm plan build / multiply / predicted cost (g=1, ref impl).

    Returns {"algorithms": {name: {metric: float}}, "auto_selection":
    {"choice": name, "scores": {name: float}}} — timings and the
    auto-selection result are separate keys so trajectory consumers can
    diff the floats without special-casing.
    """
    import jax.numpy as jnp

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import random_sparse
    from repro.core.roofline import TPU_V5E

    m = 128 if smoke else 512
    a_d = random_sparse(m, m, 0.08, seed=5)
    b = np.random.default_rng(5).standard_normal((m, 64)).astype(np.float32)
    a_h = DistBSR.from_dense(a_d, g=1, block_size=32)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    out: Dict[str, Dict[str, float]] = {}
    for alg in api.algorithms():
        t0 = time.perf_counter()
        plan = api.plan_matmul(a_h, b_h, algorithm=alg, impl="ref",
                               cache=False)
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan(a_h, b_h).block_until_ready()
        t_first = time.perf_counter() - t0
        t_call = _time(lambda: plan(a_h, b_h).block_until_ready(),
                       repeats=2 if smoke else 5)
        out[alg] = {
            "plan_build_s": t_build,
            "first_call_s": t_first,          # trace + compile + run
            "per_multiply_s": t_call,
            "predicted_s_v5e": plan.predicted_cost(TPU_V5E),
        }
    choice, scores = api.auto_select(a_h, b_h, machine=TPU_V5E)
    return {"algorithms": out,
            "auto_selection": {"choice": choice, "scores": scores}}


def obs_drift_section(smoke: bool = False,
                      trace_path: str = None) -> Dict:
    """Traced bench pass: per-algorithm predicted-vs-measured drift.

    Runs the g=1 geometry twice around a traced window: (A) per-multiply
    with tracing disabled, (B) traced calls — each records a span and a
    drift pair through the normal ``MatmulPlan.__call__`` path — then
    (C) per-multiply with tracing disabled again.  The section reports
    the per-algorithm drift ratios (``obs.drift_report()``), the trace's
    schema validity, and asserts the disabled path stayed within noise
    of the never-traced one (A vs C) — tracing must cost nothing when
    off.  ``trace_path`` additionally writes the Chrome trace JSON.
    """
    import jax.numpy as jnp

    from repro import obs
    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import random_sparse

    m = 128 if smoke else 512
    a_d = random_sparse(m, m, 0.08, seed=5)
    b = np.random.default_rng(5).standard_normal((m, 64)).astype(np.float32)
    a_h = DistBSR.from_dense(a_d, g=1, block_size=32)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    algs = ("ring_c", "summa_bcast") if smoke else tuple(api.algorithms())
    reps = 3 if smoke else 5
    plans = {}
    for alg in algs:
        plans[alg] = api.plan_matmul(a_h, b_h, algorithm=alg, impl="ref",
                                     cache=False)
        plans[alg](a_h, b_h).block_until_ready()   # compile before timing
    before = {alg: _time(lambda p=p: p(a_h, b_h).block_until_ready(),
                         repeats=reps) for alg, p in plans.items()}
    obs.enable(clear=True)
    obs.reset_drift()
    with obs.span("bench.obs_drift", smoke=smoke):
        # one plan build under tracing so the exported trace carries
        # plan-build spans next to the per-multiply ones
        api.plan_matmul(a_h, b_h, algorithm=algs[0], impl="ref",
                        cache=False)
        for p in plans.values():
            for _ in range(reps):
                p(a_h, b_h)
    obs.disable()
    report = obs.drift_report()
    trace = obs.export_trace(trace_path)
    problems = obs.validate_trace(trace)
    after = {alg: _time(lambda p=p: p(a_h, b_h).block_until_ready(),
                        repeats=reps) for alg, p in plans.items()}
    # Disabled-mode overhead gate: total per-multiply time after the traced
    # window (tracing off again) must sit within noise of the never-traced
    # baseline.  Generous slack — fake-device CPU timings jitter — but a
    # forgotten always-on clock/block would blow well past it.
    t_before = sum(before.values())
    t_after = sum(after.values())
    overhead_ok = t_after <= t_before * 1.5 + 5e-3
    drift = {alg: report[key] for alg in algs
             if (key := f"{alg}/{plans[alg].wire}/auto") in report}
    return {
        "drift": drift,
        "trace_events": len(trace["traceEvents"]),
        "trace_valid": not problems,
        "trace_problems": problems[:10],
        "span_names": sorted({e["name"] for e in trace["traceEvents"]}),
        "per_multiply_untraced_s": before,
        "per_multiply_after_disable_s": after,
        "disabled_overhead_ok": bool(overhead_ok),
    }


def run(repeats: int = 3, smoke: bool = False):
    import jax.numpy as jnp

    from repro.core.bsr import BSR, random_sparse
    from repro.kernels import ops

    rows = []
    cases = ((256, 256, 256, 32, 0.1),) if smoke else \
        ((256, 256, 256, 32, 0.1), (512, 512, 128, 64, 0.05))
    for m, k, n, bs, dens in cases:
        a_d = random_sparse(m, k, dens, seed=0)
        b = np.random.default_rng(0).standard_normal((k, n)).astype(
            np.float32)
        a = BSR.from_dense(a_d, bs)
        b_j = jnp.asarray(b)

        ref = lambda: ops.bsr_spmm(a, b_j, impl="ref").block_until_ready()
        ref()
        t0 = time.perf_counter()
        for _ in range(repeats):
            ref()
        t_ref = (time.perf_counter() - t0) / repeats
        err = float(np.abs(np.asarray(ops.bsr_spmm(a, b_j, impl="interpret",
                                                   block_n=min(n, 128)))
                           - a_d @ b).max())
        flops = a.flops(n)
        rows.append((f"kernel,bsr_spmm,{m}x{k}x{n},bs={bs},d={dens}",
                     t_ref * 1e6,
                     f"us_ref;pallas_err={err:.1e};"
                     f"mxu_s_v5e={flops / 197e12:.2e}"))
    rows.extend(_pair_list_rows(*((2_000, 256, 256) if smoke
                                  else (20_000, 512, 512))))
    if not smoke:
        rows.extend(_plan_reuse_rows())
    return rows


def run_json(smoke: bool = False) -> Dict:
    """Structured results for BENCH_kernels.json (see benchmarks/run.py)."""
    return {
        "csv_rows": [list(r) for r in run(repeats=1 if smoke else 3,
                                          smoke=smoke)],
        "algorithms_g1": _algorithm_rows(smoke=smoke),
    }


def main(smoke: bool = False):
    for name, val, unit in run(smoke=smoke):
        print(f"{name},{val:.1f},{unit}")


if __name__ == "__main__":
    main()
