"""Local Pallas kernel micro-bench (interpret mode on CPU) + oracle check.

On real TPU hardware the same harness times the compiled kernels; here
interpret-mode wall time is only a correctness-path proxy, so we also report
the jnp-reference time (the number that matters on CPU) and the kernel's
modelled MXU utilization on v5e.

Also measures the repeated-multiply story of the plan-based API: the same
SpMM called 10 times through one reused MatmulPlan (setup + trace amortized
away) vs. 10 fresh plans (the legacy per-call behaviour, re-skewing and
re-tracing every call).
"""
from __future__ import annotations

import time

import numpy as np


def _plan_reuse_rows(calls: int = 10):
    import jax.numpy as jnp

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import random_sparse

    a_d = random_sparse(256, 256, 0.1, seed=3)
    b = np.random.default_rng(3).standard_normal((256, 64)).astype(np.float32)
    a_h = DistBSR.from_dense(a_d, g=1, block_size=32)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)

    plan = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref")
    plan(a_h, b_h).block_until_ready()      # compile once
    t0 = time.perf_counter()
    for _ in range(calls):
        plan(a_h, b_h).block_until_ready()
    t_reuse = (time.perf_counter() - t0) / calls

    t0 = time.perf_counter()
    for _ in range(calls):
        fresh = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                                cache=False)
        fresh(a_h, b_h).block_until_ready()
    t_fresh = (time.perf_counter() - t0) / calls

    return [
        (f"plan,spmm_reuse,{calls}calls", t_reuse * 1e6,
         f"us_per_call;traces={plan.traces}"),
        (f"plan,spmm_fresh,{calls}calls", t_fresh * 1e6,
         f"us_per_call;speedup={t_fresh / max(t_reuse, 1e-12):.1f}x"),
    ]


def run(repeats: int = 3):
    import jax.numpy as jnp

    from repro.core.bsr import BSR, random_sparse
    from repro.kernels import ops

    rows = []
    for m, k, n, bs, dens in ((256, 256, 256, 32, 0.1),
                              (512, 512, 128, 64, 0.05)):
        a_d = random_sparse(m, k, dens, seed=0)
        b = np.random.default_rng(0).standard_normal((k, n)).astype(
            np.float32)
        a = BSR.from_dense(a_d, bs)
        b_j = jnp.asarray(b)

        ref = lambda: ops.bsr_spmm(a, b_j, impl="ref").block_until_ready()
        ref()
        t0 = time.perf_counter()
        for _ in range(repeats):
            ref()
        t_ref = (time.perf_counter() - t0) / repeats
        err = float(np.abs(np.asarray(ops.bsr_spmm(a, b_j, impl="interpret",
                                                   block_n=min(n, 128)))
                           - a_d @ b).max())
        flops = a.flops(n)
        rows.append((f"kernel,bsr_spmm,{m}x{k}x{n},bs={bs},d={dens}",
                     t_ref * 1e6,
                     f"us_ref;pallas_err={err:.1e};"
                     f"mxu_s_v5e={flops / 197e12:.2e}"))
    rows.extend(_plan_reuse_rows())
    return rows


def main():
    for name, val, unit in run():
        print(f"{name},{val:.1f},{unit}")


if __name__ == "__main__":
    main()
