"""Packed wire format benchmark: padded vs packed shipments on skewed R-MAT.

The acceptance experiment for the packed communication layer
(``plan_matmul(wire="packed")``, ``repro.core.wire``): an unpermuted
skewed R-MAT SpMM on a 4x4 grid multiplied through ``ring_c``,
``summa_ag`` and ``steal3d`` with both wire layouts.  Padded plans ship
every sparse A tile at the uniform ``store_capacity`` stride (hub-tile
capacity + coverage blocks + rows/cols index arrays); packed plans ship
only real blocks at the bucketed wire capacity, with steal3d's
moved-tile rounds additionally sliced to their per-move real max and its
partial-C reductions row-packed.  Records ``wire_bytes_padded`` vs
``wire_bytes_packed`` per algorithm (the cost-model byte terms the
auto-scheduler ranks on), measured per-multiply times for both, and an
``auto_select`` comparison under both scorings; also one sparse-output
SpGEMM record (A @ A via ``ring_c``), where packing drops the coverage
blocks from both operands' block streams.

The run *asserts* the packed contract — packed bytes <= padded for every
algorithm and packed results allclose to padded — and exits non-zero on
violation, so the ``--smoke`` tier-1 path enforces it in CI.

Runs in its own process (16 fake CPU devices must be configured before
jax imports).  Prints a single JSON object; ``benchmarks/run.py --json``
embeds it in BENCH_kernels.json.

Usage:  python -m benchmarks.wire_bench [--scale 11] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEVICES = 16  # 4x4 grid

ALGORITHMS = ("ring_c", "summa_ag", "steal3d")


# obs.timed blocks on fn's result before reading the clock (async
# dispatch can't smear) — the check_api-sanctioned timing helper.
from repro.obs import timed as _timed  # noqa: E402


def main() -> int:
    p = argparse.ArgumentParser()
    # Same geometry as steal_bench/balance_bench: scale-11 R-MAT, 256
    # dense columns, bs=16 — skewed enough that the hub tile's capacity
    # (what the padded wire pays everywhere) is a large multiple of the
    # typical tile's real block count.
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--n-cols", type=int, default=256)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--smoke", action="store_true",
                   help="scale-8 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 8, 2
        args.block_size, args.n_cols = 8, 64

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import jax.numpy as jnp  # noqa: E402  (after flag setup)
    import numpy as np

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.roofline import TPU_V5E

    g = 4
    a_dense = rmat_matrix(scale=args.scale, edgefactor=8, seed=0)
    b = np.random.default_rng(0).standard_normal(
        (a_dense.shape[1], args.n_cols)).astype(np.float32)
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)

    out = {"rmat_scale": args.scale, "g": g,
           "block_size": args.block_size, "n_cols": args.n_cols,
           "a_capacity": a_h.capacity,
           "a_store_capacity": a_h.tiled.store_capacity,
           "a_wire_capacity": a_h.packed_operand().wire_capacity,
           "algorithms": {}}

    api.clear_plan_cache()
    failures = []
    plans = {}
    # Phase 1: build + warm every (algorithm, wire) plan.
    for alg in ALGORITHMS:
        for wire in ("padded", "packed"):
            t0 = time.perf_counter()
            plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                   impl="ref", wire=wire, cache=False)
            t_build = time.perf_counter() - t0
            c = plan(a_h, b_h)
            c.block_until_ready()
            plans[alg, wire] = (plan, np.asarray(c), t_build)

    # Phase 2: steady-state timing, variants interleaved per repeat.
    times = {key: [] for key in plans}
    for _ in range(args.repeats):
        for key, (plan, _c, _t) in plans.items():
            times[key].append(
                _timed(lambda p=plan: p(a_h, b_h).block_until_ready()))

    for alg in ALGORITHMS:
        plan_d, c_d, tb_d = plans[alg, "padded"]
        plan_p, c_p, tb_p = plans[alg, "packed"]
        cm_d, cm_p = plan_d.cost_model(), plan_p.cost_model()
        allclose = bool(np.allclose(c_p, c_d, atol=1e-4))
        rec = {
            "wire_bytes_padded": cm_d["total_net_bytes"],
            "wire_bytes_packed": cm_p["total_net_bytes"],
            "wire_reduction": cm_d["total_net_bytes"]
            / cm_p["total_net_bytes"]
            if cm_p["total_net_bytes"] else float("inf"),
            "plan_build_s_padded": tb_d,
            "plan_build_s_packed": tb_p,
            "per_multiply_s_padded": min(times[alg, "padded"]),
            "per_multiply_s_packed": min(times[alg, "packed"]),
            "predicted_s_v5e_padded": plan_d.predicted_cost(TPU_V5E),
            "predicted_s_v5e_packed": plan_p.predicted_cost(TPU_V5E),
            "allclose_packed_vs_padded": allclose,
        }
        if alg == "steal3d":
            rec["moved_tile_bytes_padded"] = \
                plan_d.steal.cost["moved_tile_bytes"]
            rec["moved_tile_bytes_packed"] = \
                plan_p.steal.cost["moved_tile_bytes"]
            rec["reduce_bytes_padded"] = plan_d.steal.cost["reduce_bytes"]
            rec["reduce_bytes_packed"] = plan_p.steal.cost["reduce_bytes"]
        out["algorithms"][alg] = rec
        if not allclose:
            failures.append(f"{alg}: packed result diverges from padded")
        if cm_p["total_net_bytes"] > cm_d["total_net_bytes"]:
            failures.append(
                f"{alg}: packed wire bytes {cm_p['total_net_bytes']:.0f} "
                f"> padded {cm_d['total_net_bytes']:.0f}")

    # sparse-output SpGEMM pair traffic: A @ A through the packed wire
    c_pack = api.plan_matmul(a_h, a_h, mesh=mesh, algorithm="ring_c",
                             impl="ref", output="sparse", cache=False)
    c_pad = api.plan_matmul(a_h, a_h, mesh=mesh, algorithm="ring_c",
                            impl="ref", output="sparse", wire="padded",
                            cache=False)
    r_pack, r_pad = c_pack(a_h, a_h), c_pad(a_h, a_h)
    sp_close = bool(np.allclose(np.asarray(r_pack.densify()),
                                np.asarray(r_pad.densify()), atol=1e-3))
    out["spgemm_sparse_output"] = {
        "auto_wire": c_pack.wire,
        "wire_bytes_padded": c_pad.cost_model()["total_net_bytes"],
        "wire_bytes_packed": c_pack.cost_model()["total_net_bytes"],
        "allclose_packed_vs_padded": sp_close,
    }
    if c_pack.wire != "packed" or not sp_close:
        failures.append("sparse-output packed wire check failed")
    if out["spgemm_sparse_output"]["wire_bytes_packed"] > \
            out["spgemm_sparse_output"]["wire_bytes_padded"]:
        failures.append("sparse-output packed bytes exceed padded")

    # what the auto-scheduler does under each scoring
    choice_pad, _ = api.auto_select(a_h, b_h, wire="padded")
    choice_pack, scores_pack = api.auto_select(a_h, b_h, wire="packed")
    out["auto"] = {"choice_v5e_padded": choice_pad,
                   "choice_v5e_packed": choice_pack,
                   "scores_v5e_packed": scores_pack}

    out["packed_never_wider"] = not any("wire bytes" in f
                                        for f in failures)
    json.dump(out, sys.stdout, indent=1)
    print()
    if failures:
        print("wire_bench FAILED: " + "; ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
