"""Paper Fig. 2: inter-node roofline for SpMM and SpGEMM.

SpMM: isolates-subgraph2-like matrix (m = k = 17.5M, nnz = 5.2B) on 24 GPUs,
sweeping the dense-matrix width N.  SpGEMM: isolates-subgraph4-ish at
different scales with measured-average compression factors.  Reported for
the paper's Summit constants AND re-parameterized for TPU v5e.
"""
from __future__ import annotations

from repro.core.roofline import (SUMMIT_V100, TPU_V5E, spgemm_model,
                                 spmm_model)

ISOLATES_M = 17_500_000
ISOLATES_NNZ = 5.2e9


def run():
    rows = []
    d = ISOLATES_NNZ / (ISOLATES_M ** 2)
    for mach in (SUMMIT_V100, TPU_V5E):
        for n in (32, 128, 512, 1024):
            m = spmm_model(ISOLATES_M, ISOLATES_M, n, 24, d, mach)
            rows.append((f"fig2,spmm,{mach.name},n={n}",
                         m["perf"] / 1e9,
                         f"ai_net={m['ai_net']:.2f};"
                         f"{'net' if m['net_bound'] else 'local'}-bound"))
        # SpGEMM at different scales; cf ~ 4 flops/nnz(C) is representative
        # of the isolates matrices (paper measures experimentally)
        for p in (24, 96, 384):
            flops = 2.0 * 4.0 * ISOLATES_NNZ / p   # per-GPU share
            m = spgemm_model(flops, 4.0, ISOLATES_M, ISOLATES_M, ISOLATES_M,
                             p, d, mach)
            rows.append((f"fig2,spgemm,{mach.name},p={p}",
                         m["perf"] / 1e9,
                         f"ai_net={m['ai_net']:.2f};"
                         f"{'net' if m['net_bound'] else 'local'}-bound"))
    return rows


def main():
    for name, val, extra in run():
        print(f"{name},{val:.2f},GF/s/chip;{extra}")


if __name__ == "__main__":
    main()
