"""Elastic recovery benchmark: device loss on a 3x3 grid, shrink to 2x2.

Measures the headline number of the elastic replanning runtime: **time to
recover** — from "5 of 9 devices are gone" to the first correct product
on the surviving 2x2 grid (device-side reshard of the operands, a rebuilt
locality-aware steal3d assignment validated by the static checker, plan
build, first multiply) — against the **cold rebuild** alternative (host
round-trip: densify, re-tile from scratch at g=2, plan, first multiply).
Also reports the post-recovery per-multiply time next to the cold-built
plan's, since a recovery that leaves a slow plan behind would be a
pyrrhic one.

Asserts (exit non-zero on violation): the recovered product matches the
dense reference, recovery touches no host round-trip yet lands within a
generous slack of the cold rebuild, and the ``replan.*`` counters are in
the obs registry.

Runs in its own process (9 fake CPU devices must be configured before jax
imports).  Prints a single JSON object; ``benchmarks/run.py --json``
embeds it in BENCH_kernels.json under ``elastic``.

Usage:  python -m benchmarks.elastic_bench [--scale 9] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys

DEVICES = 9  # 3x3 grid before the loss

# obs.timed blocks on fn's result before reading the clock (async
# dispatch can't smear) — the check_api-sanctioned timing helper.
from repro.obs import timed as _timed  # noqa: E402


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=int, default=9)
    p.add_argument("--n-cols", type=int, default=64)
    p.add_argument("--block-size", type=int, default=8)
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--smoke", action="store_true",
                   help="scale-6 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 6, 2
        args.n_cols = 48

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import jax.numpy as jnp  # noqa: E402  (after flag setup)
    import numpy as np

    from repro import obs
    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.runtime.faultinject import DeviceLoss
    from repro.runtime.replan import ElasticReplanner

    obs.reset_all()
    obs.enable(clear=True)
    a_dense = rmat_matrix(scale=args.scale, edgefactor=8, seed=0)
    b = np.random.default_rng(0).standard_normal(
        (a_dense.shape[1], args.n_cols)).astype(np.float32)
    want = a_dense @ b

    # pre-loss steady state: steal3d on the full 3x3 grid
    mesh3 = make_grid_mesh(3)
    a3 = DistBSR.from_dense(a_dense, g=3, block_size=args.block_size)
    b3 = DistDense.for_rhs(jnp.asarray(b), a3)
    p3 = api.plan_matmul(a3, b3, algorithm="steal3d", mesh=mesh3,
                         validate="fast")
    pre_multiply_s = _timed(lambda: p3(a3, b3), repeats=args.repeats,
                            warmup=1)

    loss = DeviceLoss(DEVICES, 5, seed=0)
    mesh2 = make_grid_mesh(2)

    # cold rebuild first (any process-wide compile caching then favors
    # neither side — the recovery path runs second and is the one we
    # bound): host round-trip + re-tile + fresh plan + first multiply
    def cold():
        a2 = DistBSR.from_dense(a_dense, g=2, block_size=args.block_size)
        b2 = DistDense.for_rhs(jnp.asarray(b), a2)
        plan = api.plan_matmul(a2, b2, algorithm="steal3d", mesh=mesh2,
                               validate="fast", cache=False)
        return plan, a2, b2

    cold_plan, a2c, b2c = [None] * 3

    def cold_to_first_result():
        nonlocal cold_plan, a2c, b2c
        cold_plan, a2c, b2c = cold()
        return cold_plan(a2c, b2c)

    cold_rebuild_s = _timed(cold_to_first_result)
    cold_multiply_s = _timed(lambda: cold_plan(a2c, b2c),
                             repeats=args.repeats, warmup=1)

    # elastic recovery: device-side reshard + rebuilt steal3d assignment
    # (validated against the survivor set) + plan + first multiply
    rp = ElasticReplanner()
    rec = None

    def recover_to_first_result():
        nonlocal rec
        rec = rp.recover_from_loss(a3, b3, loss.survivors(), mesh=mesh2)
        return rec.plan(rec.a, rec.b)

    time_to_recover_s = _timed(recover_to_first_result)
    post_multiply_s = _timed(lambda: rec.plan(rec.a, rec.b),
                             repeats=args.repeats, warmup=1)

    got = np.asarray(rec.plan(rec.a, rec.b))
    err = float(np.max(np.abs(got[:want.shape[0], :want.shape[1]] - want)))
    snap = obs.registry().snapshot()
    replan_metrics = {k: v for k, v in snap.items()
                      if k.startswith("replan.")}

    out = {
        "smoke": bool(args.smoke),
        "rmat_scale": args.scale,
        "n_cols": args.n_cols,
        "block_size": args.block_size,
        "g_before": 3,
        "g_after": rec.g,
        "survivors": list(loss.survivors()),
        "pre_multiply_s": pre_multiply_s,
        "time_to_recover_s": time_to_recover_s,
        "cold_rebuild_s": cold_rebuild_s,
        "recover_over_cold": time_to_recover_s / cold_rebuild_s,
        "post_multiply_s": post_multiply_s,
        "cold_multiply_s": cold_multiply_s,
        "plans_evicted": rec.evicted,
        "max_err_recovered": err,
        "replan_metrics": replan_metrics,
    }
    print(json.dumps(out, indent=1))

    ok = True
    if err > 1e-3:
        print(f"FAIL: recovered product off by {err:.3e}", file=sys.stderr)
        ok = False
    if not (0.0 < time_to_recover_s < float("inf")):
        print(f"FAIL: bogus time_to_recover_s={time_to_recover_s}",
              file=sys.stderr)
        ok = False
    # both paths build one validated g=2 steal3d plan; recovery replaces
    # the host round-trip with a device-side reshard, so it must land in
    # the same ballpark (wide slack: CI wall clocks are noisy)
    if time_to_recover_s > 10.0 * cold_rebuild_s:
        print(f"FAIL: recovery {time_to_recover_s:.3f}s vs cold rebuild "
              f"{cold_rebuild_s:.3f}s exceeds 10x slack", file=sys.stderr)
        ok = False
    if "replan.recoveries" not in replan_metrics:
        print("FAIL: replan.recoveries missing from obs registry",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
