"""Overlap A-B benchmark: double-buffered vs bulk-synchronous schedules.

The acceptance experiment for the asynchronous-overlap subsystem: an
unpermuted skewed R-MAT SpMM on a 4x4 grid, every registered schedule
built twice through ``plan_matmul`` — once with ``overlap="on"``
(split-step double-buffered bodies: step i+1's ``ppermute`` issued before
step i's accumulate) and once with ``overlap="off"`` (the legacy bulk
scan, each transfer fully exposed).  Per schedule it records both
per-multiply times and ``comm_exposed = max(0, measured - t_comp)`` —
the communication left visible above the host roofline's compute floor,
the quantity the overlap term of the cost model
(``exposed = max(0, t_comm - overlap_eff * t_comp)``) predicts and
``tools/fit_machine.py`` fits ``Machine.overlap_eff`` from.

The run *asserts* the overlap contract — double-buffered results allclose
to bulk for every schedule, and exposed comm no worse than bulk beyond
measurement tolerance — and exits non-zero on violation, so the
``--smoke`` tier-1 path enforces it in CI.  (On the fake-device CPU
harness XLA runs collectives synchronously, so "no worse" plus the byte
parity recorded here is the honest claim; the GPU async-collective flags
that realize the hiding are planted by ``repro.runtime.platform``.)

Runs in its own process (16 fake CPU devices must be configured before
jax imports).  Prints a single JSON object; ``benchmarks/run.py --json``
embeds it in BENCH_kernels.json.

Usage:  python -m benchmarks.overlap_bench [--scale 11] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEVICES = 16  # 4x4 grid

# measurement tolerance for the "overlap never worse" assert: min-of-repeats
# on 16 fake CPU devices still jitters by scheduling noise
SLACK_FACTOR = 1.25
SLACK_ABS_S = 5e-3

# Schedules whose overlap="on" form adds a kernel dispatch (steal3d's
# own/stolen segment split) rather than reordering a scan: on this
# synchronous-collective harness the extra dispatch is pure overhead
# (which is why plan_matmul keeps their "auto" at the bulk body), so
# they are A-B *recorded* here but exempt from the regression assert.
SEGMENT_SPLIT_ALGS = frozenset({"steal3d"})


# obs.timed blocks on fn's result before reading the clock (async
# dispatch can't smear) — the check_api-sanctioned timing helper.
from repro.obs import timed as _timed  # noqa: E402


def main() -> int:
    p = argparse.ArgumentParser()
    # Same geometry as steal_bench/wire_bench: scale-11 R-MAT, 256 dense
    # columns, bs=16 — per-step einsums well above the shard_map dispatch
    # floor, so body-structure differences (not fixed overheads) dominate.
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--n-cols", type=int, default=256)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--smoke", action="store_true",
                   help="scale-8 quick pass")
    args = p.parse_args()
    if args.smoke:
        args.scale, args.repeats = 8, 2
        args.block_size, args.n_cols = 8, 64

    from repro.runtime.platform import set_host_device_count
    set_host_device_count(DEVICES, overlap=True)
    import jax.numpy as jnp  # noqa: E402  (after flag setup)
    import numpy as np

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import rmat_matrix
    from repro.core.dist import make_grid_mesh
    from repro.core.roofline import HOST_CPU, TPU_V5E

    g = 4
    a_dense = rmat_matrix(scale=args.scale, edgefactor=8, seed=0)
    b = np.random.default_rng(0).standard_normal(
        (a_dense.shape[1], args.n_cols)).astype(np.float32)
    mesh = make_grid_mesh(g)
    a_h = DistBSR.from_dense(a_dense, g=g, block_size=args.block_size)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)

    out = {"rmat_scale": args.scale, "g": g,
           "block_size": args.block_size, "n_cols": args.n_cols,
           "a_capacity": a_h.capacity, "algorithms": {}}

    api.clear_plan_cache()
    failures = []
    plans = {}
    # Phase 1: build + warm every (algorithm, overlap) plan.
    for alg in api.algorithms():
        for overlap in ("on", "off"):
            t0 = time.perf_counter()
            plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                   impl="ref", overlap=overlap, cache=False)
            t_build = time.perf_counter() - t0
            c = plan(a_h, b_h)
            c.block_until_ready()
            plans[alg, overlap] = (plan, np.asarray(c), t_build)

    # Phase 2: steady-state timing, variants interleaved per repeat;
    # min over repeats (scheduling noise on 16 fake devices swamps a mean).
    times = {key: [] for key in plans}
    for _ in range(args.repeats):
        for key, (plan, _c, _t) in plans.items():
            times[key].append(
                _timed(lambda p=plan: p(a_h, b_h).block_until_ready()))

    for alg in api.algorithms():
        plan_on, c_on, tb_on = plans[alg, "on"]
        plan_off, c_off, tb_off = plans[alg, "off"]
        # compute floor from the harness machine's roofline: everything
        # measured above it is exposed communication + dispatch
        t_comp = plan_on.predicted_perf(HOST_CPU)["t_comp"]
        t_on = min(times[alg, "on"])
        t_off = min(times[alg, "off"])
        exposed_on = max(0.0, t_on - t_comp)
        exposed_off = max(0.0, t_off - t_comp)
        allclose = bool(np.allclose(c_on, c_off, atol=1e-4))
        out["algorithms"][alg] = {
            "plan_build_s_on": tb_on,
            "plan_build_s_off": tb_off,
            "per_multiply_s_on": t_on,
            "per_multiply_s_off": t_off,
            "t_comp_host_s": t_comp,
            "comm_exposed_on_s": exposed_on,
            "comm_exposed_off_s": exposed_off,
            "predicted_s_v5e_on": plan_on.predicted_cost(TPU_V5E),
            "predicted_s_v5e_off": plan_off.predicted_cost(TPU_V5E),
            "overlap_eff_scored_on":
                plan_on.predicted_perf(TPU_V5E)["overlap_eff"],
            "allclose_on_vs_off": allclose,
        }
        if not allclose:
            failures.append(f"{alg}: overlap=on result diverges from off")
        if alg not in SEGMENT_SPLIT_ALGS and \
                exposed_on > exposed_off * SLACK_FACTOR + SLACK_ABS_S:
            failures.append(
                f"{alg}: exposed comm regressed with overlap on "
                f"({exposed_on:.4f}s vs {exposed_off:.4f}s off)")

    # what the auto-scheduler does with and without overlap credit
    choice_auto, scores_auto = api.auto_select(a_h, b_h, machine=TPU_V5E,
                                               overlap="auto")
    choice_off, scores_off = api.auto_select(a_h, b_h, machine=TPU_V5E,
                                             overlap="off")
    out["auto"] = {"choice_v5e_overlap_auto": choice_auto,
                   "choice_v5e_overlap_off": choice_off,
                   "scores_v5e_overlap_auto": scores_auto,
                   "scores_v5e_overlap_off": scores_off}

    out["overlap_never_worse"] = not any("regressed" in f for f in failures)
    json.dump(out, sys.stdout, indent=1)
    print()
    if failures:
        print("overlap_bench FAILED: " + "; ".join(failures),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
