#!/usr/bin/env python
"""Fit Machine.net_bw / hop_latency from measured benchmark trajectories.

``benchmarks/run.py --json`` records predicted-vs-measured per-multiply
times for every algorithm (g=1 micro-bench + the 4x4 R-MAT balance and
padded-vs-packed wire experiments) in ``BENCH_kernels.json``; packed-wire
records fit against the *packed* byte terms — the bytes those plans
actually ship.  The auto-scheduler's alpha-beta
model (``api._predicted_time``) is linear in the two network unknowns:

    t_comm = total_bytes / (net_bw * duplex) + n_msgs * hop_latency

so, after subtracting the roofline compute term, a least-squares fit over
the records recovers ``1/net_bw`` and ``hop_latency`` — the ROADMAP's
"auto-scheduling calibration": fit the machine the fleet actually is,
instead of trusting nominal v5e constants.

The overlap A/B section (``overlap_rmat_4x4``) additionally calibrates
``Machine.overlap_eff`` — the fraction of compute time the double-buffered
bodies actually hide communication under.  Each schedule's record pairs
the split-step (on) and bulk (off) per-multiply times with the host
roofline's compute floor, so the realized hiding is
``clip((t_off - t_on) / t_comp, 0, 1)`` per schedule and the fitted value
is the median over the non-wire-amortized schedules.  The calibrated
preset carries it into ``auto_select``'s exposed-comm term
(``max(0, t_comm - overlap_eff * t_comp)``).

Usage:
    python tools/fit_machine.py [BENCH_kernels.json]
    python tools/fit_machine.py --write MACHINE_calibrated.json

``--write`` saves the calibrated preset via ``roofline.save_machine``;
load it with ``roofline.load_machine(path)`` and pass it to
``plan_matmul(machine=...)`` / ``auto_select(machine=...)``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _comm_row(cm: Dict[str, float], alg) -> Tuple[float, float]:
    """Design-matrix row (effective bytes, message count) for one record."""
    n_msgs = alg.msgs_per_step if alg.msgs_per_step is not None \
        else len(alg.wire)
    msgs = n_msgs * (1.0 if alg.wire_amortized else cm["steps"])
    return cm["total_net_bytes"] / alg.duplex, msgs


def fit(records: List[Dict], base) -> Tuple[object, Dict]:
    """Least-squares fit of (net_bw, hop_latency) from benchmark records.

    Each record: ``{"cm": cost-model dict, "alg": Algorithm,
    "measured": seconds}``.  BSP schedules pay compute + comm, so their
    comm time is ``measured - t_comp`` exactly; RDMA rings pay
    max(comp, comm), so they inform the fit only when comm-dominated —
    rows whose residual target comes out non-positive are dropped.
    """
    from repro.core import roofline as _roofline

    rows, targets, used = [], [], []
    for rec in records:
        cm, alg = rec["cm"], rec["alg"]
        t_comp = cm["total_flops"] / _roofline.local_peak(
            cm["ai_local"], base)
        if alg.style == "bsp":
            y = rec["measured"] - t_comp
        else:
            # rings pay max(comp, comm): the measured time equals comm only
            # when comm dominates.  A compute-bound ring record would be
            # attributed entirely to the network and wreck the fit, so keep
            # rings only when measured clearly exceeds the compute floor.
            if rec["measured"] <= 2.0 * t_comp:
                continue
            y = rec["measured"]
        if y <= 0:
            continue
        rows.append(_comm_row(cm, alg))
        targets.append(y)
        used.append(rec)
    if len(rows) < 2:
        raise ValueError(
            f"need >= 2 usable records to fit 2 parameters, got {len(rows)}")
    a = np.asarray(rows, dtype=np.float64)
    y = np.asarray(targets, dtype=np.float64)
    # normalize columns so bytes (~1e6) and msgs (~1e1) are comparable
    scale = a.max(axis=0)
    scale[scale == 0] = 1.0
    x, *_ = np.linalg.lstsq(a / scale, y, rcond=None)
    x = x / scale
    inv_bw = max(float(x[0]), 1e-18)     # clip to physical (positive) values
    alpha = max(float(x[1]), 0.0)
    fitted = dataclasses.replace(base, name=base.name + "-fit",
                                 net_bw=1.0 / inv_bw, hop_latency=alpha)
    resid = a @ np.array([inv_bw, alpha]) - y
    diag = {
        "n_records": len(records),
        "n_used": len(rows),
        "rms_residual_s": float(np.sqrt((resid ** 2).mean())),
        "net_bw": fitted.net_bw,
        "hop_latency": fitted.hop_latency,
    }
    return fitted, diag


def _g1_records(payload: Dict) -> List[Dict]:
    """Rebuild the kernels_bench g=1 geometry; attach measured timings."""
    import jax.numpy as jnp

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import random_sparse

    section = payload.get("kernels", {}).get("algorithms_g1", {})
    algos = section.get("algorithms", {})
    if not algos:
        return []
    m = 128 if payload.get("smoke") else 512     # kernels_bench geometry
    a_d = random_sparse(m, m, 0.08, seed=5)
    b = np.zeros((m, 64), dtype=np.float32)
    a_h = DistBSR.from_dense(a_d, g=1, block_size=32)  # default (bucketed)
    b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
    geom = api._geometry(a_h, b_h, impl=None, axis_row="row",
                         axis_col="col")
    out = []
    for name, metrics in algos.items():
        if name not in api.REGISTRY:
            continue
        alg = api.REGISTRY.get(name)
        if alg.cost_fn is not None:
            # structure-dependent cost (steal3d) can't be reconstructed
            # from the recorded geometry alone; those records are skipped
            continue
        cm = api._cost_model(alg, geom, a_h.abstract_key(),
                             b_h.abstract_key())
        out.append({"cm": cm, "alg": alg, "source": f"g1/{name}",
                    "measured": metrics["per_multiply_s"],
                    "predicted": metrics.get("predicted_s_v5e")})
    return out


def _balance_records(payload: Dict) -> List[Dict]:
    """Reconstruct the 4x4 balance-bench cost models from recorded meta
    (capacity, block size, scale) — no R-MAT rebuild needed."""
    import jax.numpy as jnp

    from repro.core import api

    section = payload.get("balance_rmat_4x4", {})
    if "balance" not in section:
        return []
    g = section["g"]
    n = 1 << section["rmat_scale"]
    bs = section["block_size"]
    n_cols = section["n_cols"]
    out = []
    for mode, entry in section["balance"].items():
        cap = entry["capacity"]
        a_key = ("bsr", (n, n), (g, g), bs, cap, "float32")
        b_key = ("dense", (n, n_cols), g, "float32")
        geom = api._Geom(g=g, tm=n // g, tn=n_cols // g,
                         a_nbr=(n // g) // bs, b_nbr=0, b_nbc=0, impl=None,
                         axr="row", axc="col", out_dtype=jnp.float32)
        for name, metrics in entry["algorithms"].items():
            if name not in api.REGISTRY or "per_multiply_s" not in metrics:
                continue
            alg = api.REGISTRY.get(name)
            if alg.cost_fn is not None:
                continue                 # see _g1_records

            cm = api._cost_model(alg, geom, a_key, b_key)
            out.append({"cm": cm, "alg": alg,
                        "source": f"balance/{mode}/{name}",
                        "measured": metrics["per_multiply_s"],
                        "predicted": metrics.get("predicted_s_v5e")})
    return out


def _wire_records(payload: Dict) -> List[Dict]:
    """Reconstruct the 4x4 wire-bench cost models from recorded meta.

    Padded records use the stored-stride byte terms; packed records use
    the *packed* terms (``wire_caps`` — blocks-only at the recorded wire
    capacity), so the fit sees the bytes each plan actually ships.
    """
    import jax.numpy as jnp

    from repro.core import api

    section = payload.get("wire_rmat_4x4", {})
    algos = section.get("algorithms")
    if not algos:
        return []
    g = section["g"]
    n = 1 << section["rmat_scale"]
    bs = section["block_size"]
    n_cols = section["n_cols"]
    cap = section["a_capacity"]
    wc = section["a_wire_capacity"]
    a_key = ("bsr", (n, n), (g, g), bs, cap, "float32")
    b_key = ("dense", (n, n_cols), g, "float32")
    geom = api._Geom(g=g, tm=n // g, tn=n_cols // g,
                     a_nbr=(n // g) // bs, b_nbr=0, b_nbc=0, impl=None,
                     axr="row", axc="col", out_dtype=jnp.float32)
    out = []
    for name, metrics in algos.items():
        if name not in api.REGISTRY:
            continue
        alg = api.REGISTRY.get(name)
        if alg.cost_fn is not None:
            continue                     # see _g1_records (steal3d)
        for wire, caps in (("padded", None), ("packed", {"a": wc})):
            measured = metrics.get(f"per_multiply_s_{wire}")
            if measured is None:
                continue
            cm = api._cost_model(alg, geom, a_key, b_key, wire_caps=caps)
            out.append({"cm": cm, "alg": alg,
                        "source": f"wire/{wire}/{name}",
                        "measured": measured,
                        "predicted": metrics.get(
                            f"predicted_s_v5e_{wire}")})
    return out


def collect_records(payload: Dict) -> List[Dict]:
    return _g1_records(payload) + _balance_records(payload) \
        + _wire_records(payload)


def _records_from_drift(raw: List[Dict]) -> List[Dict]:
    """Convert obs drift records ({"algorithm", "cm", "measured_s"}) to
    fit records.  Drift records carry the executed plan's cost-model dict
    verbatim, so no geometry reconstruction is needed; records for
    unregistered algorithms or with structure-dependent cost functions
    (steal3d — see _g1_records) are skipped."""
    from repro.core import api

    out = []
    for rec in raw:
        name = rec.get("algorithm")
        cm = rec.get("cm")
        if cm is None or name not in api.REGISTRY:
            continue
        alg = api.REGISTRY.get(name)
        if alg.cost_fn is not None:
            continue
        out.append({"cm": cm, "alg": alg,
                    "source": f"drift/{name}/{rec.get('wire', '?')}",
                    "measured": rec["measured_s"],
                    "predicted": rec.get("predicted_s")})
    return out


def fit_from_registry(base=None) -> Tuple[object, Dict]:
    """Re-fit (net_bw, hop_latency) from the live obs drift series.

    The observed-step-time loop: any process that executed plans under
    ``obs.enable()`` has per-multiply measurements (with their cost-model
    dicts) sitting in ``obs.drift_records()`` — this fits a Machine from
    them directly, no bench JSON round-trip.  Raises ValueError with
    fewer than two usable records, like :func:`fit`.
    """
    from repro import obs
    from repro.core import roofline

    base = base or roofline.TPU_V5E
    return fit(_records_from_drift(obs.drift_records()), base)


def fit_overlap_eff(payload: Dict) -> Tuple[Optional[float], Dict]:
    """Fit ``Machine.overlap_eff`` from the overlap A/B section.

    ``overlap_rmat_4x4`` records min-of-repeats per-multiply times with
    the double-buffered (on) and bulk (off) bodies plus the harness
    roofline's compute floor ``t_comp``; the hiding a schedule realized
    is ``clip((t_off - t_on) / t_comp, 0, 1)``.  Wire-amortized
    schedules are skipped (their bodies have no overlap variant), as are
    segment-split ones (steal3d: its A/B delta measures the opt-in
    second dispatch, not scan-step hiding).
    Returns ``(median_eff | None, diagnostics)``.
    """
    from repro.core import api

    algos = payload.get("overlap_rmat_4x4", {}).get("algorithms", {})
    effs: Dict[str, float] = {}
    for name, rec in algos.items():
        if name not in api.REGISTRY:
            continue
        alg = api.REGISTRY.get(name)
        if alg.wire_amortized or alg.static_planner is not None:
            continue
        t_on = rec.get("per_multiply_s_on")
        t_off = rec.get("per_multiply_s_off")
        t_comp = rec.get("t_comp_host_s")
        if not t_comp or t_on is None or t_off is None:
            continue
        effs[name] = min(max((t_off - t_on) / t_comp, 0.0), 1.0)
    if not effs:
        return None, {"overlap_records": 0}
    eff = float(np.median(list(effs.values())))
    return eff, {"overlap_records": len(effs), "overlap_eff": eff,
                 "overlap_eff_per_alg": effs}


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("bench_json", nargs="?",
                   default=os.path.join(REPO_ROOT, "BENCH_kernels.json"))
    p.add_argument("--machine", default="tpu-v5e",
                   choices=["tpu-v5e", "summit-v100", "dgx2-v100"],
                   help="base preset supplying compute-side constants")
    p.add_argument("--write", nargs="?", const="MACHINE_calibrated.json",
                   default=None, metavar="PATH",
                   help="save the calibrated preset as JSON")
    p.add_argument("--drift", default=None, metavar="PATH",
                   help="fit from an obs.export_drift JSON (live-registry "
                        "records) instead of bench sections")
    args = p.parse_args(argv)

    from repro.core import roofline
    base = {"tpu-v5e": roofline.TPU_V5E, "summit-v100": roofline.SUMMIT_V100,
            "dgx2-v100": roofline.DGX2_V100}[args.machine]
    if args.drift:
        with open(args.drift) as f:
            records = _records_from_drift(json.load(f).get("records", []))
        payload = {}
        source = args.drift
    else:
        with open(args.bench_json) as f:
            payload = json.load(f)
        records = collect_records(payload)
        source = args.bench_json
    if not records:
        print(f"no predicted-vs-measured records in {source}")
        return 1
    fitted, diag = fit(records, base)
    eff, ov_diag = fit_overlap_eff(payload)
    if eff is not None:
        fitted = dataclasses.replace(fitted, overlap_eff=eff)
    diag.update(ov_diag)
    print(f"fit over {diag['n_used']}/{diag['n_records']} records "
          f"(rms residual {diag['rms_residual_s']:.2e} s):")
    print(f"  net_bw      {base.net_bw:.3e} -> {fitted.net_bw:.3e} B/s")
    print(f"  hop_latency {base.hop_latency:.3e} -> "
          f"{fitted.hop_latency:.3e} s")
    if eff is not None:
        print(f"  overlap_eff {base.overlap_eff:.3f} -> "
              f"{fitted.overlap_eff:.3f} "
              f"(median over {ov_diag['overlap_records']} schedules)")
    from repro.core.api import _predicted_time
    for rec in records:
        t_fit = _predicted_time(rec["cm"], rec["alg"], fitted)
        print(f"  {rec['source']:28s} measured {rec['measured']:.3e}  "
              f"fit {t_fit:.3e}")
    if args.write:
        path = args.write if os.path.isabs(args.write) \
            else os.path.join(REPO_ROOT, args.write)
        roofline.save_machine(fitted, path)
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
