#!/usr/bin/env python
"""API-hygiene guard: keep first-party code on the plan-based API.

Three classes of violation:

* The free functions in ``repro.core.spmm`` (``spmm`` / ``spgemm`` /
  ``dense_matmul``) are deprecated shims kept only for downstream
  compatibility; first-party code must go through ``repro.core.api``
  (``matmul`` / ``plan_matmul`` / ``DistBSR`` / ``DistDense``).
* The Pallas kernel module ``repro.kernels.bsr_spmm`` is an internal
  implementation detail behind ``repro.kernels.ops`` and the planner;
  importing it directly bypasses impl dispatch, the coverage contract and
  the plan cache.
* The SpGEMM symbolic phase ``repro.core.symbolic`` and the steal3d
  planner ``repro.core.steal3d`` are internal to ``repro/core``: their
  public surfaces are re-exported by / reachable through
  ``repro.core.api`` (``symbolic_spgemm`` / ``SymbolicProduct`` /
  ``plan_matmul(algorithm="steal3d")``), and plans own the
  pair-list -> executable coupling.  Importing them anywhere outside
  ``src/repro/core`` bypasses the structure-keyed plan cache.

One more hygiene rule rides along: ``XLA_FLAGS`` is read by XLA exactly
once, at first backend init, so scattered ``os.environ`` writes are
silently dead or clobber each other.  ``repro/runtime/platform.py`` is
the repo's single allowed write site (merge semantics + init guard);
every other file must go through its ``set_platform`` /
``set_host_device_count`` / ``subprocess_env`` helpers, and this script
flags any direct ``...["XLA_FLAGS"] = ...`` / ``.setdefault("XLA_FLAGS",
...)`` elsewhere.

This script AST-scans each module's watched directories for imports and
exits non-zero on any hit outside the allowed prefixes.  It is also run by
``tests/test_api.py`` so the guard rides tier-1.

Usage:  python tools/check_api.py  [repo_root]
"""
from __future__ import annotations

import ast
import pathlib
import sys
from typing import List, Optional

# module -> scan config:
#   parent/leaf  : detect `from parent import leaf`
#   dirs         : repo-relative directories to scan
#   allow        : path prefixes (relative, posix) where the import is fine
FORBIDDEN_MODULES = {
    "repro.core.spmm": {
        "parent": "repro.core", "leaf": "spmm",
        "dirs": ("examples", "benchmarks"), "allow": (),
    },
    "repro.kernels.bsr_spmm": {
        "parent": "repro.kernels", "leaf": "bsr_spmm",
        "dirs": ("examples", "benchmarks"), "allow": (),
    },
    "repro.core.symbolic": {
        "parent": "repro.core", "leaf": "symbolic",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/core",),
    },
    # The steal3d planner couples LPT assignments to executables the same
    # way the symbolic phase couples pair lists: plans own that coupling,
    # so the builder is internal to repro/core (use
    # plan_matmul(algorithm="steal3d")).
    "repro.core.steal3d": {
        "parent": "repro.core", "leaf": "steal3d",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/core",),
    },
    # The packed wire layer couples consume maps / remapped pair lists to
    # executables exactly like the symbolic phase; its public surface is
    # plan_matmul(wire="packed") plus the repro.core.api re-exports
    # (PackedOperand / wire_capacity / DistBSR.packed_operand).
    "repro.core.wire": {
        "parent": "repro.core", "leaf": "wire",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/core",),
    },
    # The serving engine's slot/cache-splicing internals are not API:
    # import ServeEngine from repro.serving (the package __init__), which
    # owns the admission/batching/metrics surface.
    "repro.serving.engine": {
        "parent": "repro.serving", "leaf": "engine",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/serving",),
    },
}


# XLA_FLAGS write ban: scanned dirs and the single allowed writer.
XLA_FLAG_DIRS = ("src/repro", "examples", "benchmarks", "tools", "tests")
XLA_FLAG_ALLOW = ("src/repro/runtime/platform.py",)


# Raw-perf_counter timing ban: jax dispatch is asynchronous, so a
# perf_counter pair around a jax call times the *dispatch*, not the work
# (the timing smear PR 6 fixed in launch/serve.py).  Any function that
# reads perf_counter twice or more must reference one of the sanctioned
# blocking helpers (``block_until_ready`` directly, or ``sync_elapsed`` /
# ``timed`` from ``repro.obs``) in the same scope.  ``repro/obs`` and the
# thin re-export in ``serving/metrics.py`` are the helpers' home.
PERF_COUNTER_DIRS = ("src/repro", "examples", "benchmarks", "tools")
PERF_COUNTER_ALLOW = ("src/repro/obs", "src/repro/serving/metrics.py")
PERF_COUNTER_BLOCKERS = ("block_until_ready", "sync_elapsed", "timed")


def _perf_counter_hits(tree: ast.AST) -> List:
    """Functions timing with >= 2 raw perf_counter reads and no blocking
    discipline (no block_until_ready/sync_elapsed/timed reference)."""
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        n_pc = 0
        blocked = False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                f = sub.func
                name = f.attr if isinstance(f, ast.Attribute) else \
                    f.id if isinstance(f, ast.Name) else None
                if name == "perf_counter":
                    n_pc += 1
            ref = sub.attr if isinstance(sub, ast.Attribute) else \
                sub.id if isinstance(sub, ast.Name) else None
            if ref in PERF_COUNTER_BLOCKERS:
                blocked = True
        if n_pc >= 2 and not blocked:
            hits.append(
                (node.lineno,
                 f"function {node.name!r} times with raw perf_counter "
                 "pairs and never blocks (use obs.sync_elapsed / "
                 "obs.timed / block_until_ready)"))
    return hits


def _is_xla_key(node) -> bool:
    return isinstance(node, ast.Constant) and node.value == "XLA_FLAGS"


def _xla_flag_hits(tree: ast.AST) -> List:
    """Direct XLA_FLAGS writes: ``env["XLA_FLAGS"] = ...`` (any mapping)
    and ``.setdefault("XLA_FLAGS", ...)``."""
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) and _is_xla_key(t.slice):
                    hits.append(
                        (node.lineno, 'sets ["XLA_FLAGS"] directly '
                         "(use repro.runtime.platform)"))
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "setdefault"
                    and node.args and _is_xla_key(node.args[0])):
                hits.append(
                    (node.lineno, 'setdefault("XLA_FLAGS", ...) '
                     "(use repro.runtime.platform)"))
    return hits


def _module_hits(tree: ast.AST, mod: str, parent: str, leaf: str) -> List:
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.name
                if name == mod or name.startswith(mod + "."):
                    hits.append((node.lineno, f"import {name}"))
        elif isinstance(node, ast.ImportFrom):
            src = node.module or ""
            if src == mod or src.startswith(mod + "."):
                hits.append((node.lineno, f"from {src} import ..."))
            elif src == parent:
                for alias in node.names:
                    if alias.name == leaf:
                        hits.append((node.lineno,
                                     f"from {parent} import {leaf}"))
    return hits


def violations(root: Optional[str] = None) -> List[str]:
    root_path = pathlib.Path(root) if root else \
        pathlib.Path(__file__).resolve().parents[1]
    out: List[str] = []
    for mod, cfg in FORBIDDEN_MODULES.items():
        for sub in cfg["dirs"]:
            base = root_path / sub
            if not base.is_dir():
                continue
            for path in sorted(base.glob("**/*.py")):
                rel = path.relative_to(root_path)
                if any(rel.as_posix().startswith(pre + "/") or
                       rel.as_posix() == pre for pre in cfg["allow"]):
                    continue
                tree = ast.parse(path.read_text(), filename=str(path))
                for lineno, desc in _module_hits(tree, mod, cfg["parent"],
                                                 cfg["leaf"]):
                    out.append(f"{rel}:{lineno}: {desc}")
    for sub in XLA_FLAG_DIRS:
        base = root_path / sub
        if not base.is_dir():
            continue
        for path in sorted(base.glob("**/*.py")):
            rel = path.relative_to(root_path)
            if rel.as_posix() in XLA_FLAG_ALLOW:
                continue
            tree = ast.parse(path.read_text(), filename=str(path))
            for lineno, desc in _xla_flag_hits(tree):
                out.append(f"{rel}:{lineno}: {desc}")
    for sub in PERF_COUNTER_DIRS:
        base = root_path / sub
        if not base.is_dir():
            continue
        for path in sorted(base.glob("**/*.py")):
            rel = path.relative_to(root_path)
            rp = rel.as_posix()
            if any(rp == pre or rp.startswith(pre + "/")
                   for pre in PERF_COUNTER_ALLOW):
                continue
            tree = ast.parse(path.read_text(), filename=str(path))
            for lineno, desc in _perf_counter_hits(tree):
                out.append(f"{rel}:{lineno}: {desc}")
    return sorted(set(out))


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    found = violations(argv[0] if argv else None)
    if found:
        print("deprecated/internal module usage (use repro.core.api):")
        for v in found:
            print(f"  {v}")
        return 1
    scanned = sorted({d for cfg in FORBIDDEN_MODULES.values()
                      for d in cfg["dirs"]})
    print(f"check_api: OK ({', '.join(scanned)} are plan-API clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
