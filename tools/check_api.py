#!/usr/bin/env python
"""API-hygiene guard: keep first-party code on the plan-based API.

This is a thin CLI shim over :mod:`repro.analysis.source_rules`, the
pluggable rule registry the guard grew into (see DESIGN.md "Static
analysis").  The rules and output are byte-compatible with the original
standalone script; the registry adds ``--list-rules``, ``--json`` and
per-line ``# analysis: allow(<rule-id>)`` waiver pragmas.

Usage:  python tools/check_api.py  [repo_root]  [--json] [--list-rules]
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.source_rules import (  # noqa: E402,F401
    FORBIDDEN_MODULES, PERF_COUNTER_ALLOW, PERF_COUNTER_BLOCKERS,
    PERF_COUNTER_DIRS, RULES, XLA_FLAG_ALLOW, XLA_FLAG_DIRS, SourceRule,
    _module_hits, _perf_counter_hits, _xla_flag_hits, iter_rules, main,
    violations)

if __name__ == "__main__":
    sys.exit(main())
