#!/usr/bin/env python
"""API-hygiene guard: examples/ and benchmarks/ must use the plan-based API.

Two classes of violation:

* The free functions in ``repro.core.spmm`` (``spmm`` / ``spgemm`` /
  ``dense_matmul``) are deprecated shims kept only for downstream
  compatibility; first-party code must go through ``repro.core.api``
  (``matmul`` / ``plan_matmul`` / ``DistBSR`` / ``DistDense``).
* The Pallas kernel module ``repro.kernels.bsr_spmm`` is an internal
  implementation detail behind ``repro.kernels.ops`` and the planner;
  importing it directly bypasses impl dispatch, the coverage contract and
  the plan cache.

This script AST-scans ``examples/`` and ``benchmarks/`` for imports of
either module and exits non-zero on any hit.  It is also run by
``tests/test_api.py`` so the guard rides tier-1.

Usage:  python tools/check_api.py  [repo_root]
"""
from __future__ import annotations

import ast
import pathlib
import sys
from typing import List, Optional

# module -> (parent package, submodule name) for `from parent import name`
FORBIDDEN_MODULES = {
    "repro.core.spmm": ("repro.core", "spmm"),
    "repro.kernels.bsr_spmm": ("repro.kernels", "bsr_spmm"),
}
SCANNED_DIRS = ("examples", "benchmarks")


def violations(root: Optional[str] = None) -> List[str]:
    root_path = pathlib.Path(root) if root else \
        pathlib.Path(__file__).resolve().parents[1]
    out: List[str] = []
    for sub in SCANNED_DIRS:
        for path in sorted((root_path / sub).glob("**/*.py")):
            rel = path.relative_to(root_path)
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in ast.walk(tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        name = alias.name
                        for mod in FORBIDDEN_MODULES:
                            if name == mod or name.startswith(mod + "."):
                                out.append(f"{rel}:{node.lineno}: "
                                           f"import {name}")
                elif isinstance(node, ast.ImportFrom):
                    mod = node.module or ""
                    for bad, (parent, leaf) in FORBIDDEN_MODULES.items():
                        if mod == bad or mod.startswith(bad + "."):
                            out.append(f"{rel}:{node.lineno}: "
                                       f"from {mod} import ...")
                        elif mod == parent:
                            for alias in node.names:
                                if alias.name == leaf:
                                    out.append(
                                        f"{rel}:{node.lineno}: "
                                        f"from {parent} import {leaf}")
    return out


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    found = violations(argv[0] if argv else None)
    if found:
        print("deprecated/internal module usage (use repro.core.api):")
        for v in found:
            print(f"  {v}")
        return 1
    print(f"check_api: OK ({', '.join(SCANNED_DIRS)} are plan-API clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
