#!/usr/bin/env python
"""Textual summarizer for repro.obs Chrome-trace JSON files.

``obs.export_trace(path)`` writes a Perfetto-loadable trace; this tool
answers the quick questions without leaving the terminal: where did the
time go, per span name, and what did the slowest spans look like.

Usage:
  python tools/trace_view.py TRACE.json [--top 20] [--slowest 5]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional


def summarize(events: List[Dict]) -> List[Dict]:
    """Aggregate Chrome-trace events per span name.

    Returns rows sorted by total duration (descending), each with
    ``name`` / ``count`` / ``total_ms`` / ``mean_ms`` / ``max_ms``.
    """
    agg: Dict[str, Dict] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        name = ev.get("name", "?")
        dur_ms = float(ev.get("dur", 0.0)) / 1e3
        row = agg.setdefault(
            name, {"name": name, "count": 0, "total_ms": 0.0, "max_ms": 0.0})
        row["count"] += 1
        row["total_ms"] += dur_ms
        row["max_ms"] = max(row["max_ms"], dur_ms)
    rows = sorted(agg.values(), key=lambda r: -r["total_ms"])
    for r in rows:
        r["mean_ms"] = r["total_ms"] / r["count"]
    return rows


def slowest(events: List[Dict], n: int = 5) -> List[Dict]:
    """The n longest individual spans, longest first."""
    evs = [e for e in events if e.get("ph") == "X"]
    return sorted(evs, key=lambda e: -float(e.get("dur", 0.0)))[:n]


def render(trace: Dict, top: int = 20, n_slowest: int = 5) -> str:
    events = trace.get("traceEvents", [])
    rows = summarize(events)
    lines = [f"{len(events)} events, {len(rows)} span names"]
    dropped = trace.get("otherData", {}).get("dropped_events", 0)
    if dropped:
        lines.append(f"WARNING: {dropped} events dropped (buffer cap)")
    lines.append("")
    hdr = f"{'name':<36} {'count':>7} {'total_ms':>12} " \
          f"{'mean_ms':>10} {'max_ms':>10}"
    lines += [hdr, "-" * len(hdr)]
    for r in rows[:top]:
        lines.append(f"{r['name']:<36} {r['count']:>7} "
                     f"{r['total_ms']:>12.3f} {r['mean_ms']:>10.3f} "
                     f"{r['max_ms']:>10.3f}")
    if n_slowest and events:
        lines += ["", f"slowest {n_slowest} spans:"]
        for ev in slowest(events, n_slowest):
            args = ev.get("args", {})
            attrs = ",".join(f"{k}={v}" for k, v in sorted(args.items())
                             if k != "depth")
            lines.append(f"  {float(ev.get('dur', 0.0)) / 1e3:>10.3f} ms  "
                         f"{ev.get('name', '?')}"
                         + (f"  [{attrs}]" if attrs else ""))
    lines.append("")
    lines.append("open in https://ui.perfetto.dev for the full timeline")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("trace", help="Chrome-trace JSON from obs.export_trace")
    p.add_argument("--top", type=int, default=20,
                   help="span names to show (by total time)")
    p.add_argument("--slowest", type=int, default=5,
                   help="individual slowest spans to list")
    args = p.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    print(render(trace, top=args.top, n_slowest=args.slowest))
    return 0


if __name__ == "__main__":
    sys.exit(main())
