#!/usr/bin/env bash
# Tier-1 gate: the pytest line from ROADMAP.md, the API-hygiene guard
# (source-rule registry), the static plan verifier at 4 devices, the
# elastic replanning/recovery check at 9 devices, and a smoke-level
# benchmark pass (kernel oracle rows + a scale-8 balanced-tiling run on
# 16 fake devices).  Extra args are forwarded to pytest.
#
#   tools/run_tier1.sh            # full gate
#   tools/run_tier1.sh -k api     # forward a pytest filter
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python tools/check_api.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.selftest \
    --devices 4 --check analysis
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.selftest \
    --devices 9 --check elastic
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --smoke
echo "tier1: OK"
