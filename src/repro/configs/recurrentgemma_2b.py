"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    layer_pattern="rrl", local_window=2048, lru_width=2560,
    mlp_kind="geglu", emb_scale=True, tie_embeddings=True,
)
