"""gemma2-9b [dense]: local+global alternating attention, logit softcaps,
sandwich norms, GeGLU, tied embeddings [arXiv:2408.00118; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    layer_pattern="lg", local_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    mlp_kind="geglu", emb_scale=True, tie_embeddings=True,
)
