"""Assigned input shapes and per-(arch, shape) applicability rules."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..models.config import ModelConfig

__all__ = ["Shape", "SHAPES", "cell_supported"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq: int           # context length (KV cache length for decode)
    batch: int         # global batch


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ModelConfig, shape_name: str
                   ) -> Tuple[bool, Optional[str]]:
    """(supported, skip_reason).  Skip rules per assignment + DESIGN.md."""
    shape = SHAPES[shape_name]
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no autoregressive decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full/global attention is quadratic at 500k; "
                       "runs only for SSM/hybrid archs (see DESIGN.md)")
    return True, None
