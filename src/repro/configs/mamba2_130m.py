"""mamba2-130m [ssm]: SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from ..models.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280,
    layer_pattern="m", mlp_kind="none", tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)
