"""Architecture registry: ``get_config(arch, smoke=False)``.

Every assigned architecture is a selectable config (``--arch <id>``); smoke
variants are family-preserving reductions used by the CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from ..models.config import ModelConfig, MoEConfig, SSMConfig
from .shapes import SHAPES, Shape, cell_supported  # noqa: F401

_ARCH_MODULES = {
    "llama3-8b": "llama3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma2-9b": "gemma2_9b",
    "qwen2.5-3b": "qwen2_5_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "arctic-480b": "arctic_480b",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "hubert-xlarge": "hubert_xlarge",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; one of {list_archs()}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    cfg: ModelConfig = mod.FULL
    return make_smoke(cfg) if smoke else cfg


def make_smoke(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction: tiny dims, same layer kinds/features."""
    unit = len(cfg.layer_pattern)
    n_layers = max(2, unit + 1)          # keep pattern + a remainder layer
    head_dim = 16
    n_heads = max(2, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    moe = None
    if cfg.moe:
        # high capacity factor => no token drops => decode == full forward
        moe = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=32, capacity_factor=8.0)
    ssm = None
    if cfg.ssm:
        ssm = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        local_window=16 if cfg.local_window else None,
        lru_width=64 if cfg.lru_width else None,
        moe=moe,
        ssm=ssm,
        frontend_dim=32 if cfg.frontend else 0,
        num_patches=4 if cfg.frontend == "vlm" else 0,
        compute_dtype="float32",
        remat=False,
    )
