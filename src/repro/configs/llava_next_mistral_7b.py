"""llava-next-mistral-7b [vlm]: mistral-7b backbone; anyres vision tower is a
STUB (input_specs provides precomputed patch embeddings, prepended)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    rope_theta=1000000.0, mlp_kind="swiglu",
    frontend="vlm", frontend_dim=1024, num_patches=1152,
)
