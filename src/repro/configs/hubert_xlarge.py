"""hubert-xlarge [audio]: encoder-only backbone; the conv feature extractor
is a STUB (input_specs provides precomputed 512-d frame features)
[arXiv:2106.07447; unverified]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    causal=False, mlp_kind="gelu",
    frontend="audio", frontend_dim=512,
)
