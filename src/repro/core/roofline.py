"""The paper's SS4 inter-node roofline model, plus TPU constants.

The model characterizes one iteration of the distributed multiply by its
*inter-node arithmetic intensity* — flops per byte moved over the network —
and caps achievable throughput by the *local* roofline peak of the on-chip
kernel (not the raw arithmetic peak).

    perf(AI_net) = min(local_peak, AI_net * net_bw)
    local_peak   = min(arith_peak, AI_local * mem_bw)

Formulas follow the paper exactly (stationary-C, square sqrt(p) grids,
density d, word size w).  Machine constants cover the paper's systems
(Summit, DGX-2) and our target (TPU v5e), so the same model drives both the
paper-reproduction benchmark (Fig. 2) and the §Roofline analysis of the
compiled dry-runs.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict

__all__ = [
    "Machine", "SUMMIT_V100", "DGX2_V100", "TPU_V5E", "HOST_CPU",
    "save_machine", "load_machine",
    "spmm_local_ai", "spmm_internode_ai", "spgemm_local_ai",
    "spgemm_internode_ai", "local_peak", "internode_roofline",
    "spmm_model", "spgemm_model",
    "steal3d_internode_ai", "steal3d_model",
]


@dataclasses.dataclass(frozen=True)
class Machine:
    """Per-accelerator constants (SI bytes/s, flop/s).

    ``overlap_eff`` is the overlap term of the cost model: the fraction
    of a schedule's compute time its communication can hide under when
    the schedule's dependence structure permits prefetch (the paper's
    SS3.3 asynchronous-transfer claim).  Per-step exposed comm becomes
    ``max(0, comm - overlap_eff * comp)`` — 1.0 is perfect hiding
    (exposed = comm beyond compute, the classic ``max(comp, comm)``),
    0.0 is fully serialized (``comp + comm``).  Fitted from measured
    overlap-on vs -off A/B runs by ``tools/fit_machine.py``.
    """
    name: str
    arith_peak: float       # flop/s (fp32 for V100 per paper; bf16 for TPU)
    mem_bw: float           # HBM bytes/s
    net_bw: float           # per-chip share of injection bandwidth, bytes/s
    word_bytes: int = 4
    hop_latency: float = 1e-6   # per-message latency (the alpha term), s
    overlap_eff: float = 1.0    # comm-hiding fraction (see docstring)


# Paper SS4/SS6: V100 16 TF fp32; Summit dual-rail EDR = 23 GB/s per node,
# /6 GPUs = 3.83 GB/s per GPU.  DGX-2: NVLink 3.0, 50 GB/s per GPU link.
SUMMIT_V100 = Machine("summit-v100", 16e12, 900e9, 3.83e9, 4)
DGX2_V100 = Machine("dgx2-v100", 16e12, 900e9, 50e9, 4)
# Harness constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = Machine("tpu-v5e", 197e12, 819e9, 50e9, 2)
# CI harness: 16 fake CPU devices sharing one host process.  Loose fit to
# the BENCH_kernels.json trajectories: ~50 GFLOP/s of einsum throughput per
# fake device, "network" is host memcpy, and the per-message alpha is the
# shard_map dispatch floor.  A *compute-bound* machine — the regime where a
# work-stealing schedule's flop saving decides (on the net-bound nominal
# v5e constants, shipping extra tiles to steal work can never pay).
HOST_CPU = Machine("host-cpu", 5e10, 2e10, 2e10, 4, hop_latency=2e-5)


def save_machine(m: Machine, path: str) -> None:
    """Persist a Machine preset as JSON (see ``tools/fit_machine.py``)."""
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(m), f, indent=1)
        f.write("\n")


def load_machine(path: str) -> Machine:
    """Load a Machine preset saved by :func:`save_machine`.

    Feed the result to ``plan_matmul(machine=...)`` / ``auto_select`` so
    auto-scheduling tracks a *fitted* machine instead of nominal constants.
    """
    with open(path) as f:
        return Machine(**json.load(f))


# ---------------------------------------------------------------------------
# SpMM (paper SS4) — C (m x n) = A (m x k, density d) @ B (k x n dense)
# ---------------------------------------------------------------------------
def _spmm_terms(m: int, k: int, n: int, p: int, d: float, w: int):
    sp = math.sqrt(p)
    flops = 2.0 * (d * m * k / p) * (n / sp)
    a_bytes = w * (2.0 * d * m * k / p + m / sp + 1.0)   # CSR: vals+cols+rowptr
    b_bytes = w * (k * n / p)
    c_bytes = w * (m * n / p)
    return flops, a_bytes, b_bytes, c_bytes


def spmm_local_ai(m: int, k: int, n: int, p: int, d: float,
                  w: int = 4) -> float:
    """Paper's local SpMM arithmetic intensity (flops / bytes of A,B,C)."""
    flops, a_b, b_b, c_b = _spmm_terms(m, k, n, p, d, w)
    return flops / (a_b + b_b + c_b)


def spmm_internode_ai(m: int, k: int, n: int, p: int, d: float,
                      w: int = 4) -> float:
    """Paper's inter-node SpMM AI (flops / network bytes of A and B tiles)."""
    flops, a_b, b_b, _ = _spmm_terms(m, k, n, p, d, w)
    return flops / (a_b + b_b)


# ---------------------------------------------------------------------------
# SpGEMM (paper SS4) — C = A @ B, both sparse with density d
# ---------------------------------------------------------------------------
def spgemm_local_ai(cf: float, b: int) -> float:
    """Gu et al. bound: AI = cf / ((3 + 2 cf) * b).

    cf = compression factor (flops per nonzero of C); b = bytes per nonzero.
    """
    return cf / ((3.0 + 2.0 * cf) * b)


def spgemm_internode_ai(flops: float, m: int, k: int, n: int, p: int,
                        d: float, w: int = 4) -> float:
    """Paper's inter-node SpGEMM AI with measured FLOPS(A, B)."""
    sp = math.sqrt(p)
    a_bytes = w * (2.0 * d * m * k / p + m / sp + 1.0)
    b_bytes = w * (2.0 * d * k * n / p + k / sp + 1.0)
    return flops / (a_bytes + b_bytes)


# ---------------------------------------------------------------------------
# Rooflines
# ---------------------------------------------------------------------------
def local_peak(local_ai: float, mach: Machine) -> float:
    """Flat 'roof' of the inter-node model = the local kernel's peak."""
    return min(mach.arith_peak, local_ai * mach.mem_bw)


def internode_roofline(ai_net: float, local_ai: float,
                       mach: Machine) -> float:
    """Predicted flop/s per accelerator for one distributed iteration."""
    return min(local_peak(local_ai, mach), ai_net * mach.net_bw)


def spmm_model(m: int, k: int, n: int, p: int, d: float,
               mach: Machine) -> Dict[str, float]:
    """Everything Fig. 2 needs for one SpMM point."""
    w = mach.word_bytes
    ai_local = spmm_local_ai(m, k, n, p, d, w)
    ai_net = spmm_internode_ai(m, k, n, p, d, w)
    return {
        "ai_local": ai_local,
        "ai_net": ai_net,
        "local_peak": local_peak(ai_local, mach),
        "perf": internode_roofline(ai_net, ai_local, mach),
        "net_bound": ai_net * mach.net_bw < local_peak(ai_local, mach),
    }


def steal3d_internode_ai(flops: float, gather_bytes: float,
                         moved_bytes: float, reduce_bytes: float) -> float:
    """Inter-node AI of the static steal3d dispatch (per device).

    Unlike the owner-computes schedules, steal3d's wire traffic has three
    distinct components that all must be charged: the up-front operand
    panel gathers, the *moved tiles* of off-owner work items (the paper's
    "one moving tile" locality cost, here shipped in static ppermute
    rounds), and the partial-C tiles reduced back to their owners.
    Under the packed wire format (``plan_matmul(wire="packed")``) the
    caller passes the packed byte terms — panel gathers at the wire
    capacity, moved tiles at their per-move real max, reductions
    row-packed — so the same model scores both layouts.
    """
    total = gather_bytes + moved_bytes + reduce_bytes
    return flops / total if total else float("inf")


def steal3d_model(flops: float, gather_bytes: float, moved_bytes: float,
                  reduce_bytes: float, ai_local: float,
                  mach: Machine) -> Dict[str, float]:
    """Roofline prediction for one steal3d dispatch (Fig. 2 style)."""
    ai_net = steal3d_internode_ai(flops, gather_bytes, moved_bytes,
                                  reduce_bytes)
    return {
        "ai_local": ai_local,
        "ai_net": ai_net,
        "local_peak": local_peak(ai_local, mach),
        "perf": internode_roofline(ai_net, ai_local, mach),
        "net_bound": ai_net * mach.net_bw < local_peak(ai_local, mach),
        "moved_tile_fraction": moved_bytes / (gather_bytes + moved_bytes
                                              + reduce_bytes)
        if (gather_bytes + moved_bytes + reduce_bytes) else 0.0,
    }


def spgemm_model(flops: float, cf: float, m: int, k: int, n: int, p: int,
                 d: float, mach: Machine) -> Dict[str, float]:
    """Everything Fig. 2 needs for one SpGEMM point (measured flops & cf)."""
    w = mach.word_bytes
    ai_local = spgemm_local_ai(cf, w)
    ai_net = spgemm_internode_ai(flops, m, k, n, p, d, w)
    return {
        "ai_local": ai_local,
        "ai_net": ai_net,
        "local_peak": local_peak(ai_local, mach),
        "perf": internode_roofline(ai_net, ai_local, mach),
        "net_bound": ai_net * mach.net_bw < local_peak(ai_local, mach),
    }
