"""2D process/tile grids and owner maps.

The paper distributes A (m x k), B (k x n) and C (m x n) over a
sqrt(p) x sqrt(p) grid of tiles, one tile per process, with a *directory of
global pointers* resolving (tile_row, tile_col) -> remote memory.  On TPU the
directory becomes compile-time metadata: a ``ProcessGrid`` maps tile
coordinates to mesh coordinates / ranks, and the actual data movement is
expressed with shardings + collectives built from these maps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

__all__ = ["ProcessGrid", "ceil_div", "pad_to_multiple", "bucket_capacity"]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to_multiple(x: int, mult: int) -> int:
    return ceil_div(x, mult) * mult


def bucket_capacity(c: int, ratio: float = 1.25) -> int:
    """Round a block capacity up to the next 1.25x geometric bucket.

    Plans are keyed on exact capacities, so two matrices with nearly equal
    sparsity (say max tile nnzb 146 vs 150) would otherwise compile two
    identical executables.  Rounding capacities up to a shared bucket at
    handle construction makes their abstract shapes — and therefore their
    cached plans — coincide, at the cost of at most ``ratio - 1`` extra
    padding.  The bucket series is deterministic: 0, 1, 2, 3, 4, 5, 7, 9,
    ... (each positive bucket is ``max(prev + 1, ceil(prev * ratio))``).

    ``bucket_capacity(0) == 0``: a genuinely empty operand must not
    inflate to capacity 1 — zero real slots is its own (cheapest) bucket,
    so empty DistBSR handles allocate no phantom block storage and their
    plans execute only the coverage blocks.
    """
    if c < 0:
        raise ValueError(f"capacity must be non-negative, got {c}")
    if c == 0:
        return 0
    b = 1
    while b < c:
        b = max(b + 1, math.ceil(b * ratio))
    return b


@dataclasses.dataclass(frozen=True)
class ProcessGrid:
    """A ``rows x cols`` grid of processes, each owning one tile per matrix.

    Ranks are assigned row-major: ``rank = i * cols + j``.  This mirrors the
    paper's 2D layout (and its balanced-send proof, which assumes tile (i, j)
    lives on a unique process).
    """

    rows: int
    cols: int

    @property
    def nprocs(self) -> int:
        return self.rows * self.cols

    @classmethod
    def square(cls, p: int) -> "ProcessGrid":
        s = int(math.isqrt(p))
        if s * s != p:
            raise ValueError(f"square grid needs a perfect square, got {p}")
        return cls(s, s)

    # ---- owner maps (the "directory") -------------------------------------
    def owner(self, i: int, j: int) -> int:
        if not (0 <= i < self.rows and 0 <= j < self.cols):
            raise IndexError(f"tile ({i},{j}) outside {self.rows}x{self.cols} grid")
        return i * self.cols + j

    def coords(self, rank: int) -> Tuple[int, int]:
        if not 0 <= rank < self.nprocs:
            raise IndexError(f"rank {rank} outside grid of {self.nprocs}")
        return divmod(rank, self.cols)

    # ---- tile geometry -----------------------------------------------------
    def tile_shape(self, m: int, n: int) -> Tuple[int, int]:
        """Uniform (padded) tile shape for an ``m x n`` matrix on this grid."""
        return ceil_div(m, self.rows), ceil_div(n, self.cols)

    def padded_shape(self, m: int, n: int) -> Tuple[int, int]:
        tm, tn = self.tile_shape(m, n)
        return tm * self.rows, tn * self.cols

    def tile_slice(self, m: int, n: int, i: int, j: int):
        """Global index slice of tile (i, j); clipped to the true shape."""
        tm, tn = self.tile_shape(m, n)
        return (
            slice(i * tm, min((i + 1) * tm, m)),
            slice(j * tn, min((j + 1) * tn, n)),
        )

    # ---- the paper's iteration offset --------------------------------------
    def k_offset(self, i: int, j: int) -> int:
        """Iteration offset of the stationary-C inner loop (paper SS3.3).

        Skews process (i, j) to start its k-loop at ``i + j`` so that (a) no
        two processes in a row/column request the same tile at the same step
        and (b) the first fetch is (nearly) local.  On the ppermute ring this
        is realized as a Cannon-style pre-rotation.
        """
        return (i + j) % self.cols
