"""Static 3D work-grid dispatch: the executable form of work stealing.

The paper's SS3.4 workstealing lets idle devices claim (i, k, j) work items
from a 2D/3D work grid at runtime with remote fetch-and-add.  A
jit-compiled shard_map program cannot fetch-and-add against a remote
counter — but the quantity stealing balances (flops per item, known from
per-tile block counts) is static for a given matrix, so the *equilibrium*
the paper's stealing converges to can be computed once at plan time
(:func:`repro.core.schedule.assign_3d_lpt`) and compiled into a schedule.
This module turns that assignment into the per-device static execution
data the ``steal3d`` algorithm body (``repro.core.api``) consumes:

* **pools** — every device all-gathers its A grid-row panel (along the
  mesh column axis) and its densified B grid-column panel (along the mesh
  row axis), so any item respecting the locality constraint (device in
  grid row i or grid column j) is one moved tile away from executable;
* **move rounds** — for off-owner items, the one missing tile (B[k, j]
  for a row-local thief, A[i, k] for a column-local one) ships in static
  ``ppermute`` rounds, one per hop distance, with plan-built per-device
  gather indices selecting what each source sends;
* **pair lists** — each device's items flatten into one block-level pair
  list (A pool block, B pool row-chunk, output slot) in the style of the
  symbolic-phase machinery (slot-sorted, coverage pair per slot, inert
  zero-block padding to the uniform capacity — the LPT makespan is the
  list length, so skew shrinks executed work instead of padding it);
* **reduce rounds** — partial C tiles computed off-owner ride static
  ``ppermute`` rounds back to their owners, who accumulate them before
  the shared unskew/crop epilogue.

Everything here is host-side numpy; the only device interaction is the
plan committing the index arrays once (like sparse-output pair lists).
Like ``core.symbolic``, this module is internal to ``repro/core`` — the
public surface is ``plan_matmul(algorithm="steal3d")``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .. import obs as _obs
from . import roofline as _roofline
from . import wire as _wire
from .grid import bucket_capacity
from .schedule import Assignment3D, assign_3d_lpt
from .symbolic import extract_structure

__all__ = ["StealPlan", "build_steal_plan", "validate_assignment"]


def validate_assignment(asg: Assignment3D, g: int,
                        cost_ik: Optional[np.ndarray] = None
                        ) -> Assignment3D:
    """Fail fast on an :class:`Assignment3D` that cannot compile.

    The steal3d builder turns the assignment into gather indices and pair
    lists with no further checks, so a hand-built (or elastically
    rebuilt) assignment that breaks the invariants used to surface as
    silently wrong results or shape errors deep in the move-round
    construction.  Checked here, with actionable errors:

    * **shape/range** — ``dev`` is an int grid of shape ``(g, g, g)``
      with every entry a valid device id in ``[0, g*g)``;
    * **exactly-once + locality** — every (i, k, j) item is assigned to
      exactly one device (the dense ``dev`` grid guarantees this by
      construction) that lies in the item's grid row i or grid column j
      (the 3D locality constraint: anything else has no pool panel to
      steal from);
    * **makespan <= owner-computes** — the assignment is no worse than
      not stealing at all, both on the recorded ``makespan`` /
      ``owner_makespan`` fields and, when ``cost_ik`` (real block
      products per (i, k) panel tile, j-independent) is given,
      recomputed from the actual item costs.

    Returns ``asg`` so it can be used inline.  Raises ``ValueError``.
    """
    dev = np.asarray(asg.dev)
    if dev.shape != (g, g, g):
        raise ValueError(
            f"Assignment3D.dev has shape {dev.shape}, expected "
            f"({g}, {g}, {g}) — one device id per (i, k, j) work item")
    if not np.issubdtype(dev.dtype, np.integer):
        raise ValueError(
            f"Assignment3D.dev must hold integer device ids, got dtype "
            f"{dev.dtype}")
    if dev.min() < 0 or dev.max() >= g * g:
        raise ValueError(
            f"Assignment3D.dev holds device ids outside [0, {g * g}) "
            f"(min {int(dev.min())}, max {int(dev.max())}) for a "
            f"{g}x{g} mesh")
    r, c = dev // g, dev % g
    ii = np.arange(g)[:, None, None]
    jj = np.arange(g)[None, None, :]
    bad = np.argwhere((r != ii) & (c != jj))
    if len(bad):
        i, k, j = (int(x) for x in bad[0])
        d = int(dev[i, k, j])
        raise ValueError(
            f"assignment violates the 3D locality constraint: item "
            f"({i},{k},{j}) is assigned to device ({d // g},{d % g}), "
            f"which is in neither grid row {i} nor grid column {j} — it "
            "has no A/B pool panel to execute from; assign items only to "
            "devices in their row or column ("
            f"{len(bad)} violating item(s) total)")
    if asg.makespan > asg.owner_makespan * (1.0 + 1e-9):
        raise ValueError(
            f"assignment records makespan {asg.makespan:.6g} > "
            f"owner-computes makespan {asg.owner_makespan:.6g} — stealing "
            "must never lose to not stealing; fall back to the owner "
            "assignment for these items")
    if cost_ik is not None:
        flops = np.broadcast_to(
            np.asarray(cost_ik, dtype=np.float64)[:, :, None], (g, g, g))
        loads = np.zeros(g * g)
        np.add.at(loads, dev.ravel(), flops.ravel())
        owner = (ii * g + jj) * np.ones((g, g, g), dtype=np.int64)
        owner_loads = np.zeros(g * g)
        np.add.at(owner_loads, owner.ravel(), flops.ravel())
        if float(loads.max()) > float(owner_loads.max()) * (1.0 + 1e-9):
            raise ValueError(
                f"assignment's realized makespan {float(loads.max()):.6g} "
                "(recomputed from the operands' per-item costs) exceeds "
                f"the owner-computes makespan {float(owner_loads.max()):.6g}"
                " — this assignment makes the multiply slower than not "
                "stealing; rebuild it with assign_3d_lpt against the "
                "current cost grid")
    return asg


@dataclasses.dataclass(frozen=True)
class StealPlan:
    """Per-device static execution data for one steal3d dispatch.

    ``aux`` holds the arrays the executable consumes, all leading-indexed
    ``[g, g, ...]`` (device-major, sharded by the plan): ``pa``/``pb``/
    ``ps`` pair lists, ``amk<d>``/``bmk<d>`` per-move-round source gather
    indices, and ``rsend<d>``/``csend<d>`` per-reduce-round output-slot
    selectors.  ``cost`` is the alpha-beta-gamma cost-model dict scored by
    ``algorithm="auto"`` — its flop term is the realized LPT makespan
    (pair capacity), its byte term counts panel gathers, moved tiles and
    owner reductions.
    """
    g: int
    a_kind: str                    # "bsr" | "dense"
    n_out: int                     # output accumulator tiles per device
    n_slots: int                   # packed output slots (n_out * a_nbr)
    pair_capacity: int             # uniform pair-list length (the makespan)
    store_a: int                   # A pool stride per tile (sparse A only)
    b_chunks: int                  # bs-row chunks per B tile (sparse A only)
    a_deltas: Tuple[int, ...]      # A move rounds (hop distances, axr)
    a_move_cap: Tuple[int, ...]    # tiles shipped per A round
    b_deltas: Tuple[int, ...]      # B move rounds (hop distances, axc)
    b_move_cap: Tuple[int, ...]
    row_deltas: Tuple[int, ...]    # C reduce rounds along axc
    col_deltas: Tuple[int, ...]    # C reduce rounds along axr
    aux: Dict[str, np.ndarray]
    assignment: Assignment3D
    a_fingerprint: Optional[str]   # sparse A structure the lists encode
    cost: Dict[str, float]
    wire: str = "padded"           # "padded" | "packed" A-side shipments
    a_wire_capacity: int = 0       # packed panel stride (wire="packed")
    a_round_cap: Tuple[int, ...] = ()
                                   # packed per-move-round real max
                                   # (parallel to ``a_deltas``)
    overlap: bool = False          # two-segment pair lists (see below)


def _item_cost_grid(a_h, g: int) -> Tuple[np.ndarray, Optional[object]]:
    """(cost[i, k], structure) — real block products per (i, k, j) item.

    Every schedule in the engine consumes B as a densified tile, so the
    executed cost of item (i, k, j) is A[i, k]'s *real* stored block count
    for sparse A (j-independent) and uniform for dense A.
    """
    if a_h.kind == "bsr":
        # the handle caches its structural view (shared with fingerprints
        # and the packed wire layout); fall back for raw duck-typed inputs
        sa = a_h.grid_structure() if hasattr(a_h, "grid_structure") \
            else extract_structure(a_h.tiled)
        return sa.real.sum(axis=2).astype(np.float64), sa
    return np.ones((g, g), dtype=np.float64), None


def build_steal_plan(a_h, b_h, geom, *, locality: str = "locality",
                     comm_penalty: float = 1.0,
                     wire: str = "padded",
                     overlap: bool = False,
                     assignment: Optional[Assignment3D] = None
                     ) -> StealPlan:
    """Compile the stealing equilibrium for ``a_h @ b_h`` into a StealPlan.

    ``geom`` is the plan's :class:`repro.core.api._Geom`; handles are
    :class:`DistBSR` / :class:`DistDense` (duck-typed via ``.kind``).

    ``wire="packed"`` (sparse A only) builds the packed-wire variant: the
    A panel gathers at the packed wire capacity, moved-tile rounds slice
    to their own per-move real max (rounds moving only empty tiles are
    dropped outright), pair lists index the flat packed pool, and the
    partial-C reduce rounds ship only the block-rows each sender's items
    can touch.  The LPT assignment — and therefore the executed makespan
    — is identical to the padded plan; only the bytes on the wire shrink.

    ``overlap=True`` additionally splits each device's pair list into two
    segments so the body can overlap the moved-tile ppermute rounds with
    compute: segment 0 (``pa0``/``pb0``/``ps0``) holds the *own* items —
    (i, k, j) with i == r and j == c, executable straight off the panel
    gathers — and segment 1 (``pa1``/``pb1``/``ps1``) the stolen items
    that need moved tiles.  Each segment is independently slot-sorted
    with its own coverage pairs (the two partial outputs sum), and
    segment 0's pair indices address the *panel-only* pool (zero block
    appended directly after the g panel tiles).  The assignment, cost
    dict and combined pair lists are identical to the non-overlap build.

    ``assignment`` injects a pre-built :class:`Assignment3D` (elastic
    replanning, experiments) instead of running the LPT; it is validated
    fail-fast by :func:`validate_assignment` — locality, exactly-once,
    makespan <= owner-computes against this operand's actual item costs —
    so a broken hand-built assignment raises an actionable ``ValueError``
    here rather than silently misbehaving downstream.
    """
    g = geom.g
    n_dev = g * g
    tk = a_h.shape[1] // g
    cost_ik, sa = _item_cost_grid(a_h, g)
    sparse_a = sa is not None
    if wire not in ("padded", "packed"):
        raise ValueError(f"unknown wire {wire!r}; one of "
                         "('padded', 'packed')")
    packed = wire == "packed" and sparse_a
    wire = "packed" if packed else "padded"
    n_real_tile = sa.real.sum(axis=2).astype(np.int64) if sparse_a else None
    wc = _wire.wire_capacity(int(n_real_tile.max()),
                             a_h.tiled.store_capacity) if packed else 0
    if assignment is not None:
        asg = validate_assignment(assignment, g, cost_ik=cost_ik)
    else:
        asg = validate_assignment(
            assign_3d_lpt(
                np.broadcast_to(cost_ik[:, :, None], (g, g, g)).copy(), g,
                locality=locality, comm_penalty=comm_penalty),
            g, cost_ik=cost_ik)
    dev = asg.dev

    # ---- per-device item sets and the tiles they need moved --------------
    items = [[] for _ in range(n_dev)]
    for i in range(g):
        for k in range(g):
            for j in range(g):
                items[int(dev[i, k, j])].append((i, k, j))
    row_js, col_is, need_a, need_b = [], [], [], []
    for d in range(n_dev):
        r, c = divmod(d, g)
        rj, ci, na, nb = set(), set(), set(), set()
        for (i, k, j) in items[d]:
            if i == r and j == c:
                continue                                  # own item
            if i == r:                                    # row-local thief
                rj.add(j)
                nb.add((k, j))                            # B[k, j] moves
            elif j == c:                                  # col-local thief
                ci.add(i)
                na.add((i, k))                            # A[i, k] moves
            else:                                         # cannot happen
                raise AssertionError(
                    f"assignment violates the 3D locality constraint: item "
                    f"({i},{k},{j}) on device ({r},{c})")
        row_js.append(sorted(rj))
        col_is.append(sorted(ci))
        need_a.append(sorted(na))
        need_b.append(sorted(nb))

    # ---- move rounds: one ppermute per hop distance ----------------------
    # A tiles move along the mesh ROW axis (source (i, c) owns the A[i, :]
    # panel after the A all-gather); B tiles along the COLUMN axis.
    def _move_rounds(need, src_of, dist_of, panel_k):
        deltas, caps, lists, send = [], [], {}, {}
        for delta in range(1, g):
            per_dev = [[t for t in need[d] if dist_of(d, t) == delta]
                       for d in range(n_dev)]
            cap = max((len(v) for v in per_dev), default=0)
            if not cap:
                continue
            # source-side gather indices: what each source packs for the
            # device `delta` hops downstream of it
            k_src = np.zeros((g, g, cap), dtype=np.int32)
            for d in range(n_dev):
                s = src_of(d, delta)
                for m, t in enumerate(per_dev[d]):
                    k_src[s[0], s[1], m] = panel_k(t)
            deltas.append(delta)
            caps.append(cap)
            lists[delta] = per_dev
            send[delta] = k_src
        return deltas, caps, lists, send

    a_deltas, a_move_cap, a_lists, a_send = _move_rounds(
        need_a,
        src_of=lambda d, delta: ((d // g - delta) % g, d % g),
        dist_of=lambda d, t: (d // g - t[0]) % g,
        panel_k=lambda t: t[1])     # A[i, k]: position k in the row panel
    b_deltas, b_move_cap, b_lists, b_send = _move_rounds(
        need_b,
        src_of=lambda d, delta: (d // g, (d % g - delta) % g),
        dist_of=lambda d, t: (d % g - t[1]) % g,
        panel_k=lambda t: t[0])     # B[k, j]: position k in the col panel

    # packed wire: each A move round is sliced to its own real max (the
    # ROADMAP "moved-tile packing" item); rounds moving only structurally
    # empty tiles vanish — no ppermute, no pool segment, no alpha term.
    a_round_cap = []
    if packed:
        keep, caps = [], []
        for delta, cap in zip(a_deltas, a_move_cap):
            mr = max((int(n_real_tile[t]) for d in range(n_dev)
                      for t in a_lists[delta][d]), default=0)
            if mr == 0:
                continue
            keep.append(delta)
            caps.append(min(wc, bucket_capacity(mr)))
        a_deltas = keep
        a_move_cap = [max(len(a_lists[d_][dd]) for dd in range(n_dev))
                      for d_ in keep]
        a_round_cap = caps
        a_send = {d_: a_send[d_] for d_ in keep}

    # ---- pool tile positions (must mirror the body's concat order) ------
    # padded: tile index into the uniform-stride pool; packed: FLAT block
    # offset (panel tiles at stride wc, each move round at its own stride).
    a_pos = [dict() for _ in range(n_dev)]
    b_pos = [dict() for _ in range(n_dev)]
    for d in range(n_dev):
        r, c = divmod(d, g)
        for k in range(g):
            a_pos[d][(r, k)] = k * wc if packed else k
            b_pos[d][(k, c)] = k                 # B col panel: B[k, c] at k
    if packed:
        base = g * wc
        for delta, cap, rcap in zip(a_deltas, a_move_cap, a_round_cap):
            for d in range(n_dev):
                for m, t in enumerate(a_lists[delta][d]):
                    a_pos[d][t] = base + m * rcap
            base += cap * rcap
        a_flat_zero = base                       # zero block appended after
        a_pool_tiles = 0                         # unused on the packed path
    else:
        base = g
        for delta, cap in zip(a_deltas, a_move_cap):
            for d in range(n_dev):
                for m, t in enumerate(a_lists[delta][d]):
                    a_pos[d][t] = base + m
            base += cap
        a_pool_tiles = base                      # zero tile appended after
    base = g
    for delta, cap in zip(b_deltas, b_move_cap):
        for d in range(n_dev):
            for m, t in enumerate(b_lists[delta][d]):
                b_pos[d][t] = base + m
        base += cap

    # ---- output accumulator layout ---------------------------------------
    n_row_max = max(len(v) for v in row_js)
    n_col_max = max(len(v) for v in col_is)
    dummy = n_row_max + n_col_max > 0    # zero target for idle reduce sends
    n_out = 1 + n_row_max + n_col_max + (1 if dummy else 0)
    out_idx = []
    for d in range(n_dev):
        r, c = divmod(d, g)
        m = {(r, c): 0}
        for t, j in enumerate(row_js[d]):
            m[(r, j)] = 1 + t
        for t, i in enumerate(col_is[d]):
            m[(i, c)] = 1 + n_row_max + t
        out_idx.append(m)
    dummy_idx = n_out - 1

    # ---- reduce rounds: partials ride home one ppermute per distance -----
    row_deltas = sorted({(j - d % g) % g for d in range(n_dev)
                         for j in row_js[d]})
    col_deltas = sorted({(i - d // g) % g for d in range(n_dev)
                         for i in col_is[d]})
    aux: Dict[str, np.ndarray] = {}
    nbr_a = geom.a_nbr if sparse_a else 1
    if packed:
        # row-packed reduce rounds: a sender's partial C tile can only be
        # nonzero in the block-rows its items' A tiles store, so each
        # round ships [round_cap, bs, tn] instead of the full tile.  The
        # sender-side row gather (``rrow``/``crow``) and the receiver-side
        # target rows (``rtgt``/``ctgt``; the padding lands on the dummy
        # row ``nbr``) are both static; rounds with no real rows vanish.
        out_rows = [dict() for _ in range(n_dev)]
        for d in range(n_dev):
            for (i, k, j) in items[d]:
                sl = np.nonzero(sa.real[i, k])[0]
                if len(sl):
                    out_rows[d].setdefault((i, j), set()).update(
                        sa.rows[i, k][sl].tolist())

        def _packed_round(deltas, out_of, src_of, prefix):
            kept, caps = [], []
            for delta in deltas:
                rows_of = [sorted(out_rows[d].get(out_of(d, delta), ()))
                           for d in range(n_dev)]
                mr = max((len(r_) for r_ in rows_of), default=0)
                if mr == 0:
                    continue
                rcap = min(nbr_a, bucket_capacity(mr))
                row = np.zeros((g, g, rcap), np.int32)
                tgt = np.full((g, g, rcap), nbr_a, np.int32)
                for d in range(n_dev):
                    r, c = divmod(d, g)
                    row[r, c, :len(rows_of[d])] = rows_of[d]
                    src = rows_of[src_of(d, delta)]
                    tgt[r, c, :len(src)] = src
                aux[f"{prefix}row{delta}"] = row
                aux[f"{prefix}tgt{delta}"] = tgt
                kept.append(delta)
                caps.append(rcap)
            return kept, caps

        row_deltas, reduce_row_caps = _packed_round(
            row_deltas,
            out_of=lambda d, delta: (d // g, (d % g + delta) % g),
            src_of=lambda d, delta: (d // g) * g + (d % g - delta) % g,
            prefix="r")
        col_deltas, reduce_col_caps = _packed_round(
            col_deltas,
            out_of=lambda d, delta: ((d // g + delta) % g, d % g),
            src_of=lambda d, delta: ((d // g - delta) % g) * g + d % g,
            prefix="c")
    else:
        reduce_row_caps = reduce_col_caps = []
    for delta in row_deltas:
        sel = np.full((g, g), dummy_idx, dtype=np.int32)
        for d in range(n_dev):
            r, c = divmod(d, g)
            sel[r, c] = out_idx[d].get((r, (c + delta) % g), dummy_idx)
        aux[f"rsend{delta}"] = sel
    for delta in col_deltas:
        sel = np.full((g, g), dummy_idx, dtype=np.int32)
        for d in range(n_dev):
            r, c = divmod(d, g)
            sel[r, c] = out_idx[d].get(((r + delta) % g, c), dummy_idx)
        aux[f"csend{delta}"] = sel
    for delta, arr in a_send.items():
        aux[f"amk{delta}"] = arr
    for delta, arr in b_send.items():
        aux[f"bmk{delta}"] = arr

    # ---- pair lists (symbolic-phase style: slot-sorted + coverage) -------
    bs = a_h.block_size if sparse_a else 0
    nbr = geom.a_nbr if sparse_a else 1
    store_a = a_h.tiled.store_capacity if sparse_a else 0
    b_chunks = tk // bs if sparse_a else 0
    n_slots = n_out * nbr if sparse_a else n_out
    if packed:
        zero_a = a_flat_zero
    else:
        zero_a = a_pool_tiles * store_a if sparse_a else a_pool_tiles

    def _pair_arrays(item_sets, z_a):
        """Slot-sorted pair arrays for a per-device item subset, with
        coverage pairs referencing the zero-A index ``z_a``."""
        per_dev_pairs = []
        for d in range(n_dev):
            pa, pb, ps = [], [], []
            for (i, k, j) in item_sets[d]:
                o = out_idx[d][(i, j)]
                if sparse_a:
                    sl = np.nonzero(sa.real[i, k])[0]
                    if packed and not len(sl):
                        # a structurally empty tile contributes no pairs;
                        # its move round may have been dropped above, so it
                        # has no packed pool position to reference either
                        continue
                    if packed:
                        # packed pool: real blocks are the tile's flat
                        # prefix
                        pa.append(a_pos[d][(i, k)] + np.arange(len(sl)))
                    else:
                        pa.append(a_pos[d][(i, k)] * store_a + sl)
                    pb.append(b_pos[d][(k, j)] * b_chunks
                              + sa.cols[i, k][sl].astype(np.int64))
                    ps.append(o * nbr + sa.rows[i, k][sl].astype(np.int64))
                else:
                    pa.append(np.array([a_pos[d][(i, k)]]))
                    pb.append(np.array([b_pos[d][(k, j)]]))
                    ps.append(np.array([o]))
            pa = np.concatenate(pa) if pa else np.zeros(0, np.int64)
            pb = np.concatenate(pb) if pb else np.zeros(0, np.int64)
            ps = np.concatenate(ps) if ps else np.zeros(0, np.int64)
            if sparse_a:
                # one coverage pair per slot (inert: zero A block), merged
                # in slot order — the kernel's first-visit zeroing contract
                ps_all = np.concatenate([ps, np.arange(n_slots)])
                order = np.argsort(ps_all, kind="stable")
                pa = np.concatenate([pa, np.full(n_slots, z_a)])[order]
                pb = np.concatenate([pb, np.zeros(n_slots, np.int64)])[order]
                ps = ps_all[order]
            else:
                order = np.argsort(ps, kind="stable")
                pa, pb, ps = pa[order], pb[order], ps[order]
            per_dev_pairs.append((pa, pb, ps))
        cap = bucket_capacity(max(len(p[0]) for p in per_dev_pairs))
        pa_arr = np.full((g, g, cap), z_a, dtype=np.int32)
        pb_arr = np.zeros((g, g, cap), dtype=np.int32)
        ps_arr = np.full((g, g, cap), n_slots - 1, dtype=np.int32)
        for d, (pa, pb, ps) in enumerate(per_dev_pairs):
            r, c = divmod(d, g)
            n = len(pa)
            pa_arr[r, c, :n] = pa
            pb_arr[r, c, :n] = pb
            ps_arr[r, c, :n] = ps
        return cap, pa_arr, pb_arr, ps_arr

    pair_cap, pa_arr, pb_arr, ps_arr = _pair_arrays(items, zero_a)
    if overlap:
        # two-segment split: own items run straight off the panel gathers
        # (segment 0, addressing the panel-only pool whose zero block sits
        # right after the g panel tiles), stolen items wait for the moved
        # tiles (segment 1, addressing the full pool as usual)
        own_items, stolen_items = [], []
        for d in range(n_dev):
            r, c = divmod(d, g)
            own_items.append([t for t in items[d]
                              if t[0] == r and t[2] == c])
            stolen_items.append([t for t in items[d]
                                 if not (t[0] == r and t[2] == c)])
        zero0 = g * wc if packed else (g * store_a if sparse_a else g)
        _, aux["pa0"], aux["pb0"], aux["ps0"] = _pair_arrays(own_items,
                                                             zero0)
        _, aux["pa1"], aux["pb1"], aux["ps1"] = _pair_arrays(stolen_items,
                                                             zero_a)
    else:
        aux["pa"], aux["pb"], aux["ps"] = pa_arr, pb_arr, ps_arr

    # ---- cost model (what auto_select scores) ----------------------------
    w_a = np.dtype(a_h.dtype).itemsize
    w_b = np.dtype(b_h.dtype).itemsize
    w_o = np.dtype(geom.out_dtype).itemsize
    if packed:
        # packed A shipments: blocks only, at the wire / per-round strides
        a_tile_bytes = wc * bs * bs * w_a
        a_moved_bytes = sum(cap * rcap for cap, rcap
                            in zip(a_move_cap, a_round_cap)) * bs * bs * w_a
    else:
        a_tile_bytes = store_a * bs * bs * w_a if sparse_a \
            else geom.tm * tk * w_a
        a_moved_bytes = sum(a_move_cap) * a_tile_bytes
    b_tile_bytes = tk * geom.tn * w_b            # B rides densified
    c_tile_bytes = geom.tm * geom.tn * w_o
    gather_bytes = (g - 1) * (a_tile_bytes + b_tile_bytes)
    moved_bytes = a_moved_bytes + sum(b_move_cap) * b_tile_bytes
    if packed:
        reduce_bytes = sum(reduce_row_caps + reduce_col_caps) \
            * bs * geom.tn * w_o
    else:
        reduce_bytes = (len(row_deltas) + len(col_deltas)) * c_tile_bytes
    flops = 2.0 * pair_cap * (bs * bs * geom.tn if sparse_a
                              else geom.tm * tk * geom.tn)
    net_bytes = float(gather_bytes + moved_bytes + reduce_bytes)
    # local traffic at the same granularity as the generic cost model: A
    # blocks stream once per executed pair (the gather), the pooled B
    # panel and the packed C accumulator are touched once
    a_local = pair_cap * (bs * bs if sparse_a else geom.tm * tk) * w_a
    local_bytes = a_local \
        + (g + sum(b_move_cap)) * b_tile_bytes + n_out * c_tile_bytes
    n_msgs = 2 + len(a_deltas) + len(b_deltas) \
        + len(row_deltas) + len(col_deltas)
    cost = {
        "steps": 1.0,
        "flops_per_step": flops,
        "net_bytes_per_step": net_bytes,
        "total_flops": flops,
        "total_net_bytes": net_bytes,
        "ai_net": _roofline.steal3d_internode_ai(
            flops, gather_bytes, moved_bytes, reduce_bytes),
        "ai_local": flops / local_bytes if local_bytes else float("inf"),
        "n_msgs": float(n_msgs),
        "gather_bytes": float(gather_bytes),
        "moved_tile_bytes": float(moved_bytes),
        "reduce_bytes": float(reduce_bytes),
        "lpt_makespan": asg.makespan,
        "owner_makespan": asg.owner_makespan,
        "n_moved_items": float(asg.n_moved),
    }
    # steal3d's stolen-work accounting feeds the process-wide registry:
    # moved-tile bytes are the paper's stealing cost, worth watching as a
    # running total across every plan a serving process builds.
    reg = _obs.registry()
    reg.counter("steal3d.plans_built", wire=wire).inc()
    reg.counter("steal3d.moved_tile_bytes").inc(float(moved_bytes))
    reg.counter("steal3d.moved_items").inc(float(asg.n_moved))
    reg.histogram("steal3d.lpt_makespan").observe(float(asg.makespan))
    return StealPlan(
        g=g, a_kind="bsr" if sparse_a else "dense", n_out=n_out,
        n_slots=n_slots, pair_capacity=pair_cap, store_a=store_a,
        b_chunks=b_chunks, a_deltas=tuple(a_deltas),
        a_move_cap=tuple(a_move_cap), b_deltas=tuple(b_deltas),
        b_move_cap=tuple(b_move_cap), row_deltas=tuple(row_deltas),
        col_deltas=tuple(col_deltas), aux=aux, assignment=asg,
        a_fingerprint=sa.fingerprint if sparse_a else None, cost=cost,
        wire=wire, a_wire_capacity=wc, a_round_cap=tuple(a_round_cap),
        overlap=overlap)
