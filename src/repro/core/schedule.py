"""Static work scheduling — the TPU adaptation of the paper's workstealing.

The paper's workstealing (SS3.4) claims work at runtime with remote
fetch-and-add against a 2D (random stealing) or 3D (locality-aware) work
grid.  Inside a compiled XLA program there is no fetch-and-add against a
remote counter, but the *quantity being balanced* — flops per device per
stage, known from per-tile nonzero counts — is static for a given matrix.
So we move the balancing decision ahead of execution:

* :func:`lpt_assign` / :func:`makespan` — the classic Longest-Processing-Time
  greedy used to *schedule* work items (i,j,k block products) onto devices;
  this is what the paper's stealing converges to dynamically.  We use it
  (a) to simulate/quantify how much stealing can help (benchmarks for
  Fig. 1 / Table 2), and (b) to drive real decisions below.
* :func:`balance_row_perm` — choose a row-block permutation of the sparse
  matrix so nnz is evenly spread over grid rows.  On TPU this directly
  shrinks the uniform tile capacity (= padded MXU work), turning the paper's
  "less time lost to load imbalance" into fewer wasted flops.
* :func:`stage_imbalance` — per-stage vs end-to-end max/avg flop imbalance
  for the ring schedules: the paper's Fig. 1 metric (sync amplifies a 1.2x
  end-to-end imbalance to ~2.3x per-stage for R-MAT scale 17 on 16x16).
"""
from __future__ import annotations

import heapq
from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "lpt_assign", "makespan", "balance_row_perm", "invert_perm",
    "stage_imbalance", "steal_simulation",
]


def invert_perm(perm: Sequence[int]) -> np.ndarray:
    """Inverse of a permutation: ``invert_perm(p)[p[t]] == t``.

    Used by the plan epilogue to undo a ``balance="rows"`` row-block
    permutation on the output (C rows inherit A's row permutation).
    """
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm), dtype=np.int64)
    return inv


def lpt_assign(costs: Sequence[float], n_workers: int) -> np.ndarray:
    """Greedy LPT: assign items (descending cost) to the least-loaded worker.

    Returns int array: worker index per item.  4/3-approximation of optimal
    makespan — the static analogue of the paper's workstealing equilibrium.
    """
    costs = np.asarray(costs, dtype=np.float64)
    order = np.argsort(-costs, kind="stable")
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    assign = np.zeros(len(costs), dtype=np.int64)
    for item in order:
        load, w = heapq.heappop(heap)
        assign[item] = w
        heapq.heappush(heap, (load + costs[item], w))
    return assign


def makespan(costs: Sequence[float], assign: np.ndarray,
             n_workers: int) -> Tuple[float, float]:
    """(max, avg) load over workers for a given assignment."""
    costs = np.asarray(costs, dtype=np.float64)
    loads = np.zeros(n_workers)
    np.add.at(loads, np.asarray(assign), costs)
    return float(loads.max()), float(loads.mean())


def balance_row_perm(nnz_per_row_block: Sequence[int],
                     grid_rows: int) -> np.ndarray:
    """Permute row blocks so each grid row gets a near-equal nnz share.

    Returns a permutation ``perm`` such that row block ``perm[t]`` should be
    placed at position ``t``; positions are dealt round-robin within each
    grid row so every grid row keeps ``n/grid_rows`` row blocks.
    """
    nnz = np.asarray(nnz_per_row_block, dtype=np.float64)
    n = len(nnz)
    if n % grid_rows:
        raise ValueError("row blocks must divide evenly among grid rows")
    per = n // grid_rows
    assign = _lpt_capacity(nnz, grid_rows, per)
    # build permutation: positions [g*per:(g+1)*per] receive the row blocks
    # assigned to grid row g (descending nnz for determinism)
    perm = np.zeros(n, dtype=np.int64)
    for gidx in range(grid_rows):
        mine = np.where(assign == gidx)[0]
        mine = mine[np.argsort(-nnz[mine], kind="stable")]
        perm[gidx * per:(gidx + 1) * per] = mine
    return perm


def _lpt_capacity(costs: np.ndarray, n_workers: int, cap: int) -> np.ndarray:
    """LPT with a per-worker item-count capacity (keeps tiles per row even)."""
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_workers)
    counts = np.zeros(n_workers, dtype=np.int64)
    assign = np.zeros(len(costs), dtype=np.int64)
    for item in order:
        open_w = np.where(counts < cap)[0]
        w = open_w[np.argmin(loads[open_w])]
        assign[item] = w
        loads[w] += costs[item]
        counts[w] += 1
    return assign


def stage_imbalance(tile_costs: np.ndarray) -> Tuple[float, float]:
    """(per_stage, end_to_end) max/avg imbalance of the ring-C schedule.

    ``tile_costs[i, k]`` = flops of using tile A[i, k] (e.g. nnzb counts).
    Device (i, j) at stage t works on A[i, (i + j + t) % g]: per-stage cost
    matrix c_t(i, j) = tile_costs[i, (i+j+t) % g].

    A bulk-synchronous implementation pays sum_t max_devices(c_t); the
    asynchronous one pays max_devices(sum_t c_t).  Both are reported as
    ratios over the average total (paper Fig. 1: ~2.3 vs ~1.2).
    """
    g = tile_costs.shape[0]
    assert tile_costs.shape == (g, g)
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    totals = np.zeros((g, g))
    per_stage_max = 0.0
    for t in range(g):
        c_t = tile_costs[i, (i + j + t) % g]
        per_stage_max += c_t.max()
        totals += c_t
    avg_total = totals.mean()
    if avg_total == 0:
        return 1.0, 1.0
    return per_stage_max / avg_total, totals.max() / avg_total


def stage_imbalance_3d(flops_ikj: np.ndarray) -> Tuple[float, float]:
    """(per_stage, end_to_end) imbalance with j-dependent local costs.

    ``flops_ikj[i, k, j]`` = flops of A[i,k] @ B[k,j].  Device (i, j) at
    stage t multiplies k = (i + j + t) % g (the paper's offset).
    """
    g = flops_ikj.shape[0]
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    totals = np.zeros((g, g))
    per_stage_max = 0.0
    for t in range(g):
        k = (i + j + t) % g
        c_t = flops_ikj[i, k, j]
        per_stage_max += c_t.max()
        totals += c_t
    avg = totals.mean()
    if avg == 0:
        return 1.0, 1.0
    return per_stage_max / avg, totals.max() / avg


def steal_simulation(tile_costs: np.ndarray, steal: str = "none",
                     comm_penalty: float = 0.0) -> float:
    """Simulated end-to-end makespan of stationary-A with work stealing.

    Work item (i, k) costs ``tile_costs[i, k]`` (x g output columns folded
    in).  ``steal='none'`` = owner computes; ``'random'`` = 2D work grid,
    any idle device may claim any remaining item at ``(1+comm_penalty)`` x
    cost (all three tiles must move — paper SS3.4); ``'locality'`` = 3D grid,
    items claimable only by devices in the same grid row/col at lower
    penalty (one tile moves).  Returns max/avg load ratio.
    """
    g = tile_costs.shape[0]
    costs = tile_costs.flatten().astype(np.float64)
    n_dev = g * g
    if steal == "none":
        loads = costs.copy()   # device (i,k) owns item (i,k)
        return float(loads.max() / loads.mean())
    # greedy list scheduling = idealized stealing equilibrium
    penalty = {"random": 1.0 + comm_penalty,
               "locality": 1.0 + comm_penalty / 3.0}[steal]
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_dev)
    for item in order:
        owner = item  # device (i,k) owns item (i,k)
        w = int(np.argmin(loads))
        if w == owner or loads[owner] <= loads[w] + costs[item] * (penalty - 1):
            loads[owner] += costs[item]
        else:
            loads[w] += costs[item] * penalty
    return float(loads.max() / loads.mean())
