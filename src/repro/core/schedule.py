"""Static work scheduling — the TPU adaptation of the paper's workstealing.

The paper's workstealing (SS3.4) claims work at runtime with remote
fetch-and-add against a 2D (random stealing) or 3D (locality-aware) work
grid.  Inside a compiled XLA program there is no fetch-and-add against a
remote counter, but the *quantity being balanced* — flops per device per
stage, known from per-tile nonzero counts — is static for a given matrix.
So we move the balancing decision ahead of execution:

* :func:`lpt_assign` / :func:`makespan` — the classic Longest-Processing-Time
  greedy used to *schedule* work items (i,j,k block products) onto devices;
  this is what the paper's stealing converges to dynamically.  We use it
  (a) to simulate/quantify how much stealing can help (benchmarks for
  Fig. 1 / Table 2), and (b) to drive real decisions below.
* :func:`balance_row_perm` — choose a row-block permutation of the sparse
  matrix so nnz is evenly spread over grid rows.  On TPU this directly
  shrinks the uniform tile capacity (= padded MXU work), turning the paper's
  "less time lost to load imbalance" into fewer wasted flops.
* :func:`stage_imbalance` — per-stage vs end-to-end max/avg flop imbalance
  for the ring schedules: the paper's Fig. 1 metric (sync amplifies a 1.2x
  end-to-end imbalance to ~2.3x per-stage for R-MAT scale 17 on 16x16).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "lpt_assign", "makespan", "balance_row_perm", "invert_perm",
    "stage_imbalance", "steal_simulation",
    "Assignment3D", "assign_3d_lpt",
]


def invert_perm(perm: Sequence[int]) -> np.ndarray:
    """Inverse of a permutation: ``invert_perm(p)[p[t]] == t``.

    Used by the plan epilogue to undo a ``balance="rows"`` row-block
    permutation on the output (C rows inherit A's row permutation).
    """
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm), dtype=np.int64)
    return inv


def lpt_assign(costs: Sequence[float], n_workers: int) -> np.ndarray:
    """Greedy LPT: assign items (descending cost) to the least-loaded worker.

    Returns int array: worker index per item.  4/3-approximation of optimal
    makespan — the static analogue of the paper's workstealing equilibrium.
    """
    costs = np.asarray(costs, dtype=np.float64)
    order = np.argsort(-costs, kind="stable")
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    assign = np.zeros(len(costs), dtype=np.int64)
    for item in order:
        load, w = heapq.heappop(heap)
        assign[item] = w
        heapq.heappush(heap, (load + costs[item], w))
    return assign


def makespan(costs: Sequence[float], assign: np.ndarray,
             n_workers: int) -> Tuple[float, float]:
    """(max, avg) load over workers for a given assignment."""
    costs = np.asarray(costs, dtype=np.float64)
    loads = np.zeros(n_workers)
    np.add.at(loads, np.asarray(assign), costs)
    return float(loads.max()), float(loads.mean())


def balance_row_perm(nnz_per_row_block: Sequence[int],
                     grid_rows: int) -> np.ndarray:
    """Permute row blocks so each grid row gets a near-equal nnz share.

    Returns a permutation ``perm`` such that row block ``perm[t]`` should be
    placed at position ``t``; positions are dealt round-robin within each
    grid row so every grid row keeps ``n/grid_rows`` row blocks.
    """
    nnz = np.asarray(nnz_per_row_block, dtype=np.float64)
    n = len(nnz)
    if n % grid_rows:
        raise ValueError("row blocks must divide evenly among grid rows")
    per = n // grid_rows
    assign = _lpt_capacity(nnz, grid_rows, per)
    # build permutation: positions [g*per:(g+1)*per] receive the row blocks
    # assigned to grid row g (descending nnz for determinism)
    perm = np.zeros(n, dtype=np.int64)
    for gidx in range(grid_rows):
        mine = np.where(assign == gidx)[0]
        mine = mine[np.argsort(-nnz[mine], kind="stable")]
        perm[gidx * per:(gidx + 1) * per] = mine
    return perm


def _lpt_capacity(costs: np.ndarray, n_workers: int, cap: int) -> np.ndarray:
    """LPT with a per-worker item-count capacity (keeps tiles per row even)."""
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_workers)
    counts = np.zeros(n_workers, dtype=np.int64)
    assign = np.zeros(len(costs), dtype=np.int64)
    for item in order:
        open_w = np.where(counts < cap)[0]
        w = open_w[np.argmin(loads[open_w])]
        assign[item] = w
        loads[w] += costs[item]
        counts[w] += 1
    return assign


def stage_imbalance(tile_costs: np.ndarray) -> Tuple[float, float]:
    """(per_stage, end_to_end) max/avg imbalance of the ring-C schedule.

    ``tile_costs[i, k]`` = flops of using tile A[i, k] (e.g. nnzb counts).
    Device (i, j) at stage t works on A[i, (i + j + t) % g]: per-stage cost
    matrix c_t(i, j) = tile_costs[i, (i+j+t) % g].

    A bulk-synchronous implementation pays sum_t max_devices(c_t); the
    asynchronous one pays max_devices(sum_t c_t).  Both are reported as
    ratios over the average total (paper Fig. 1: ~2.3 vs ~1.2).
    """
    g = tile_costs.shape[0]
    assert tile_costs.shape == (g, g)
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    totals = np.zeros((g, g))
    per_stage_max = 0.0
    for t in range(g):
        c_t = tile_costs[i, (i + j + t) % g]
        per_stage_max += c_t.max()
        totals += c_t
    avg_total = totals.mean()
    if avg_total == 0:
        return 1.0, 1.0
    return per_stage_max / avg_total, totals.max() / avg_total


def stage_imbalance_3d(flops_ikj: np.ndarray) -> Tuple[float, float]:
    """(per_stage, end_to_end) imbalance with j-dependent local costs.

    ``flops_ikj[i, k, j]`` = flops of A[i,k] @ B[k,j].  Device (i, j) at
    stage t multiplies k = (i + j + t) % g (the paper's offset).
    """
    g = flops_ikj.shape[0]
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    totals = np.zeros((g, g))
    per_stage_max = 0.0
    for t in range(g):
        k = (i + j + t) % g
        c_t = flops_ikj[i, k, j]
        per_stage_max += c_t.max()
        totals += c_t
    avg = totals.mean()
    if avg == 0:
        return 1.0, 1.0
    return per_stage_max / avg, totals.max() / avg


def steal_simulation(tile_costs: np.ndarray, steal: str = "none",
                     comm_penalty: float = 0.0) -> float:
    """Simulated end-to-end makespan of stationary-A with work stealing.

    Device (i, k) owns A[i, k] and the g work items (i, k, j) — one per
    output column, each costing ``tile_costs[i, k]`` (the paper's SS3.4
    work grids hand out *column* items; that granularity is what lets an
    idle device absorb part of a hub tile's work instead of all of it).
    ``steal='none'`` = owner computes; ``'random'`` = 2D work grid, any
    idle device may claim any remaining item at ``(1+comm_penalty)`` x
    cost (all three tiles move); ``'locality'`` = 3D grid, items claimable
    only by devices in the owner's grid row/column at ``(1+comm_penalty/3)``
    x cost (one tile moves).  Returns the max/avg load ratio; an all-empty
    ``tile_costs`` (legal for hypersparse operands) is perfectly balanced
    by definition (1.0, not NaN).
    """
    g = tile_costs.shape[0]
    tile = tile_costs.flatten().astype(np.float64)
    n_dev = g * g
    if steal == "none":
        loads = tile * g               # device (i,k) runs its g column items
        return float(loads.max() / loads.mean()) if loads.mean() else 1.0
    # greedy list scheduling over the g^3 column items = idealized
    # stealing equilibrium
    penalty = {"random": 1.0 + comm_penalty,
               "locality": 1.0 + comm_penalty / 3.0}[steal]
    costs = np.repeat(tile, g)         # item (i, k, j) costs tile[i, k]
    owners = np.repeat(np.arange(n_dev), g)
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_dev)
    for item in order:
        own = int(owners[item])
        cost = costs[item]
        if cost == 0.0:
            continue
        if steal == "random":
            w = int(np.argmin(loads))
        else:                          # same grid row/col as the owner
            i, k = divmod(own, g)
            feasible = np.concatenate(
                [i * g + np.arange(g), np.arange(g) * g + k])
            w = int(feasible[np.argmin(loads[feasible])])
        if w == own or loads[own] <= loads[w] + cost * (penalty - 1.0):
            loads[own] += cost
        else:
            loads[w] += cost * penalty
    return float(loads.max() / loads.mean()) if loads.mean() else 1.0


# ---------------------------------------------------------------------------
# Static 3D work-grid assignment (the executable form of steal_simulation)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Assignment3D:
    """A static placement of the (i, k, j) work grid onto a g x g device grid.

    ``dev[i, k, j]`` is the flattened device index ``r * g + c`` executing
    work item (i, k, j) — the block product A[i, k] @ B[k, j] contributing
    to C[i, j].  ``loads`` are the resulting per-device costs *including*
    the off-owner move penalty; ``makespan``/``owner_makespan`` compare the
    assignment against pure owner-computes (device (i, j) runs all its k).
    The invariant ``makespan <= owner_makespan`` always holds
    (:func:`assign_3d_lpt` falls back to owner-computes otherwise).
    """
    dev: np.ndarray            # i64[g, g, g] flattened device per item
    loads: np.ndarray          # f64[g*g] penalized load per device
    makespan: float
    owner_makespan: float
    n_moved: int               # items executed off-owner
    locality: str
    comm_penalty: float

    @property
    def g(self) -> int:
        return self.dev.shape[0]

    def gain(self) -> float:
        """Owner-computes makespan over assigned makespan (>= 1.0)."""
        return self.owner_makespan / self.makespan if self.makespan else 1.0


def assign_3d_lpt(flops_ikj: np.ndarray, grid: int, *,
                  locality: str = "locality", comm_penalty: float = 1.0,
                  max_stolen: Optional[int] = None) -> Assignment3D:
    """Capacity-constrained LPT assignment of the 3D work grid to devices.

    The static realization of the paper's SS3.4 work stealing: instead of
    devices claiming items at runtime with remote fetch-and-add, the same
    greedy equilibrium is computed once at plan time and baked into a
    schedule.  ``flops_ikj[i, k, j]`` is the cost of work item (i, k, j);
    device (i, j) owns it.

    ``locality`` selects the work-grid shape: ``"none"`` is pure
    owner-computes, ``"random"`` the paper's 2D grid (any device may take
    any item, at ``1 + comm_penalty`` x cost — all tiles move) and
    ``"locality"`` the 3D grid (an item is only placeable on devices in
    grid row i or grid column j, at ``1 + comm_penalty / 3`` x cost — one
    tile moves), matching :func:`steal_simulation`'s penalty convention.

    ``max_stolen`` caps how many items a device may take off-owner (the
    capacity constraint — it bounds the static move/pair buffers a
    compiled dispatch must allocate).

    Items are placed in descending cost order on the feasible device that
    minimizes its resulting load, staying with the owner on ties (a
    zero-cost item never moves).  If the greedy result would exceed the
    owner-computes makespan, the owner assignment is returned instead, so
    ``makespan <= owner_makespan`` is an invariant callers may rely on.
    """
    g = int(grid)
    flops = np.asarray(flops_ikj, dtype=np.float64)
    if flops.shape != (g, g, g):
        raise ValueError(f"flops_ikj must be ({g}, {g}, {g}) for a {g}x{g} "
                         f"grid, got {flops.shape}")
    if locality not in ("none", "random", "locality"):
        raise ValueError(f"unknown locality {locality!r}; one of "
                         "('none', 'random', 'locality')")
    ii, kk, jj = np.meshgrid(np.arange(g), np.arange(g), np.arange(g),
                             indexing="ij")
    owner = (ii * g + jj).astype(np.int64)
    owner_loads = np.zeros(g * g)
    np.add.at(owner_loads, owner.ravel(), flops.ravel())
    owner_makespan = float(owner_loads.max())

    def _owner_result() -> Assignment3D:
        return Assignment3D(
            dev=owner.copy(), loads=owner_loads.copy(),
            makespan=owner_makespan, owner_makespan=owner_makespan,
            n_moved=0, locality=locality, comm_penalty=comm_penalty)

    if locality == "none":
        return _owner_result()
    penalty = 1.0 + comm_penalty if locality == "random" \
        else 1.0 + comm_penalty / 3.0
    order = np.argsort(-flops.ravel(), kind="stable")
    dev = owner.copy().ravel()
    loads = np.zeros(g * g)
    stolen = np.zeros(g * g, dtype=np.int64)
    items_i, items_j = ii.ravel(), jj.ravel()
    for item in order:
        cost = flops.ravel()[item]
        own = owner.ravel()[item]
        if cost == 0.0:
            continue                       # free items never move
        if locality == "random":
            feasible = np.arange(g * g)
        else:
            i, j = items_i[item], items_j[item]
            feasible = np.concatenate(
                [i * g + np.arange(g), np.arange(g) * g + j])
        if max_stolen is not None:
            feasible = feasible[stolen[feasible] < max_stolen]
        open_w = np.append(feasible, own)  # running your own item never steals
        w = int(open_w[np.argmin(loads[open_w])])
        # stay home unless moving (with penalty) strictly helps the max
        if w == own or loads[own] <= loads[w] + cost * (penalty - 1.0):
            loads[own] += cost
        else:
            dev[item] = w
            loads[w] += cost * penalty
            stolen[w] += 1
    if float(loads.max()) > owner_makespan:
        return _owner_result()             # greedy never beats owner: keep it
    return Assignment3D(
        dev=dev.reshape(g, g, g), loads=loads, makespan=float(loads.max()),
        owner_makespan=owner_makespan,
        n_moved=int((dev != owner.ravel()).sum()), locality=locality,
        comm_penalty=comm_penalty)
