"""Symbolic phase of distributed block SpGEMM.

The paper's SpGEMM keeps sparse structure end-to-end; the sparsity-aware
designs it builds on (Hong et al.'s symbolic/numeric split, the
Yang/Buluc/Owens row-merge family) all hinge on the same observation: the
*structure* of C = A @ B is a function of the operands' structures alone,
so it can be computed once, cheaply, on the host — and every numeric
multiply afterwards writes straight into a pre-allocated sparse output.

This module is that phase for the distributed engine.  Given two
:class:`~repro.core.bsr.TiledBSR` operands it computes, entirely host-side
(no devices, no tracing):

* the block mask of every C tile — C tile (i, j) unions the structural
  products A[i, k] x B[k, j] over k — packed into a capacity-bounded
  layout that satisfies the ``TiledBSR`` storage contract (row-sorted,
  coverage-augmented, uniformly padded), so the numeric result wraps
  directly into a :class:`~repro.core.api.DistBSR` and chains into further
  multiplies without a densify/re-tile round trip;
* per-(device, inner-step) **pair lists**: for each k, the matched
  (A slot, B slot) -> C slot triples that the numeric kernel
  (``ops.bsr_pair_accumulate``) scatter-accumulates, extending the
  sort-merge join of ``ops.build_pair_lists`` (``ops.match_block_pairs``)
  with slot mapping, per-slot coverage pairs and uniform padding;
* the statistics the cost model needs to charge sparse-output schedules
  for their *actual* traffic and flops (real pair counts, packed output
  bytes, predicted density).

Structure is derived from stored-block *data* norms, so zero padding and
coverage blocks never produce pairs, and a sparse-output C fed back in as
an operand automatically presents its (possibly tighter) effective
structure.  The public surface is re-exported by :mod:`repro.core.api`
(``symbolic_spgemm`` / ``SymbolicProduct``); importing this module
directly outside ``repro/core`` is banned by ``tools/check_api.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..kernels.ops import match_block_pairs
from .bsr import TiledBSR
from .grid import bucket_capacity

__all__ = [
    "GridStructure", "SymbolicProduct", "extract_structure",
    "structure_fingerprint", "predicted_density", "symbolic_spgemm",
]


@dataclasses.dataclass(frozen=True)
class GridStructure:
    """Host-side structural view of a TiledBSR's stored slots.

    ``real[i, j, s]`` marks slots holding nonzero data (padding and
    coverage blocks are structurally zero); ``zero_slot[i, j]`` is one
    slot per tile that is guaranteed zero — the coverage augmentation
    always stores at least one zero block — used as the inert target of
    dummy pairs.
    """
    rows: np.ndarray          # i32[g, g, store]
    cols: np.ndarray          # i32[g, g, store]
    real: np.ndarray          # bool[g, g, store]
    zero_slot: np.ndarray     # i64[g, g]
    grid_shape: Tuple[int, int]
    block_size: int
    shape: Tuple[int, int]    # padded global shape
    tile_nbr: int             # block-rows per tile
    tile_nbc: int             # block-cols per tile
    fingerprint: str


def extract_structure(t: TiledBSR) -> GridStructure:
    """Pull a TiledBSR's block structure to the host (one device read)."""
    rows = np.asarray(t.rows)
    cols = np.asarray(t.cols)
    real = np.abs(np.asarray(t.blocks)).sum(axis=(3, 4)) != 0
    if not (~real).any(axis=2).all():
        # cannot happen for TiledBSR-constructed values (coverage adds >= 1
        # zero block per tile); fail loudly rather than corrupt pair lists
        raise ValueError("tile without a zero block slot: operand does not "
                         "satisfy the TiledBSR coverage-augmentation "
                         "contract")
    zero_slot = np.argmin(real, axis=2)       # first False per tile
    h = hashlib.sha1()
    for arr in (rows, cols, real):
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(repr((t.shape, t.grid_shape, t.block_size)).encode())
    tm, tn = t.tile_shape
    return GridStructure(
        rows=rows, cols=cols, real=real, zero_slot=zero_slot,
        grid_shape=t.grid_shape, block_size=t.block_size, shape=t.shape,
        tile_nbr=tm // t.block_size, tile_nbc=tn // t.block_size,
        fingerprint=h.hexdigest())


def structure_fingerprint(t: TiledBSR) -> str:
    """Stable hash of the block structure (which slots hold data, where)."""
    return extract_structure(t).fingerprint


@dataclasses.dataclass(frozen=True)
class SymbolicProduct:
    """Predicted structure of C = A @ B plus the numeric-phase pair lists.

    The C layout (``c_rows``/``c_cols``/``c_counts``) follows the
    ``TiledBSR`` storage contract: per tile, real predicted blocks sorted
    by (row, col), padded to the uniform (bucketed) ``capacity`` and
    coverage-augmented to ``store_capacity = capacity + tile_nbr``, so the
    numeric result wraps directly into a TiledBSR.

    Pair lists are indexed ``[i, j, k, p]`` — device (i, j), inner index k
    in *natural* order (the planner reorders axis 2 per schedule via
    :meth:`scheduled_pairs`).  Each list is sorted by output slot
    (nondecreasing, the kernel's first-visit-zeroing contract), contains
    one coverage pair per output slot, and is padded with inert pairs
    (both operands' zero slots, repeating the last output slot).
    """
    g: int
    block_size: int
    tile_nbr: int                 # C tile block-rows
    tile_nbc: int                 # C tile block-cols
    shape: Tuple[int, int]        # padded C shape
    capacity: int                 # real-block capacity per C tile (bucketed)
    c_rows: np.ndarray            # i32[g, g, store_capacity]
    c_cols: np.ndarray            # i32[g, g, store_capacity]
    c_real: np.ndarray            # bool[g, g, store_capacity] — real slots
    c_counts: np.ndarray          # i32[g, g] — predicted real blocks
    pair_a: np.ndarray            # i32[g, g, g, pair_capacity]
    pair_b: np.ndarray            # i32[g, g, g, pair_capacity]
    pair_slot: np.ndarray         # i32[g, g, g, pair_capacity]
    n_real_pairs: np.ndarray      # i64[g, g, g]
    a_fingerprint: str
    b_fingerprint: str

    @property
    def store_capacity(self) -> int:
        return self.c_rows.shape[2]

    @property
    def pair_capacity(self) -> int:
        return self.pair_a.shape[3]

    def density(self) -> float:
        """Predicted fraction of C block positions that are nonzero."""
        total = self.g * self.g * self.tile_nbr * self.tile_nbc
        return float(self.c_counts.sum()) / float(total)

    def total_real_pairs(self) -> int:
        return int(self.n_real_pairs.sum())

    def flops(self) -> int:
        """Real (structure-only) MXU flops of one numeric multiply."""
        return 2 * self.total_real_pairs() * self.block_size ** 3

    def output_bytes(self, itemsize: int = 4) -> int:
        """Packed C bytes per device: blocks + rows/cols index arrays."""
        bs = self.block_size
        return self.store_capacity * (bs * bs * itemsize + 2 * 4)

    def block_mask(self) -> np.ndarray:
        """Predicted global block mask of C (bool[g*tile_nbr, g*tile_nbc])."""
        g, nbr, nbc = self.g, self.tile_nbr, self.tile_nbc
        mask = np.zeros((g * nbr, g * nbc), dtype=bool)
        for i in range(g):
            for j in range(g):
                real = self.c_real[i, j]
                mask[i * nbr + self.c_rows[i, j][real],
                     j * nbc + self.c_cols[i, j][real]] = True
        return mask

    def scheduled_pairs(self, k_order: Callable,
                        pair_a: Optional[np.ndarray] = None,
                        pair_b: Optional[np.ndarray] = None
                        ) -> Dict[str, np.ndarray]:
        """Reorder the inner axis per schedule: pairs for step t on device
        (i, j) are the natural-k lists at ``k = k_order(i, j, t, g)``.
        ``k_order`` must be numpy-broadcastable (the ring offset
        ``(i + j + t) % g``, SUMMA's ``t``, ...).

        ``pair_a``/``pair_b`` override the stored-slot operand lists with
        remapped variants of the same ``[g, g, g, P]`` shape — how the
        packed wire format (``repro.core.wire.remap_pairs_packed``)
        composes its receiver-side slot mapping into the schedule.
        """
        g = self.g
        i = np.arange(g)[:, None, None]
        j = np.arange(g)[None, :, None]
        t = np.arange(g)[None, None, :]
        k = np.broadcast_to(k_order(i, j, t, g), (g, g, g))
        take = lambda arr: arr[i, j, k]
        return {"pa": take(self.pair_a if pair_a is None else pair_a),
                "pb": take(self.pair_b if pair_b is None else pair_b),
                "ps": take(self.pair_slot)}


def _validate_pair(a: TiledBSR, b: TiledBSR) -> None:
    if a.grid_shape != b.grid_shape or a.grid_shape[0] != a.grid_shape[1]:
        raise ValueError(f"operands need matching square grids, got "
                         f"{a.grid_shape} and {b.grid_shape}")
    if a.block_size != b.block_size:
        raise ValueError(f"block sizes disagree: {a.block_size} vs "
                         f"{b.block_size}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"inner (padded) dimensions disagree: A is "
                         f"{a.shape}, B is {b.shape}")


def _global_mask(s: GridStructure) -> np.ndarray:
    """Global block mask (bool[g*tile_nbr, g*tile_nbc]) of a structure."""
    g, nbr, nbc = s.grid_shape[0], s.tile_nbr, s.tile_nbc
    mask = np.zeros((g * nbr, g * nbc), dtype=bool)
    for i in range(g):
        for j in range(g):
            real = s.real[i, j]
            mask[i * nbr + s.rows[i, j][real],
                 j * nbc + s.cols[i, j][real]] = True
    return mask


def predicted_density(a: TiledBSR, b: TiledBSR) -> float:
    """Predicted block density of C = A @ B, from block masks alone.

    The cheap prefix of the symbolic phase — one boolean mask product, no
    pair lists — enough for the ``output="auto"`` decision, so a product
    that resolves to a dense output never pays for pair-list
    construction.  Equals ``symbolic_spgemm(a, b).density()`` exactly.
    """
    _validate_pair(a, b)
    ma = _global_mask(extract_structure(a)).astype(np.float32)
    mb = _global_mask(extract_structure(b)).astype(np.float32)
    return float(((ma @ mb) > 0).mean())


def symbolic_spgemm(a: TiledBSR, b: TiledBSR,
                    capacity: Optional[int] = None) -> SymbolicProduct:
    """Run the symbolic phase for distributed C = A @ B.

    Pure host-side numpy — no mesh or devices needed, so large grids can
    be planned on a single host.  ``capacity`` pins the C tile capacity
    (must cover the prediction); by default the minimal capacity is
    derived and rounded up to a 1.25x bucket
    (:func:`repro.core.grid.bucket_capacity`), like sparse operand
    handles.
    """
    _validate_pair(a, b)
    sa, sb = extract_structure(a), extract_structure(b)
    g = a.grid_shape[0]
    bs = a.block_size
    nbr, nbc = sa.tile_nbr, sb.tile_nbc

    # Pass 1: per-tile block masks of C (union of structural products over
    # k) and the raw per-k matches, kept for pass 2.
    matches: Dict[Tuple[int, int, int], tuple] = {}
    counts = np.zeros((g, g), dtype=np.int64)
    real_rc: Dict[Tuple[int, int], tuple] = {}
    for i in range(g):
        for j in range(g):
            mask = np.zeros((nbr, nbc), dtype=bool)
            for k in range(g):
                ra = np.nonzero(sa.real[i, k])[0]
                rb = np.nonzero(sb.real[k, j])[0]
                ai, bj = match_block_pairs(sa.cols[i, k][ra],
                                           sb.rows[k, j][rb])
                pa, pb = ra[ai], rb[bj]
                orow = sa.rows[i, k][pa].astype(np.int64)
                ocol = sb.cols[k, j][pb].astype(np.int64)
                matches[i, j, k] = (pa, pb, orow, ocol)
                mask[orow, ocol] = True
            rr, cc = np.nonzero(mask)        # row-major => (row, col) sorted
            real_rc[i, j] = (rr, cc)
            counts[i, j] = len(rr)

    max_nnzb = int(counts.max())
    if capacity is None:
        capacity = bucket_capacity(max_nnzb)
    elif capacity < max_nnzb:
        raise ValueError(f"capacity {capacity} < predicted max tile nnzb "
                         f"{max_nnzb}")
    # a structurally empty product keeps capacity 0 (coverage blocks only)
    capacity = int(capacity)
    store = capacity + nbr

    # Pass 2: packed C layout (mirrors BSR.from_dense padding +
    # bsr._augment_tile coverage merge, so the result satisfies the
    # TiledBSR storage contract) and slot-mapped pair lists.
    c_rows = np.zeros((g, g, store), dtype=np.int32)
    c_cols = np.zeros((g, g, store), dtype=np.int32)
    c_real = np.zeros((g, g, store), dtype=bool)
    raw_pairs: Dict[Tuple[int, int, int], tuple] = {}
    max_pairs = 0
    for i in range(g):
        for j in range(g):
            rr, cc = real_rc[i, j]
            nnzb = len(rr)
            rows_full = np.zeros(capacity, dtype=np.int64)
            cols_full = np.zeros(capacity, dtype=np.int64)
            rows_full[:nnzb], cols_full[:nnzb] = rr, cc
            if nnzb:                         # keep padding sorted
                rows_full[nnzb:] = rr[-1]
                cols_full[nnzb:] = cc[-1]
            cov = np.arange(nbr, dtype=np.int64)
            rows_aug = np.concatenate([rows_full, cov])
            order = np.argsort(rows_aug, kind="stable")
            c_rows[i, j] = rows_aug[order]
            c_cols[i, j] = np.concatenate(
                [cols_full, np.zeros(nbr, np.int64)])[order]
            inv = np.empty(store, dtype=np.int64)
            inv[order] = np.arange(store)
            c_real[i, j, inv[:nnzb]] = True
            slot_lookup = np.full(nbr * nbc, -1, dtype=np.int64)
            slot_lookup[rr * nbc + cc] = inv[:nnzb]
            for k in range(g):
                pa, pb, orow, ocol = matches[i, j, k]
                ps = slot_lookup[orow * nbc + ocol]
                by_slot = np.argsort(ps, kind="stable")
                pa, pb, ps = pa[by_slot], pb[by_slot], ps[by_slot]
                # one coverage pair per slot (inert: both zero slots), so
                # the packed kernel's first-visit zeroing initializes every
                # slot; merged in slot order, real pairs first per slot.
                za, zb = sa.zero_slot[i, k], sb.zero_slot[k, j]
                ps_all = np.concatenate([ps, np.arange(store)])
                merge = np.argsort(ps_all, kind="stable")
                raw_pairs[i, j, k] = (
                    np.concatenate([pa, np.full(store, za)])[merge],
                    np.concatenate([pb, np.full(store, zb)])[merge],
                    ps_all[merge], len(pa))
                max_pairs = max(max_pairs, len(pa) + store)

    pair_cap = bucket_capacity(max_pairs)
    pair_a = np.zeros((g, g, g, pair_cap), dtype=np.int32)
    pair_b = np.zeros((g, g, g, pair_cap), dtype=np.int32)
    pair_slot = np.zeros((g, g, g, pair_cap), dtype=np.int32)
    n_real = np.zeros((g, g, g), dtype=np.int64)
    for (i, j, k), (pa, pb, ps, nr) in raw_pairs.items():
        n = len(pa)
        pair_a[i, j, k, :n] = pa
        pair_b[i, j, k, :n] = pb
        pair_slot[i, j, k, :n] = ps
        # inert padding: zero slots of both operands, last output slot
        # (keeps pair_slot nondecreasing)
        pair_a[i, j, k, n:] = sa.zero_slot[i, k]
        pair_b[i, j, k, n:] = sb.zero_slot[k, j]
        pair_slot[i, j, k, n:] = store - 1
        n_real[i, j, k] = nr

    return SymbolicProduct(
        g=g, block_size=bs, tile_nbr=nbr, tile_nbc=nbc,
        shape=(a.shape[0], b.shape[1]), capacity=capacity,
        c_rows=c_rows, c_cols=c_cols, c_real=c_real,
        c_counts=counts.astype(np.int32),
        pair_a=pair_a, pair_b=pair_b, pair_slot=pair_slot,
        n_real_pairs=n_real,
        a_fingerprint=sa.fingerprint, b_fingerprint=sb.fingerprint)
