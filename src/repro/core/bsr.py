"""Block-sparse (BSR) matrix pytrees and generators.

The paper stores each sparse tile as CSR (three RDMA-visible arrays:
values / rowptr / colind).  Scalar CSR wastes a TPU's MXU, so the TPU-native
data structure is *block* CSR: nonzeros are grouped into dense
``bs x bs`` blocks (bs = 128 in production, smaller in tests), and sparsity
lives at block granularity.  Blocks multiply on the MXU at full speed; the
block mask plays the role of the CSR structure arrays.

Two layouts:

* :class:`BSR` — one flat, statically-padded block list (sorted by block row)
  describing a single local matrix.  This is the layout the Pallas kernel
  consumes (scalar-prefetch of ``rows``/``cols`` drives the BlockSpec index
  maps).
* :class:`TiledBSR` — a ``grid.rows x grid.cols`` array of equally-padded BSR
  tiles for the distributed algorithms.  Uniform padding gives every device a
  static shape; the *padding itself* is the TPU manifestation of the paper's
  load imbalance (zero blocks still burn MXU cycles), which is exactly what
  the static rebalancing scheduler (``core/schedule.py``) shrinks.

Two tiling-time optimizations live here (see DESIGN.md "Sparsity-aware
capacity planning"):

* ``balance="rows"`` applies :func:`repro.core.schedule.balance_row_perm`
  to the global row blocks before tiling, spreading nonzero blocks evenly
  over grid rows so the uniform tile capacity (= executed MXU work per
  device) shrinks.  The permutation is carried on the result
  (``row_block_perm``) and inverted by the plan epilogue, so balanced and
  unbalanced plans produce identical outputs.
* TiledBSR tiles are stored *pre-augmented*: one zero block per block-row is
  merged (stably sorted) into each tile's block list at construction, so the
  SpMM kernel's coverage requirement (every output block-row visited) is met
  without any per-step concat + argsort inside the compiled ring loop.
  Stored per-tile length is therefore ``capacity + tile block-rows``
  (:attr:`TiledBSR.store_capacity`); ``capacity``/``counts`` keep counting
  *real* blocks only.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .grid import ProcessGrid, bucket_capacity, ceil_div, pad_to_multiple

__all__ = ["BSR", "TiledBSR", "rmat_edges", "rmat_matrix", "random_sparse"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["blocks", "rows", "cols"],
    meta_fields=["shape", "block_size", "nnzb", "logical_shape"],
)
@dataclasses.dataclass
class BSR:
    """Flat padded block-sparse matrix.

    blocks : f[capacity, bs, bs]  — dense data per stored block (zeros pad)
    rows   : i32[capacity]        — block-row of each stored block, sorted
    cols   : i32[capacity]        — block-col of each stored block
    shape  : (m, n) logical shape (multiple of bs after construction padding)
    nnzb   : number of *valid* blocks (static Python int; <= capacity)

    Contract: blocks beyond the valid ones are ZERO (constructors guarantee
    it), so scatter-add consumers need no masking.  For a BSR built by
    :meth:`from_dense` the valid blocks are the prefix ``[:nnzb]``; a BSR
    extracted via :meth:`TiledBSR.tile` instead interleaves zero *coverage*
    blocks among the real ones (sorted merge), so there ``nnzb`` counts
    real blocks but is NOT a prefix length — do not slice ``[:nnzb]``.
    """

    blocks: jnp.ndarray
    rows: jnp.ndarray
    cols: jnp.ndarray
    shape: Tuple[int, int]
    block_size: int
    nnzb: int
    logical_shape: Optional[Tuple[int, int]] = None

    # ---------------------------------------------------------------- basics
    @property
    def capacity(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_block_rows(self) -> int:
        return self.shape[0] // self.block_size

    @property
    def n_block_cols(self) -> int:
        return self.shape[1] // self.block_size

    @property
    def dtype(self):
        return self.blocks.dtype

    def block_fill_ratio(self) -> float:
        """Fraction of stored block entries that are nonzero (1.0 = perfect).

        Computed over blocks with any nonzero data (prefix-free, so it is
        also correct for the interleaved tiles of :meth:`TiledBSR.tile`);
        zero padding/coverage blocks never count against the ratio.
        """
        b = np.asarray(self.blocks)
        nz_blocks = int((np.abs(b).sum(axis=(1, 2)) != 0).sum())
        denom = max(nz_blocks, 1) * self.block_size**2
        return float(np.count_nonzero(b)) / float(denom)

    def flops(self, n_cols_dense: int) -> int:
        """MXU flops of BSR @ dense-with-n_cols (2*nnzb*bs^2*n)."""
        return 2 * self.nnzb * self.block_size**2 * n_cols_dense

    # ----------------------------------------------------------- conversions
    @classmethod
    def from_dense(
        cls,
        dense,
        block_size: int,
        capacity: Optional[int] = None,
        dtype=None,
    ) -> "BSR":
        dense = np.asarray(dense)
        m, n = dense.shape
        mp, np_ = pad_to_multiple(m, block_size), pad_to_multiple(n, block_size)
        padded = np.zeros((mp, np_), dtype=dense.dtype)
        padded[:m, :n] = dense
        nbr, nbc = mp // block_size, np_ // block_size
        view = padded.reshape(nbr, block_size, nbc, block_size).transpose(0, 2, 1, 3)
        mask = np.abs(view).sum(axis=(2, 3)) != 0
        rr, cc = np.nonzero(mask)  # np.nonzero returns row-major (sorted by row)
        nnzb = len(rr)
        # an all-zero matrix legitimately has capacity 0 (coverage blocks
        # added by the TiledBSR augmenter keep kernels well-defined)
        cap = capacity if capacity is not None else nnzb
        if nnzb > cap:
            raise ValueError(f"capacity {cap} < nnzb {nnzb}")
        bs = block_size
        blocks = np.zeros((cap, bs, bs), dtype=dense.dtype)
        rows = np.zeros((cap,), dtype=np.int32)
        cols = np.zeros((cap,), dtype=np.int32)
        blocks[:nnzb] = view[rr, cc]
        rows[:nnzb] = rr
        cols[:nnzb] = cc
        if nnzb > 0:  # keep padding sorted: repeat the last (row, col)
            rows[nnzb:] = rr[-1]
            cols[nnzb:] = cc[-1]
        out_dtype = dtype or dense.dtype
        return cls(
            blocks=jnp.asarray(blocks, dtype=out_dtype),
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            shape=(mp, np_),
            block_size=bs,
            nnzb=nnzb,
            logical_shape=(m, n),
        )

    @classmethod
    def from_scipy(cls, sp_mat, block_size: int, capacity: Optional[int] = None,
                   dtype=None) -> "BSR":
        import scipy.sparse as sps

        sp_mat = sps.csr_matrix(sp_mat)
        return cls.from_dense(sp_mat.toarray(), block_size, capacity, dtype)

    def to_dense(self) -> jnp.ndarray:
        # Padding / coverage blocks are zero by construction (from_dense,
        # with_capacity and the TiledBSR augmenter all guarantee it), so a
        # plain scatter-add is exact even when valid blocks are interleaved
        # with zero coverage blocks (the pre-augmented tile layout).
        bs = self.block_size
        nbr, nbc = self.n_block_rows, self.n_block_cols
        out = jnp.zeros((nbr, nbc, bs, bs), dtype=self.dtype)
        out = out.at[self.rows, self.cols].add(self.blocks)
        return out.transpose(0, 2, 1, 3).reshape(nbr * bs, nbc * bs)

    def with_capacity(self, capacity: int) -> "BSR":
        """Re-pad to a new (>= current) capacity — used to unify tile shapes.

        Shrinking is refused: valid blocks are not necessarily a prefix
        (see the class contract), so truncation could silently drop data.
        """
        pad = capacity - self.capacity
        if pad == 0:
            return self
        if pad < 0:
            raise ValueError(
                f"cannot shrink capacity {self.capacity} -> {capacity}: "
                "stored blocks are not necessarily a prefix; rebuild with "
                "from_dense(capacity=...) instead")
        last_r = self.rows[-1] if self.capacity else jnp.zeros((), jnp.int32)
        last_c = self.cols[-1] if self.capacity else jnp.zeros((), jnp.int32)
        blocks = jnp.concatenate(
            [self.blocks,
             jnp.zeros((pad, self.block_size, self.block_size), self.dtype)])
        rows = jnp.concatenate([self.rows, jnp.full((pad,), last_r, jnp.int32)])
        cols = jnp.concatenate([self.cols, jnp.full((pad,), last_c, jnp.int32)])
        return BSR(blocks, rows, cols, self.shape, self.block_size, self.nnzb,
                   self.logical_shape)


def _augment_tile(blocks: np.ndarray, rows: np.ndarray, cols: np.ndarray,
                  n_block_rows: int):
    """Merge one zero block per block-row into a tile's block list (sorted).

    This is the SpMM kernel's coverage requirement — every output block-row
    must be visited so the first-visit zeroing initializes the whole C tile —
    precomputed at tiling time instead of per ring step.  The stable sort
    keeps real blocks in row order and the appended zero blocks inert.
    """
    cov = np.arange(n_block_rows, dtype=rows.dtype)
    rows_aug = np.concatenate([rows, cov])
    order = np.argsort(rows_aug, kind="stable")
    bs = blocks.shape[1]
    blocks_aug = np.concatenate(
        [blocks, np.zeros((n_block_rows, bs, bs), blocks.dtype)])[order]
    cols_aug = np.concatenate(
        [cols, np.zeros((n_block_rows,), cols.dtype)])[order]
    return blocks_aug, rows_aug[order], cols_aug


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["blocks", "rows", "cols", "counts"],
    meta_fields=["shape", "block_size", "grid_shape", "capacity",
                 "logical_shape", "row_block_perm", "col_block_perm"],
)
@dataclasses.dataclass
class TiledBSR:
    """A grid of uniformly-padded, coverage-augmented BSR tiles.

    blocks : f[gr, gc, store_cap, bs, bs]  (store_cap = capacity + tile nbr)
    rows   : i32[gr, gc, store_cap]  block-row *within the tile*, sorted;
                                     every block-row present at least once
    cols   : i32[gr, gc, store_cap]  block-col *within the tile*
    counts : i32[gr, gc]    *real* blocks per tile (the load-imbalance map)

    Stored arrays are pre-augmented for kernel coverage (zero block per
    block-row, merged in sorted order — see :func:`_augment_tile`), so the
    distributed hot loop consumes them as-is.  ``capacity`` counts real
    block slots only; zero padding/coverage blocks are inert under the
    scatter-add consumers (``to_dense``, ``densify_raw``, the ref SpMM).

    ``row_block_perm`` (optional) records a load-balancing permutation of
    the *global* row blocks applied before tiling (``balance="rows"``):
    position ``t`` holds original row block ``row_block_perm[t]``.  The plan
    epilogue inverts it on the output, so results match unbalanced plans.
    ``col_block_perm`` is the column-axis analogue (``balance="cols"``):
    position ``t`` holds original column block ``col_block_perm[t]``.  A
    column permutation of the *left* operand permutes the contraction
    dimension, so the planner compensates by permuting the right operand's
    row blocks before the multiply; on the *right* operand the output's
    column blocks inherit the permutation and the epilogue inverts it.
    """

    blocks: jnp.ndarray
    rows: jnp.ndarray
    cols: jnp.ndarray
    counts: jnp.ndarray
    shape: Tuple[int, int]      # padded global shape
    block_size: int
    grid_shape: Tuple[int, int]
    capacity: int
    logical_shape: Optional[Tuple[int, int]] = None
    row_block_perm: Optional[Tuple[int, ...]] = None
    col_block_perm: Optional[Tuple[int, ...]] = None

    @property
    def tile_shape(self) -> Tuple[int, int]:
        return (self.shape[0] // self.grid_shape[0],
                self.shape[1] // self.grid_shape[1])

    @property
    def store_capacity(self) -> int:
        """Stored block slots per tile: capacity + coverage augmentation."""
        return self.blocks.shape[2]

    @property
    def dtype(self):
        return self.blocks.dtype

    @classmethod
    def from_dense(cls, dense, grid: ProcessGrid, block_size: int,
                   capacity=None, dtype=None,
                   balance: str = "none") -> "TiledBSR":
        """Tile a dense array into uniformly-padded BSR tiles.

        ``capacity`` is the uniform real-block capacity: an int pins it,
        ``None`` derives the minimum (max tile nnzb), and ``"bucket"``
        derives the minimum and rounds it up to the next 1.25x bucket
        (:func:`repro.core.grid.bucket_capacity`) so near-identical
        sparsity patterns share abstract shapes — and therefore cached,
        jitted plans.

        ``balance`` permutes global row blocks (``"rows"``), column blocks
        (``"cols"``) or whichever axis shrinks the capacity most
        (``"auto"``) before tiling; the permutation is carried as
        ``row_block_perm`` / ``col_block_perm`` and undone by the planner.
        An axis is only kept when it *strictly* shrinks the capacity.
        """
        if balance not in ("none", "rows", "cols", "auto"):
            raise ValueError(f"unknown balance {balance!r}; one of "
                             "('none', 'rows', 'cols', 'auto')")
        dense = np.asarray(dense)
        m, n = dense.shape
        tm = pad_to_multiple(ceil_div(m, grid.rows), block_size)
        tn = pad_to_multiple(ceil_div(n, grid.cols), block_size)
        mp, np_ = tm * grid.rows, tn * grid.cols
        padded = np.zeros((mp, np_), dtype=dense.dtype)
        padded[:m, :n] = dense
        perm = col_perm = None
        if balance != "none":
            from .schedule import balance_row_perm
            nbr_global = mp // block_size
            nbc_global = np_ // block_size
            mask = np.abs(
                padded.reshape(nbr_global, block_size, nbc_global,
                               block_size)).sum(axis=(1, 3)) != 0

            def tile_cap(m):
                per_tile = m.reshape(grid.rows, nbr_global // grid.rows,
                                     grid.cols, nbc_global // grid.cols)
                return int(per_tile.sum(axis=(1, 3)).max())

            # balance_row_perm equalizes grid-ROW (or grid-COL) totals; the
            # uniform capacity is the per-TILE max, which a permutation can
            # occasionally worsen (mass re-concentrating in one tile).
            # Keep an axis only when it strictly shrinks the capacity;
            # "auto" takes the axis with the larger shrink (rows on ties).
            best_cap = tile_cap(mask)
            best_axis = None
            if balance in ("rows", "auto"):
                p = balance_row_perm(mask.sum(axis=1), grid.rows)
                c = tile_cap(mask[np.asarray(p)])
                if c < best_cap:
                    best_axis, best_cap, perm = "rows", c, p
            if balance in ("cols", "auto"):
                p = balance_row_perm(mask.sum(axis=0), grid.cols)
                c = tile_cap(mask[:, np.asarray(p)])
                if c < best_cap:
                    best_axis, best_cap, col_perm = "cols", c, p
            if best_axis == "rows":
                col_perm = None
                padded = padded.reshape(nbr_global, block_size, np_)[perm]
                padded = padded.reshape(mp, np_)
                perm = tuple(int(p) for p in perm)
            elif best_axis == "cols":
                perm = None
                padded = padded.reshape(mp, nbc_global,
                                        block_size)[:, col_perm]
                padded = padded.reshape(mp, np_)
                col_perm = tuple(int(p) for p in col_perm)
            else:
                perm = col_perm = None
        tiles = []
        for i in range(grid.rows):
            row = []
            for j in range(grid.cols):
                row.append(BSR.from_dense(
                    padded[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn],
                    block_size, dtype=dtype))
            tiles.append(row)
        max_nnzb = max(max(t.nnzb for t in row) for row in tiles)
        if capacity == "bucket":
            cap = bucket_capacity(max_nnzb)
        else:
            if capacity is not None and capacity < max_nnzb:
                raise ValueError(
                    f"capacity {capacity} < max tile nnzb {max_nnzb}")
            # an all-zero matrix keeps capacity 0: store_capacity is then
            # just the coverage blocks — the cheap empty fast path
            cap = capacity if capacity is not None else max_nnzb
        tile_nbr = tm // block_size
        aug = [[_augment_tile(np.asarray(t.blocks), np.asarray(t.rows),
                              np.asarray(t.cols), tile_nbr)
                for t in (u.with_capacity(cap) for u in row)]
               for row in tiles]
        blocks = jnp.asarray(np.stack(
            [np.stack([a[0] for a in row]) for row in aug]))
        rows_ = jnp.asarray(np.stack(
            [np.stack([a[1] for a in row]) for row in aug]))
        cols_ = jnp.asarray(np.stack(
            [np.stack([a[2] for a in row]) for row in aug]))
        counts = jnp.asarray(
            [[t.nnzb for t in row] for row in tiles], dtype=jnp.int32)
        return cls(blocks=blocks, rows=rows_, cols=cols_, counts=counts,
                   shape=(mp, np_), block_size=block_size,
                   grid_shape=(grid.rows, grid.cols), capacity=cap,
                   logical_shape=(m, n), row_block_perm=perm,
                   col_block_perm=col_perm)

    def to_dense(self) -> jnp.ndarray:
        gr, gc = self.grid_shape
        tm, tn = self.tile_shape
        out = np.zeros(self.shape, dtype=self.blocks.dtype)
        for i in range(gr):
            for j in range(gc):
                t = BSR(self.blocks[i, j], self.rows[i, j], self.cols[i, j],
                        (tm, tn), self.block_size, int(self.counts[i, j]))
                out[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn] = np.asarray(
                    t.to_dense())
        return jnp.asarray(out)

    def tile(self, i: int, j: int) -> BSR:
        """View tile (i, j) as a flat BSR.

        The returned BSR shares the stored *pre-augmented* arrays: zero
        coverage blocks are interleaved with the real ones, so its ``nnzb``
        counts real blocks but is not a prefix length (safe for zero-inert
        consumers like ``to_dense``/``flops``; do not slice ``[:nnzb]``).
        """
        return BSR(self.blocks[i, j], self.rows[i, j], self.cols[i, j],
                   self.tile_shape, self.block_size, int(self.counts[i, j]))

    # ------------------------------------------------------ imbalance metrics
    def load_imbalance(self) -> float:
        """max/avg valid-block count over tiles — the paper's Table 1 metric."""
        c = np.asarray(self.counts, dtype=np.float64)
        avg = c.mean()
        return float(c.max() / avg) if avg > 0 else 1.0

    def padded_flop_waste(self) -> float:
        """Fraction of MXU block-matmuls that operate on padding.

        Uniform static padding means every device executes ``capacity`` block
        products per tile; only ``counts`` of them are real.  This is the
        paper's per-stage load imbalance made physical on a TPU.
        """
        c = np.asarray(self.counts, dtype=np.float64)
        total = self.capacity * c.size
        return float(1.0 - c.sum() / total) if total else 0.0


# --------------------------------------------------------------------------
# Generators
# --------------------------------------------------------------------------
def rmat_edges(scale: int, edgefactor: int = 8,
               a: float = 0.6, b: float = 0.4 / 3, c: float = 0.4 / 3,
               d: float = 0.4 / 3, seed: int = 0) -> np.ndarray:
    """R-MAT edge list (paper Fig. 1 uses a=0.6, b=c=d=0.4/3, ef=8, scale 17).

    Returns int64[nedges, 2].  Vectorized recursive bit sampling.
    """
    rng = np.random.default_rng(seed)
    n_edges = edgefactor << scale
    probs = np.array([a, b, c, d], dtype=np.float64)
    probs = probs / probs.sum()
    rows = np.zeros(n_edges, dtype=np.int64)
    cols = np.zeros(n_edges, dtype=np.int64)
    for bit in range(scale):
        quad = rng.choice(4, size=n_edges, p=probs)
        rows |= ((quad >> 1) & 1).astype(np.int64) << bit
        cols |= (quad & 1).astype(np.int64) << bit
    return np.stack([rows, cols], axis=1)


def rmat_matrix(scale: int, edgefactor: int = 8, seed: int = 0,
                dtype=np.float32, **kw):
    """Dense numpy adjacency matrix from R-MAT edges (small scales only)."""
    n = 1 << scale
    e = rmat_edges(scale, edgefactor, seed=seed, **kw)
    m = np.zeros((n, n), dtype=dtype)
    m[e[:, 0], e[:, 1]] = 1.0
    return m


def random_sparse(m: int, n: int, density: float, seed: int = 0,
                  dtype=np.float32) -> np.ndarray:
    """Uniform random sparse dense-array (for tests/benchmarks)."""
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((m, n)).astype(dtype)
    mask = rng.random((m, n)) < density
    return mat * mask
