"""Block-sparse (BSR) matrix pytrees and generators.

The paper stores each sparse tile as CSR (three RDMA-visible arrays:
values / rowptr / colind).  Scalar CSR wastes a TPU's MXU, so the TPU-native
data structure is *block* CSR: nonzeros are grouped into dense
``bs x bs`` blocks (bs = 128 in production, smaller in tests), and sparsity
lives at block granularity.  Blocks multiply on the MXU at full speed; the
block mask plays the role of the CSR structure arrays.

Two layouts:

* :class:`BSR` — one flat, statically-padded block list (sorted by block row)
  describing a single local matrix.  This is the layout the Pallas kernel
  consumes (scalar-prefetch of ``rows``/``cols`` drives the BlockSpec index
  maps).
* :class:`TiledBSR` — a ``grid.rows x grid.cols`` array of equally-padded BSR
  tiles for the distributed algorithms.  Uniform padding gives every device a
  static shape; the *padding itself* is the TPU manifestation of the paper's
  load imbalance (zero blocks still burn MXU cycles), which is exactly what
  the static rebalancing scheduler (``core/schedule.py``) shrinks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .grid import ProcessGrid, ceil_div, pad_to_multiple

__all__ = ["BSR", "TiledBSR", "rmat_edges", "rmat_matrix", "random_sparse"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["blocks", "rows", "cols"],
    meta_fields=["shape", "block_size", "nnzb", "logical_shape"],
)
@dataclasses.dataclass
class BSR:
    """Flat padded block-sparse matrix.

    blocks : f[capacity, bs, bs]  — dense data per stored block (zeros pad)
    rows   : i32[capacity]        — block-row of each stored block, sorted
    cols   : i32[capacity]        — block-col of each stored block
    shape  : (m, n) logical shape (multiple of bs after construction padding)
    nnzb   : number of *valid* blocks (static Python int; <= capacity)
    """

    blocks: jnp.ndarray
    rows: jnp.ndarray
    cols: jnp.ndarray
    shape: Tuple[int, int]
    block_size: int
    nnzb: int
    logical_shape: Optional[Tuple[int, int]] = None

    # ---------------------------------------------------------------- basics
    @property
    def capacity(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_block_rows(self) -> int:
        return self.shape[0] // self.block_size

    @property
    def n_block_cols(self) -> int:
        return self.shape[1] // self.block_size

    @property
    def dtype(self):
        return self.blocks.dtype

    def block_fill_ratio(self) -> float:
        """Fraction of stored block entries that are nonzero (1.0 = perfect)."""
        nz = np.count_nonzero(np.asarray(self.blocks[: self.nnzb]))
        denom = max(self.nnzb, 1) * self.block_size**2
        return float(nz) / float(denom)

    def flops(self, n_cols_dense: int) -> int:
        """MXU flops of BSR @ dense-with-n_cols (2*nnzb*bs^2*n)."""
        return 2 * self.nnzb * self.block_size**2 * n_cols_dense

    # ----------------------------------------------------------- conversions
    @classmethod
    def from_dense(
        cls,
        dense,
        block_size: int,
        capacity: Optional[int] = None,
        dtype=None,
    ) -> "BSR":
        dense = np.asarray(dense)
        m, n = dense.shape
        mp, np_ = pad_to_multiple(m, block_size), pad_to_multiple(n, block_size)
        padded = np.zeros((mp, np_), dtype=dense.dtype)
        padded[:m, :n] = dense
        nbr, nbc = mp // block_size, np_ // block_size
        view = padded.reshape(nbr, block_size, nbc, block_size).transpose(0, 2, 1, 3)
        mask = np.abs(view).sum(axis=(2, 3)) != 0
        rr, cc = np.nonzero(mask)  # np.nonzero returns row-major (sorted by row)
        nnzb = len(rr)
        cap = capacity if capacity is not None else max(nnzb, 1)
        if nnzb > cap:
            raise ValueError(f"capacity {cap} < nnzb {nnzb}")
        bs = block_size
        blocks = np.zeros((cap, bs, bs), dtype=dense.dtype)
        rows = np.zeros((cap,), dtype=np.int32)
        cols = np.zeros((cap,), dtype=np.int32)
        blocks[:nnzb] = view[rr, cc]
        rows[:nnzb] = rr
        cols[:nnzb] = cc
        if nnzb > 0:  # keep padding sorted: repeat the last (row, col)
            rows[nnzb:] = rr[-1]
            cols[nnzb:] = cc[-1]
        out_dtype = dtype or dense.dtype
        return cls(
            blocks=jnp.asarray(blocks, dtype=out_dtype),
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            shape=(mp, np_),
            block_size=bs,
            nnzb=nnzb,
            logical_shape=(m, n),
        )

    @classmethod
    def from_scipy(cls, sp_mat, block_size: int, capacity: Optional[int] = None,
                   dtype=None) -> "BSR":
        import scipy.sparse as sps

        sp_mat = sps.csr_matrix(sp_mat)
        return cls.from_dense(sp_mat.toarray(), block_size, capacity, dtype)

    def to_dense(self) -> jnp.ndarray:
        bs = self.block_size
        nbr, nbc = self.n_block_rows, self.n_block_cols
        out = jnp.zeros((nbr, nbc, bs, bs), dtype=self.dtype)
        valid = (jnp.arange(self.capacity) < self.nnzb)[:, None, None]
        contrib = jnp.where(valid, self.blocks, 0)
        out = out.at[self.rows, self.cols].add(contrib)
        return out.transpose(0, 2, 1, 3).reshape(nbr * bs, nbc * bs)

    def with_capacity(self, capacity: int) -> "BSR":
        """Re-pad to a new (>= nnzb) capacity — used to unify tile shapes."""
        if capacity < self.nnzb:
            raise ValueError(f"capacity {capacity} < nnzb {self.nnzb}")
        pad = capacity - self.capacity
        if pad == 0:
            return self
        if pad < 0:
            return BSR(self.blocks[:capacity], self.rows[:capacity],
                       self.cols[:capacity], self.shape, self.block_size,
                       self.nnzb, self.logical_shape)
        last_r = self.rows[-1] if self.capacity else jnp.zeros((), jnp.int32)
        last_c = self.cols[-1] if self.capacity else jnp.zeros((), jnp.int32)
        blocks = jnp.concatenate(
            [self.blocks,
             jnp.zeros((pad, self.block_size, self.block_size), self.dtype)])
        rows = jnp.concatenate([self.rows, jnp.full((pad,), last_r, jnp.int32)])
        cols = jnp.concatenate([self.cols, jnp.full((pad,), last_c, jnp.int32)])
        return BSR(blocks, rows, cols, self.shape, self.block_size, self.nnzb,
                   self.logical_shape)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["blocks", "rows", "cols", "counts"],
    meta_fields=["shape", "block_size", "grid_shape", "capacity",
                 "logical_shape"],
)
@dataclasses.dataclass
class TiledBSR:
    """A grid of uniformly-padded BSR tiles (the distributed data structure).

    blocks : f[gr, gc, cap, bs, bs]
    rows   : i32[gr, gc, cap]   block-row *within the tile*
    cols   : i32[gr, gc, cap]   block-col *within the tile*
    counts : i32[gr, gc]        valid blocks per tile (the load-imbalance map)
    """

    blocks: jnp.ndarray
    rows: jnp.ndarray
    cols: jnp.ndarray
    counts: jnp.ndarray
    shape: Tuple[int, int]      # padded global shape
    block_size: int
    grid_shape: Tuple[int, int]
    capacity: int
    logical_shape: Optional[Tuple[int, int]] = None

    @property
    def tile_shape(self) -> Tuple[int, int]:
        return (self.shape[0] // self.grid_shape[0],
                self.shape[1] // self.grid_shape[1])

    @property
    def dtype(self):
        return self.blocks.dtype

    @classmethod
    def from_dense(cls, dense, grid: ProcessGrid, block_size: int,
                   capacity: Optional[int] = None, dtype=None) -> "TiledBSR":
        dense = np.asarray(dense)
        m, n = dense.shape
        tm = pad_to_multiple(ceil_div(m, grid.rows), block_size)
        tn = pad_to_multiple(ceil_div(n, grid.cols), block_size)
        mp, np_ = tm * grid.rows, tn * grid.cols
        padded = np.zeros((mp, np_), dtype=dense.dtype)
        padded[:m, :n] = dense
        tiles = []
        for i in range(grid.rows):
            row = []
            for j in range(grid.cols):
                row.append(BSR.from_dense(
                    padded[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn],
                    block_size, dtype=dtype))
            tiles.append(row)
        cap = capacity if capacity is not None else max(
            max(t.nnzb for t in row) for row in tiles)
        cap = max(cap, 1)
        tiles = [[t.with_capacity(cap) for t in row] for row in tiles]
        blocks = jnp.stack([jnp.stack([t.blocks for t in row]) for row in tiles])
        rows_ = jnp.stack([jnp.stack([t.rows for t in row]) for row in tiles])
        cols_ = jnp.stack([jnp.stack([t.cols for t in row]) for row in tiles])
        counts = jnp.asarray(
            [[t.nnzb for t in row] for row in tiles], dtype=jnp.int32)
        return cls(blocks=blocks, rows=rows_, cols=cols_, counts=counts,
                   shape=(mp, np_), block_size=block_size,
                   grid_shape=(grid.rows, grid.cols), capacity=cap,
                   logical_shape=(m, n))

    def to_dense(self) -> jnp.ndarray:
        gr, gc = self.grid_shape
        tm, tn = self.tile_shape
        out = np.zeros(self.shape, dtype=self.blocks.dtype)
        for i in range(gr):
            for j in range(gc):
                t = BSR(self.blocks[i, j], self.rows[i, j], self.cols[i, j],
                        (tm, tn), self.block_size, int(self.counts[i, j]))
                out[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn] = np.asarray(
                    t.to_dense())
        return jnp.asarray(out)

    def tile(self, i: int, j: int) -> BSR:
        return BSR(self.blocks[i, j], self.rows[i, j], self.cols[i, j],
                   self.tile_shape, self.block_size, int(self.counts[i, j]))

    # ------------------------------------------------------ imbalance metrics
    def load_imbalance(self) -> float:
        """max/avg valid-block count over tiles — the paper's Table 1 metric."""
        c = np.asarray(self.counts, dtype=np.float64)
        avg = c.mean()
        return float(c.max() / avg) if avg > 0 else 1.0

    def padded_flop_waste(self) -> float:
        """Fraction of MXU block-matmuls that operate on padding.

        Uniform static padding means every device executes ``capacity`` block
        products per tile; only ``counts`` of them are real.  This is the
        paper's per-stage load imbalance made physical on a TPU.
        """
        c = np.asarray(self.counts, dtype=np.float64)
        total = self.capacity * c.size
        return float(1.0 - c.sum() / total) if total else 0.0


# --------------------------------------------------------------------------
# Generators
# --------------------------------------------------------------------------
def rmat_edges(scale: int, edgefactor: int = 8,
               a: float = 0.6, b: float = 0.4 / 3, c: float = 0.4 / 3,
               d: float = 0.4 / 3, seed: int = 0) -> np.ndarray:
    """R-MAT edge list (paper Fig. 1 uses a=0.6, b=c=d=0.4/3, ef=8, scale 17).

    Returns int64[nedges, 2].  Vectorized recursive bit sampling.
    """
    rng = np.random.default_rng(seed)
    n_edges = edgefactor << scale
    probs = np.array([a, b, c, d], dtype=np.float64)
    probs = probs / probs.sum()
    rows = np.zeros(n_edges, dtype=np.int64)
    cols = np.zeros(n_edges, dtype=np.int64)
    for bit in range(scale):
        quad = rng.choice(4, size=n_edges, p=probs)
        rows |= ((quad >> 1) & 1).astype(np.int64) << bit
        cols |= (quad & 1).astype(np.int64) << bit
    return np.stack([rows, cols], axis=1)


def rmat_matrix(scale: int, edgefactor: int = 8, seed: int = 0,
                dtype=np.float32, **kw):
    """Dense numpy adjacency matrix from R-MAT edges (small scales only)."""
    n = 1 << scale
    e = rmat_edges(scale, edgefactor, seed=seed, **kw)
    m = np.zeros((n, n), dtype=dtype)
    m[e[:, 0], e[:, 1]] = 1.0
    return m


def random_sparse(m: int, n: int, density: float, seed: int = 0,
                  dtype=np.float32) -> np.ndarray:
    """Uniform random sparse dense-array (for tests/benchmarks)."""
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((m, n)).astype(dtype)
    mask = rng.random((m, n)) < density
    return mat * mask
