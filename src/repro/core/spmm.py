"""Deprecated per-call wrappers for the distributed matmul engine.

The engine itself — the paper's algorithm family (bulk-synchronous SUMMA
baselines and the RDMA-style ``ring_c`` / ``ring_a`` schedules with
placement-time ``k_offset`` skew), operand packing and the shard_map bodies
— lives in :mod:`repro.core.api` behind the plan-based interface:

    a_h  = api.DistBSR.from_tiled(a_tiled)
    b_h  = api.DistDense.for_rhs(b, a_h)
    plan = api.plan_matmul(a_h, b_h, algorithm="ring_c")
    c    = plan(a_h, b_h)          # no re-trace, no re-skew on later calls

or simply ``api.matmul(a, b)``.  The free functions below are kept only for
backward compatibility; they delegate to the shared plan cache (so repeated
calls no longer re-trace) and emit a :class:`DeprecationWarning`.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax.numpy as jnp

from . import api
from .api import _prep_mesh, validate_mesh  # noqa: F401 (compat re-export)
from .bsr import TiledBSR

__all__ = ["spmm", "spgemm", "dense_matmul", "ALGORITHMS"]

# Snapshot of the built-in registry, in registration order (legacy name).
ALGORITHMS = api.algorithms()


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.spmm.{name} is deprecated; use repro.core.api.matmul "
        "or plan_matmul (see DESIGN.md, 'Plan-based API')",
        DeprecationWarning, stacklevel=3)


def spmm(a: TiledBSR, b: jnp.ndarray, *, mesh=None,
         algorithm: str = "ring_c", impl: Optional[str] = None,
         axis_row: str = "row", axis_col: str = "col",
         allow_pad: bool = False) -> jnp.ndarray:
    """Deprecated: distributed C = A @ B for block-sparse A and dense B."""
    _warn("spmm")
    return api.matmul(a, b, algorithm=algorithm, mesh=mesh, impl=impl,
                      axis_row=axis_row, axis_col=axis_col,
                      allow_pad=allow_pad)


def spgemm(a: TiledBSR, b: TiledBSR, *, mesh=None,
           algorithm: str = "ring_c", impl: Optional[str] = None,
           axis_row: str = "row", axis_col: str = "col") -> jnp.ndarray:
    """Deprecated: distributed C = A @ B for block-sparse A and B."""
    _warn("spgemm")
    return api.matmul(a, b, algorithm=algorithm, mesh=mesh, impl=impl,
                      axis_row=axis_row, axis_col=axis_col)


def dense_matmul(a: jnp.ndarray, b: jnp.ndarray, *, g: int, mesh=None,
                 algorithm: str = "ring_c", axis_row: str = "row",
                 axis_col: str = "col") -> jnp.ndarray:
    """Deprecated: dense-dense distributed matmul through the same engine."""
    _warn("dense_matmul")
    return api.matmul(jnp.asarray(a), jnp.asarray(b), g=g, mesh=mesh,
                      algorithm=algorithm, axis_row=axis_row,
                      axis_col=axis_col)
