"""Distributed SpMM / SpGEMM / dense matmul algorithms.

The paper's algorithm family, adapted from one-sided RDMA to a TPU torus:

* ``summa_bcast``  — the bulk-synchronous SUMMA baseline (paper SS2.2): a
  broadcast collective in every inner-loop step, realized as masked ``psum``
  (an all-reduce per step — the synchronizing pattern the paper criticizes).
* ``summa_ag``     — all-gather variant: every device gathers its whole tile
  row of A / tile column of B up front (the way dense TP usually does it);
  one big collective, g x tile memory footprint.
* ``ring_c``       — the paper's RDMA stationary-C algorithm (Alg 2).  The
  iteration offset ``k_offset = i + j`` becomes a skewed tile placement, and
  each step exchanges exactly one A tile and one B tile with torus
  neighbours via ``ppermute`` (collective-permute = the ICI analogue of an
  RDMA get).  The next step's tiles are requested before the local matmul so
  the compiler overlaps DMA with MXU work (the paper's prefetch).
* ``ring_a``       — the paper's RDMA stationary-A algorithm (Alg 1).  A
  tiles stay put; B tiles ride the ring; partial C tiles ride a reverse ring
  toward their owners, accumulating en route (the TPU replacement for the
  paper's remote accumulation queues).
* stationary-B is stationary-A on the transposed problem; the paper skips it
  for SpMM (B and C have equal size) and so do we — see DESIGN.md.

All algorithms produce results equal to a dense reference (up to float
accumulation order) and move identical per-step per-device volume on the
ring paths (the paper's balanced-send property, by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from ..kernels import ref as kref
from .bsr import TiledBSR
from .dist import (make_grid_mesh, place_b_for_stationary_a, skew_bsr,
                   skew_dense, unskew_c_rows)
from .grid import pad_to_multiple

__all__ = ["spmm", "spgemm", "dense_matmul", "ALGORITHMS"]

ALGORITHMS = ("summa_bcast", "summa_ag", "ring_c", "ring_a")


@dataclasses.dataclass(frozen=True)
class _Geom:
    """Static geometry threaded to the shard_map bodies via closure."""
    g: int
    tm: int           # local C tile rows
    tn: int           # local C tile cols
    a_nbr: int        # block-rows per A tile (0 => dense A)
    b_nbr: int        # block-rows per B tile (0 => dense B)
    b_nbc: int        # block-cols per B tile (0 => dense B)
    impl: Optional[str]
    axr: str
    axc: str
    out_dtype: object


# ---------------------------------------------------------------------------
# Local tile math (operand trees hold ONLY arrays)
# ---------------------------------------------------------------------------
def _local_mm(a: Dict, b: Dict, geom: _Geom) -> jnp.ndarray:
    if "dense" in b:
        b_dense = b["dense"]
    else:
        b_dense = kref.densify_raw(b["blocks"], b["rows"], b["cols"],
                                   geom.b_nbr, geom.b_nbc)
    if "dense" in a:
        out = jnp.dot(a["dense"], b_dense, preferred_element_type=jnp.float32)
    else:
        out = kops.bsr_spmm_raw(a["blocks"], a["rows"], a["cols"], b_dense,
                                n_block_rows=geom.a_nbr, impl=geom.impl)
    return out.astype(geom.out_dtype)


def _tree_ppermute(tree: Dict, axis: str, g: int) -> Dict:
    perm = [((d + 1) % g, d) for d in range(g)]
    return {k: lax.ppermute(v, axis, perm) for k, v in tree.items()}


def _tree_bcast(tree: Dict, axis: str, root, my_idx) -> Dict:
    sel = my_idx == root
    return {k: lax.psum(jnp.where(sel, v, jnp.zeros_like(v)), axis)
            for k, v in tree.items()}


# ---------------------------------------------------------------------------
# Algorithm bodies (run inside shard_map on local tile views)
# ---------------------------------------------------------------------------
def _pvary(x, geom: _Geom):
    return lax.pvary(x, (geom.axr, geom.axc))


def _body_ring_c(a, b, geom: _Geom):
    def step(carry, _):
        a_t, b_t, c = carry
        # "async_get_tile" for step k+1, issued before the local matmul so the
        # collective-permute DMA overlaps the MXU work (paper SS3.3 prefetch).
        a_n = _tree_ppermute(a_t, geom.axc, geom.g)
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)
        c = c + _local_mm(a_t, b_t, geom)
        return (a_n, b_n, c), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    (_, _, c), _ = lax.scan(step, (a, b, c0), None, length=geom.g)
    return c


def _body_ring_a(a, b, geom: _Geom):
    acc0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)

    def step(carry, _):
        b_t, acc = carry
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)   # prefetch next B tile
        acc = acc + _local_mm(a, b_t, geom)
        # route the partial C tile one hop toward its owner (the TPU
        # replacement for the paper's remote accumulation queue push)
        acc = lax.ppermute(acc, geom.axc,
                           [((d + 1) % geom.g, d) for d in range(geom.g)])
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (b, acc0), None, length=geom.g)
    return acc


def _body_summa_bcast(a, b, geom: _Geom):
    my_row = lax.axis_index(geom.axr)
    my_col = lax.axis_index(geom.axc)

    def step(c, k):
        a_k = _tree_bcast(a, geom.axc, k, my_col)  # bcast A[:, k] along rows
        b_k = _tree_bcast(b, geom.axr, k, my_row)  # bcast B[k, :] along cols
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


def _body_summa_ag(a, b, geom: _Geom):
    a_g = {k: lax.all_gather(v, geom.axc) for k, v in a.items()}
    b_g = {k: lax.all_gather(v, geom.axr) for k, v in b.items()}

    def step(c, k):
        a_k = {kk: v[k] for kk, v in a_g.items()}
        b_k = {kk: v[k] for kk, v in b_g.items()}
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


_BODIES = {
    "ring_c": _body_ring_c,
    "ring_a": _body_ring_a,
    "summa_bcast": _body_summa_bcast,
    "summa_ag": _body_summa_ag,
}


# ---------------------------------------------------------------------------
# Operand packing / placement
# ---------------------------------------------------------------------------
def _pack_bsr(t: TiledBSR) -> Dict:
    return {"blocks": t.blocks, "rows": t.rows, "cols": t.cols}


def _specs_for(tree: Dict, axr: str, axc: str) -> Dict:
    out = {}
    for k, v in tree.items():
        if k == "dense":
            out[k] = P(axr, axc)
        elif k == "blocks":
            out[k] = P(axr, axc, None, None, None)
        else:  # rows / cols
            out[k] = P(axr, axc, None)
    return out


def _local_view(tree: Dict) -> Dict:
    """Strip the leading (1, 1) grid dims of TiledBSR leaves inside shard_map."""
    return {k: (v if k == "dense" else v[0, 0]) for k, v in tree.items()}


def _run(a_tree, b_tree, mesh, algorithm, geom: _Geom):
    body = _BODIES[algorithm]

    def fn(a, b):
        return body(_local_view(a), _local_view(b), geom)

    f = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(_specs_for(a_tree, geom.axr, geom.axc),
                  _specs_for(b_tree, geom.axr, geom.axc)),
        out_specs=P(geom.axr, geom.axc),
        # pallas_call's out_shape carries no vma annotation; the engine's
        # collectives are explicit, so skip the varying-axes checker.
        check_vma=False)
    return f(a_tree, b_tree)


def _prep_mesh(mesh, g, axr, axc):
    return mesh if mesh is not None else make_grid_mesh(g, axr, axc)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
def spmm(a: TiledBSR, b: jnp.ndarray, *, mesh=None, algorithm: str = "ring_c",
         impl: Optional[str] = None, axis_row: str = "row",
         axis_col: str = "col") -> jnp.ndarray:
    """Distributed C = A @ B for block-sparse A and dense B."""
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm}; one of {ALGORITHMS}")
    g = a.grid_shape[0]
    assert a.grid_shape[0] == a.grid_shape[1], "square process grid required"
    mesh = _prep_mesh(mesh, g, axis_row, axis_col)
    k_log, n_log = b.shape
    if k_log > a.shape[1]:
        raise ValueError("inner dimensions disagree")
    n_pad = pad_to_multiple(max(n_log, g), g)
    b_p = jnp.zeros((a.shape[1], n_pad), b.dtype).at[:k_log, :n_log].set(b)

    geom = _Geom(
        g=g, tm=a.tile_shape[0], tn=n_pad // g,
        a_nbr=a.tile_shape[0] // a.block_size, b_nbr=0, b_nbc=0,
        impl=impl, axr=axis_row, axc=axis_col,
        out_dtype=jnp.promote_types(a.dtype, b.dtype))

    if algorithm == "ring_c":
        a_tree = _pack_bsr(skew_bsr(a, "rows"))
        b_tree = {"dense": skew_dense(b_p, g, "cols")}
    elif algorithm == "ring_a":
        a_tree = _pack_bsr(a)
        b_tree = {"dense": place_b_for_stationary_a(b_p, g)}
    else:
        a_tree = _pack_bsr(a)
        b_tree = {"dense": b_p}

    c = _run(a_tree, b_tree, mesh, algorithm, geom)
    if algorithm == "ring_a":
        c = unskew_c_rows(c, g)
    m_log = (a.logical_shape or a.shape)[0]
    return c[:m_log, :n_log]


def spgemm(a: TiledBSR, b: TiledBSR, *, mesh=None, algorithm: str = "ring_c",
           impl: Optional[str] = None, axis_row: str = "row",
           axis_col: str = "col") -> jnp.ndarray:
    """Distributed C = A @ B for block-sparse A and B (dense result tiles).

    Circulating B tiles stay compressed (blocks/rows/cols) on the wire — the
    analogue of shipping the paper's three CSR arrays — and are densified
    only transiently for the local MXU call.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm}; one of {ALGORITHMS}")
    g = a.grid_shape[0]
    assert a.grid_shape == b.grid_shape, "operands on different grids"
    assert a.shape[1] == b.shape[0], "inner dimensions disagree"
    mesh = _prep_mesh(mesh, g, axis_row, axis_col)

    geom = _Geom(
        g=g, tm=a.tile_shape[0], tn=b.tile_shape[1],
        a_nbr=a.tile_shape[0] // a.block_size,
        b_nbr=b.tile_shape[0] // b.block_size,
        b_nbc=b.tile_shape[1] // b.block_size,
        impl=impl, axr=axis_row, axc=axis_col,
        out_dtype=jnp.promote_types(a.dtype, b.dtype))

    if algorithm == "ring_c":
        a_tree = _pack_bsr(skew_bsr(a, "rows"))
        b_tree = _pack_bsr(skew_bsr(b, "cols"))
    elif algorithm == "ring_a":
        a_tree = _pack_bsr(a)
        i = np.arange(g)[:, None]
        k = np.arange(g)[None, :]
        si, sj = k + 0 * i, (i + k) % g  # B tile (k, (i+k)%g) at position (i,k)
        b_tree = {"blocks": b.blocks[si, sj], "rows": b.rows[si, sj],
                  "cols": b.cols[si, sj]}
    else:
        a_tree = _pack_bsr(a)
        b_tree = _pack_bsr(b)

    c = _run(a_tree, b_tree, mesh, algorithm, geom)
    if algorithm == "ring_a":
        c = unskew_c_rows(c, g)
    m_log = (a.logical_shape or a.shape)[0]
    n_log = (b.logical_shape or b.shape)[1]
    return c[:m_log, :n_log]


def dense_matmul(a: jnp.ndarray, b: jnp.ndarray, *, g: int, mesh=None,
                 algorithm: str = "ring_c", axis_row: str = "row",
                 axis_col: str = "col") -> jnp.ndarray:
    """Dense-dense distributed matmul through the same engine (engine test)."""
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm}; one of {ALGORITHMS}")
    mesh = _prep_mesh(mesh, g, axis_row, axis_col)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    mp, kp, np_ = (pad_to_multiple(x, g) for x in (m, k, n))
    a_p = jnp.zeros((mp, kp), a.dtype).at[:m, :k].set(a)
    b_p = jnp.zeros((kp, np_), b.dtype).at[:k, :n].set(b)

    geom = _Geom(
        g=g, tm=mp // g, tn=np_ // g, a_nbr=0, b_nbr=0, b_nbc=0,
        impl=None, axr=axis_row, axc=axis_col,
        out_dtype=jnp.promote_types(a.dtype, b.dtype))

    if algorithm == "ring_c":
        a_tree = {"dense": skew_dense(a_p, g, "rows")}
        b_tree = {"dense": skew_dense(b_p, g, "cols")}
    elif algorithm == "ring_a":
        a_tree = {"dense": a_p}
        b_tree = {"dense": place_b_for_stationary_a(b_p, g)}
    else:
        a_tree = {"dense": a_p}
        b_tree = {"dense": b_p}

    c = _run(a_tree, b_tree, mesh, algorithm, geom)
    if algorithm == "ring_a":
        c = unskew_c_rows(c, g)
    return c[:m, :n]
