"""Tile placement (skew layouts) and mesh helpers for the distributed
algorithms.

The paper's iteration offset ``k_offset = i + j`` (SS3.3) balances
communication and makes the first fetch local.  On a torus we realize the
offset at *tile-placement time*: the distributed matrix constructor places
tile ``A[i, (i+j) % g]`` at mesh position (i, j) ("skew_rows"), which costs
nothing at runtime — it is the TPU analogue of remapping the paper's global
pointer directory.  The ring algorithms then only ever talk to nearest
neighbours.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..compat import make_mesh
from .bsr import TiledBSR

__all__ = [
    "make_grid_mesh", "tileize", "untileize",
    "skew_dense", "skew_bsr", "place_b_for_stationary_a", "unskew_c_rows",
]


def make_grid_mesh(g: int, axis_row: str = "row", axis_col: str = "col"):
    """A g x g device mesh with Auto axis types (stable across jax versions)."""
    return make_mesh((g, g), (axis_row, axis_col))


def tileize(x: jnp.ndarray, g: int) -> jnp.ndarray:
    """[M, N] -> [g, g, M/g, N/g] tile grid view."""
    m, n = x.shape
    return x.reshape(g, m // g, g, n // g).transpose(0, 2, 1, 3)


def untileize(t: jnp.ndarray) -> jnp.ndarray:
    g1, g2, tm, tn = t.shape
    return t.transpose(0, 2, 1, 3).reshape(g1 * tm, g2 * tn)


def _roll_rows(tiles: jnp.ndarray, sign: int) -> jnp.ndarray:
    """tiles[i, j] <- tiles[i, (j + sign*i) % g]  (row-dependent column roll)."""
    g = tiles.shape[0]
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    src = (j + sign * i) % g
    return tiles[i, src]


def _roll_cols(tiles: jnp.ndarray, sign: int) -> jnp.ndarray:
    """tiles[i, j] <- tiles[(i + sign*j) % g, j]  (col-dependent row roll)."""
    g = tiles.shape[0]
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    src = (i + sign * j) % g
    return tiles[src, j]


def skew_dense(x: jnp.ndarray, g: int, kind: str) -> jnp.ndarray:
    """Skew a global dense matrix's tile grid.

    kind='rows': position (i,j) holds tile (i, (i+j)%g)   [A operand]
    kind='cols': position (i,j) holds tile ((i+j)%g, j)   [B operand]
    """
    tiles = tileize(x, g)
    if kind == "rows":
        tiles = _roll_rows(tiles, +1)
    elif kind == "cols":
        tiles = _roll_cols(tiles, +1)
    else:
        raise ValueError(kind)
    return untileize(tiles)


def skew_bsr(a: TiledBSR, kind: str) -> TiledBSR:
    """Skew a TiledBSR's tile grid (same placement semantics as skew_dense)."""
    g = a.grid_shape[0]
    if a.grid_shape[0] != a.grid_shape[1]:
        raise ValueError("skew needs a square grid")
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    if kind == "rows":
        si, sj = i + 0 * j, (j + i) % g
    elif kind == "cols":
        si, sj = (i + j) % g, j + 0 * i
    else:
        raise ValueError(kind)
    take = lambda arr: arr[si, sj]
    return TiledBSR(
        blocks=take(a.blocks), rows=take(a.rows), cols=take(a.cols),
        counts=take(a.counts), shape=a.shape, block_size=a.block_size,
        grid_shape=a.grid_shape, capacity=a.capacity,
        logical_shape=a.logical_shape, row_block_perm=a.row_block_perm,
        col_block_perm=a.col_block_perm)


def place_b_for_stationary_a(b: jnp.ndarray, g: int) -> jnp.ndarray:
    """Initial B placement for the stationary-A ring.

    Mesh position (i, k) holds B tile (k, (i+k) % g): the owner of A[i, k]
    starts with the B tile for its first output column j0 = (i+k) % g — the
    paper's ``k_offset = i + k`` for stationary A.
    """
    tiles = tileize(b, g)
    i = np.arange(g)[:, None]
    k = np.arange(g)[None, :]
    return untileize(tiles[k + 0 * i, (i + k) % g])


def unskew_c_rows(c: jnp.ndarray, g: int) -> jnp.ndarray:
    """Invert 'rows' skew on the output: position (i,j) held tile (i,(i+j)%g)."""
    tiles = tileize(c, g)
    return untileize(_roll_rows(tiles, -1))
