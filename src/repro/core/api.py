"""Plan-based public API for the distributed sparse-matmul engine.

The paper's NVSHMEM implementation builds its algorithms on *persistent*
distributed-matrix objects (BCL ``DMatrix``) with a global pointer
directory: placement and skew are decided once, at construction, and every
multiply afterwards is pure communication + compute.  This module is the
TPU analogue of that design:

* :class:`DistBSR` / :class:`DistDense` — distributed-matrix *handles*
  wrapping a :class:`~repro.core.bsr.TiledBSR` / a grid-padded dense array.
  A handle carries the process-grid geometry, dtype, logical (uncropped)
  shape and — crucially — a cache of *placements* (natural / skew-rows /
  skew-cols / stationary-A), so the paper's ``k_offset`` skew is
  materialized at most once per operand and reused across calls.
* :func:`plan_matmul` -> :class:`MatmulPlan` — precomputes the static
  :class:`_Geom`, operand pack specs and placement requirements, and holds
  one jit-compiled ``shard_map`` executable: calling the plan again with the
  same abstract shapes never re-traces.  ``plan.cost_model()`` exposes the
  per-step network volume / flops that feed ``core/roofline.py`` and
  ``core/schedule.py``.
* :func:`matmul` — one polymorphic entry point dispatching
  sparse x dense -> SpMM, sparse x sparse -> SpGEMM and dense x dense ->
  the dense engine through :data:`REGISTRY` (an :class:`AlgorithmRegistry`).
  Algorithms register declaratively with their required operand placements,
  output unskew and per-step wire traffic, so new schedules (work-stealing
  layouts, stationary-B, ...) plug in without touching the engine.

The algorithm family itself is unchanged from the paper adaptation (see the
body docstrings): ``summa_bcast`` / ``summa_ag`` are the bulk-synchronous
baselines, ``ring_c`` / ``ring_a`` the RDMA-style stationary-C /
stationary-A rings with placement-time ``k_offset`` skew and prefetch via
early ``ppermute``.  The legacy free functions in ``core/spmm.py`` remain
as deprecated shims delegating to the shared plan cache here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import pvary, shard_map
from ..kernels import ops as kops
from ..kernels import ref as kref
from . import roofline as _roofline
from . import schedule as _schedule
from .bsr import TiledBSR
from .dist import (make_grid_mesh, place_b_for_stationary_a, skew_bsr,
                   skew_dense, unskew_c_rows)
from .grid import ProcessGrid, pad_to_multiple

__all__ = [
    "NATURAL", "SKEW_ROWS", "SKEW_COLS", "STATIONARY_A", "PLACEMENTS",
    "DistMatrix", "DistBSR", "DistDense",
    "Algorithm", "AlgorithmRegistry", "REGISTRY", "register_algorithm",
    "algorithms",
    "MatmulPlan", "plan_matmul", "matmul",
    "add_trace_hook", "remove_trace_hook",
    "clear_plan_cache", "plan_cache_size",
    "validate_mesh",
]

# Placement states a DistMatrix can hold (the paper's directory remaps).
NATURAL = "natural"            # tile (i, j) at mesh position (i, j)
SKEW_ROWS = "skew_rows"        # position (i, j) holds tile (i, (i+j)%g)
SKEW_COLS = "skew_cols"        # position (i, j) holds tile ((i+j)%g, j)
STATIONARY_A = "stationary_a"  # position (i, j) holds tile (j, (i+j)%g)
PLACEMENTS = (NATURAL, SKEW_ROWS, SKEW_COLS, STATIONARY_A)


@dataclasses.dataclass(frozen=True)
class _Geom:
    """Static geometry threaded to the shard_map bodies via closure."""
    g: int
    tm: int           # local C tile rows
    tn: int           # local C tile cols
    a_nbr: int        # block-rows per A tile (0 => dense A)
    b_nbr: int        # block-rows per B tile (0 => dense B)
    b_nbc: int        # block-cols per B tile (0 => dense B)
    impl: Optional[str]
    axr: str
    axc: str
    out_dtype: object


# ---------------------------------------------------------------------------
# Local tile math (operand trees hold ONLY arrays)
# ---------------------------------------------------------------------------
def _local_mm(a: Dict, b: Dict, geom: _Geom) -> jnp.ndarray:
    if "dense" in b:
        b_dense = b["dense"]
    else:
        b_dense = kref.densify_raw(b["blocks"], b["rows"], b["cols"],
                                   geom.b_nbr, geom.b_nbc)
    if "dense" in a:
        out = jnp.dot(a["dense"], b_dense, preferred_element_type=jnp.float32)
    else:
        out = kops.bsr_spmm_raw(a["blocks"], a["rows"], a["cols"], b_dense,
                                n_block_rows=geom.a_nbr, impl=geom.impl)
    return out.astype(geom.out_dtype)


def _tree_ppermute(tree: Dict, axis: str, g: int) -> Dict:
    perm = [((d + 1) % g, d) for d in range(g)]
    return {k: lax.ppermute(v, axis, perm) for k, v in tree.items()}


def _tree_bcast(tree: Dict, axis: str, root, my_idx) -> Dict:
    sel = my_idx == root
    return {k: lax.psum(jnp.where(sel, v, jnp.zeros_like(v)), axis)
            for k, v in tree.items()}


def _pvary(x, geom: _Geom):
    return pvary(x, (geom.axr, geom.axc))


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------
# Shared plan cache (defined before the registry: registering over an
# existing algorithm name must evict that name's cached plans).
_PLAN_CACHE: Dict[tuple, "MatmulPlan"] = {}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def _evict_plans_for_algorithm(name: str) -> None:
    for key in [k for k in _PLAN_CACHE if k[0] == name]:
        del _PLAN_CACHE[key]


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered schedule: shard_map body + declarative placement needs.

    ``a_placement`` / ``b_placement`` name the :data:`PLACEMENTS` state each
    operand must be in before the body runs (the handle caches the
    transform); ``unskew_out`` names the inverse placement applied to the
    output; ``wire`` lists which tiles ride the network each inner step
    (feeds :meth:`MatmulPlan.cost_model`); ``wire_amortized`` marks
    schedules whose communication happens once up front (all-gather) rather
    than per step.
    """
    name: str
    body: Callable
    a_placement: str = NATURAL
    b_placement: str = NATURAL
    unskew_out: Optional[str] = None        # None | "rows"
    wire: Tuple[str, ...] = ("a", "b")      # subset of {"a", "b", "c"}
    wire_amortized: bool = False
    style: str = "rdma"                     # "rdma" | "bsp"


class AlgorithmRegistry:
    """Name -> :class:`Algorithm` map driving :func:`matmul` dispatch."""

    def __init__(self):
        self._algorithms: Dict[str, Algorithm] = {}

    def register(self, alg: Algorithm, *, overwrite: bool = False) -> Algorithm:
        for placement, who in ((alg.a_placement, "a"), (alg.b_placement, "b")):
            if placement not in PLACEMENTS:
                raise ValueError(
                    f"algorithm {alg.name!r}: unknown {who}_placement "
                    f"{placement!r}; one of {PLACEMENTS}")
        if alg.name in self._algorithms:
            if not overwrite:
                raise ValueError(f"algorithm {alg.name!r} already registered")
            _evict_plans_for_algorithm(alg.name)
        self._algorithms[alg.name] = alg
        return alg

    def unregister(self, name: str) -> None:
        if self._algorithms.pop(name, None) is not None:
            _evict_plans_for_algorithm(name)

    def get(self, name: str) -> Algorithm:
        try:
            return self._algorithms[name]
        except KeyError:
            raise ValueError(
                f"unknown algorithm {name!r}; one of {self.names()}") from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._algorithms)

    def __contains__(self, name: str) -> bool:
        return name in self._algorithms

    def __iter__(self):
        return iter(self._algorithms.values())

    def __len__(self) -> int:
        return len(self._algorithms)


REGISTRY = AlgorithmRegistry()


def register_algorithm(name: str, *, a_placement: str = NATURAL,
                       b_placement: str = NATURAL,
                       unskew_out: Optional[str] = None,
                       wire: Tuple[str, ...] = ("a", "b"),
                       wire_amortized: bool = False, style: str = "rdma",
                       registry: AlgorithmRegistry = REGISTRY):
    """Decorator registering a shard_map body as a named algorithm."""
    def deco(body):
        registry.register(Algorithm(
            name=name, body=body, a_placement=a_placement,
            b_placement=b_placement, unskew_out=unskew_out, wire=wire,
            wire_amortized=wire_amortized, style=style))
        return body
    return deco


def algorithms() -> Tuple[str, ...]:
    """Names of all registered algorithms (registration order)."""
    return REGISTRY.names()


# ---------------------------------------------------------------------------
# Algorithm bodies (run inside shard_map on local tile views)
# ---------------------------------------------------------------------------
@register_algorithm("summa_bcast", style="bsp")
def _body_summa_bcast(a, b, geom: _Geom):
    """Bulk-synchronous SUMMA (paper SS2.2): a broadcast per inner step."""
    my_row = lax.axis_index(geom.axr)
    my_col = lax.axis_index(geom.axc)

    def step(c, k):
        a_k = _tree_bcast(a, geom.axc, k, my_col)  # bcast A[:, k] along rows
        b_k = _tree_bcast(b, geom.axr, k, my_row)  # bcast B[k, :] along cols
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


@register_algorithm("summa_ag", style="bsp", wire_amortized=True)
def _body_summa_ag(a, b, geom: _Geom):
    """All-gather SUMMA: one big up-front collective, g x tile footprint."""
    a_g = {k: lax.all_gather(v, geom.axc) for k, v in a.items()}
    b_g = {k: lax.all_gather(v, geom.axr) for k, v in b.items()}

    def step(c, k):
        a_k = {kk: v[k] for kk, v in a_g.items()}
        b_k = {kk: v[k] for kk, v in b_g.items()}
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


@register_algorithm("ring_c", a_placement=SKEW_ROWS, b_placement=SKEW_COLS)
def _body_ring_c(a, b, geom: _Geom):
    """Paper Alg 2 (stationary-C): skewed placement + neighbour ppermute."""
    def step(carry, _):
        a_t, b_t, c = carry
        # "async_get_tile" for step k+1, issued before the local matmul so
        # the collective-permute DMA overlaps MXU work (paper SS3.3 prefetch).
        a_n = _tree_ppermute(a_t, geom.axc, geom.g)
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)
        c = c + _local_mm(a_t, b_t, geom)
        return (a_n, b_n, c), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    (_, _, c), _ = lax.scan(step, (a, b, c0), None, length=geom.g)
    return c


@register_algorithm("ring_a", b_placement=STATIONARY_A, unskew_out="rows",
                    wire=("b", "c"))
def _body_ring_a(a, b, geom: _Geom):
    """Paper Alg 1 (stationary-A): B rides the ring, partial C rides back."""
    acc0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)

    def step(carry, _):
        b_t, acc = carry
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)   # prefetch next B tile
        acc = acc + _local_mm(a, b_t, geom)
        # route the partial C tile one hop toward its owner (the TPU
        # replacement for the paper's remote accumulation queue push)
        acc = lax.ppermute(acc, geom.axc,
                           [((d + 1) % geom.g, d) for d in range(geom.g)])
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (b, acc0), None, length=geom.g)
    return acc


# ---------------------------------------------------------------------------
# Distributed-matrix handles
# ---------------------------------------------------------------------------
def _place_bsr(t: TiledBSR, placement: str) -> TiledBSR:
    if placement == NATURAL:
        return t
    if placement in (SKEW_ROWS, SKEW_COLS):
        return skew_bsr(t, placement[len("skew_"):])
    if placement == STATIONARY_A:
        g = t.grid_shape[0]
        i = np.arange(g)[:, None]
        j = np.arange(g)[None, :]
        si, sj = j + 0 * i, (i + j) % g   # position (i,j) <- tile (j,(i+j)%g)
        take = lambda arr: arr[si, sj]
        return TiledBSR(
            blocks=take(t.blocks), rows=take(t.rows), cols=take(t.cols),
            counts=take(t.counts), shape=t.shape, block_size=t.block_size,
            grid_shape=t.grid_shape, capacity=t.capacity,
            logical_shape=t.logical_shape)
    raise ValueError(f"unknown placement {placement!r}; one of {PLACEMENTS}")


def _place_dense(x: jnp.ndarray, g: int, placement: str) -> jnp.ndarray:
    if placement == NATURAL:
        return x
    if placement == SKEW_ROWS:
        return skew_dense(x, g, "rows")
    if placement == SKEW_COLS:
        return skew_dense(x, g, "cols")
    if placement == STATIONARY_A:
        return place_b_for_stationary_a(x, g)
    raise ValueError(f"unknown placement {placement!r}; one of {PLACEMENTS}")


class DistMatrix:
    """A matrix distributed over a square ``g x g`` process grid.

    Subclasses cache placement transforms: ``placed(p)`` materializes the
    operand tree for placement ``p`` at most once per handle, the way the
    paper's DMatrix resolves its pointer directory once at construction.
    """

    kind = "abstract"

    @property
    def g(self) -> int:
        raise NotImplementedError

    @property
    def shape(self) -> Tuple[int, int]:      # padded global shape
        raise NotImplementedError

    @property
    def logical_shape(self) -> Tuple[int, int]:
        raise NotImplementedError

    @property
    def tile_shape(self) -> Tuple[int, int]:
        s = self.shape
        return s[0] // self.g, s[1] // self.g

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def abstract_key(self) -> tuple:
        """Hashable abstract signature (shapes/dtypes, no data) for caching."""
        raise NotImplementedError

    def placements(self) -> Tuple[str, ...]:
        """Placement states materialized so far (diagnostics/tests)."""
        return tuple(self._placed)


class DistBSR(DistMatrix):
    """Handle for a block-sparse distributed matrix (wraps TiledBSR)."""

    kind = "bsr"

    def __init__(self, tiled: TiledBSR):
        if tiled.grid_shape[0] != tiled.grid_shape[1]:
            raise ValueError("square process grid required, got "
                             f"{tiled.grid_shape}")
        self.tiled = tiled
        self._placed: Dict[str, Dict[str, jnp.ndarray]] = {}

    @classmethod
    def from_tiled(cls, tiled: TiledBSR) -> "DistBSR":
        return cls(tiled)

    @classmethod
    def from_dense(cls, dense, *, g: int, block_size: int,
                   capacity: Optional[int] = None, dtype=None) -> "DistBSR":
        return cls(TiledBSR.from_dense(dense, ProcessGrid(g, g), block_size,
                                       capacity=capacity, dtype=dtype))

    @property
    def g(self) -> int:
        return self.tiled.grid_shape[0]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.tiled.shape

    @property
    def logical_shape(self) -> Tuple[int, int]:
        return self.tiled.logical_shape or self.tiled.shape

    @property
    def dtype(self):
        return self.tiled.dtype

    @property
    def block_size(self) -> int:
        return self.tiled.block_size

    @property
    def capacity(self) -> int:
        return self.tiled.capacity

    @property
    def counts(self):
        return self.tiled.counts

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        tree = self._placed.get(placement)
        if tree is None:
            t = _place_bsr(self.tiled, placement)
            tree = {"blocks": t.blocks, "rows": t.rows, "cols": t.cols}
            self._placed[placement] = tree
        return tree

    def abstract_key(self) -> tuple:
        t = self.tiled
        return ("bsr", t.shape, t.grid_shape, t.block_size, t.capacity,
                jnp.dtype(t.dtype).name)


class DistDense(DistMatrix):
    """Handle for a dense distributed matrix (grid-padded global array)."""

    kind = "dense"

    def __init__(self, data, g: int,
                 logical_shape: Optional[Tuple[int, int]] = None):
        data = jnp.asarray(data)
        if data.ndim != 2:
            raise ValueError(f"expected a 2-D array, got shape {data.shape}")
        if data.shape[0] % g or data.shape[1] % g:
            raise ValueError(
                f"padded shape {data.shape} not divisible by grid size {g}; "
                "use DistDense.from_global to pad")
        self.data = data
        self._g = g
        self._logical = tuple(logical_shape or data.shape)
        self._placed: Dict[str, Dict[str, jnp.ndarray]] = {}

    @classmethod
    def from_global(cls, x, g: int, *, rows_pad: Optional[int] = None,
                    cols_pad: Optional[int] = None) -> "DistDense":
        """Wrap a global array, zero-padding each dim to a multiple of g."""
        x = jnp.asarray(x)
        m, n = x.shape
        rp = pad_to_multiple(m, g) if rows_pad is None else rows_pad
        cp = pad_to_multiple(n, g) if cols_pad is None else cols_pad
        if rp < m or cp < n or rp % g or cp % g:
            raise ValueError(f"bad padded shape ({rp}, {cp}) for array "
                             f"{x.shape} on a {g}x{g} grid")
        if (rp, cp) != (m, n):
            x = jnp.zeros((rp, cp), x.dtype).at[:m, :n].set(x)
        return cls(x, g, logical_shape=(m, n))

    @classmethod
    def for_rhs(cls, x, a: DistMatrix, *, allow_pad: bool = False
                ) -> "DistDense":
        """Wrap the right operand of ``a @ x``, matching a's padded K dim.

        The inner dimension must equal a's logical or padded column count;
        anything smaller is only zero-padded with an explicit
        ``allow_pad=True`` (silent padding hides shape bugs).
        """
        x = jnp.asarray(x)
        k = x.shape[0]
        k_pad, k_log = a.shape[1], a.logical_shape[1]
        if k > k_pad:
            raise ValueError(
                f"inner dimensions disagree: right operand has {k} rows, "
                f"left operand has only {k_pad} (padded) columns")
        if k not in (k_pad, k_log) and not allow_pad:
            raise ValueError(
                f"inner dimension mismatch: right operand has {k} rows but "
                f"the left operand has {k_log} logical / {k_pad} padded "
                "columns; pass allow_pad=True to zero-pad explicitly")
        return cls.from_global(x, a.g, rows_pad=k_pad)

    @property
    def g(self) -> int:
        return self._g

    @property
    def shape(self) -> Tuple[int, int]:
        return self.data.shape

    @property
    def logical_shape(self) -> Tuple[int, int]:
        return self._logical

    @property
    def dtype(self):
        return self.data.dtype

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        tree = self._placed.get(placement)
        if tree is None:
            tree = {"dense": _place_dense(self.data, self._g, placement)}
            self._placed[placement] = tree
        return tree

    def abstract_key(self) -> tuple:
        return ("dense", self.data.shape, self._g,
                jnp.dtype(self.data.dtype).name)


# ---------------------------------------------------------------------------
# Mesh preparation / validation
# ---------------------------------------------------------------------------
def validate_mesh(mesh, g: int, axis_row: str, axis_col: str) -> None:
    """Fail fast (and clearly) on a mesh that can't carry the g x g grid."""
    names = tuple(mesh.axis_names)
    if axis_row not in names or axis_col not in names:
        raise ValueError(
            f"mesh axes {names} do not include the required axes "
            f"({axis_row!r}, {axis_col!r}); build one with "
            f"make_grid_mesh({g}, {axis_row!r}, {axis_col!r})")
    if len(names) != 2:
        raise ValueError(
            f"expected a 2-axis ({axis_row!r}, {axis_col!r}) mesh, got axes "
            f"{names}")
    shape = dict(mesh.shape)
    got = (shape[axis_row], shape[axis_col])
    if got != (g, g):
        raise ValueError(
            f"mesh shape {axis_row}={got[0]}, {axis_col}={got[1]} does not "
            f"match the {g}x{g} process grid of the operands")


def _prep_mesh(mesh, g: int, axis_row: str, axis_col: str):
    if mesh is None:
        return make_grid_mesh(g, axis_row, axis_col)
    validate_mesh(mesh, g, axis_row, axis_col)
    return mesh


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------
_TRACE_HOOKS: List[Callable] = []


def add_trace_hook(hook: Callable) -> Callable:
    """Register ``hook(plan)`` to fire once per executable (re)trace."""
    _TRACE_HOOKS.append(hook)
    return hook


def remove_trace_hook(hook: Callable) -> None:
    _TRACE_HOOKS.remove(hook)


def _tree_keys(abstract_key: tuple) -> Tuple[str, ...]:
    return ("blocks", "rows", "cols") if abstract_key[0] == "bsr" \
        else ("dense",)


def _specs_for_keys(keys: Tuple[str, ...], axr: str, axc: str) -> Dict:
    out = {}
    for k in keys:
        if k == "dense":
            out[k] = P(axr, axc)
        elif k == "blocks":
            out[k] = P(axr, axc, None, None, None)
        else:  # rows / cols
            out[k] = P(axr, axc, None)
    return out


def _local_view(tree: Dict) -> Dict:
    """Strip the leading (1, 1) grid dims of TiledBSR leaves inside shard_map."""
    return {k: (v if k == "dense" else v[0, 0]) for k, v in tree.items()}


def _tile_bytes(abstract_key: tuple) -> int:
    if abstract_key[0] == "bsr":
        _, _, _, bs, cap, dt = abstract_key
        return cap * bs * bs * np.dtype(dt).itemsize + cap * 2 * 4
    _, shape, g, dt = abstract_key
    return (shape[0] // g) * (shape[1] // g) * np.dtype(dt).itemsize


class MatmulPlan:
    """A reusable distributed multiply: placement + one compiled executable.

    Create via :func:`plan_matmul`; execute with ``plan(a, b)``.  The
    executable is ``jax.jit(shard_map(body))`` built once at plan time, so
    repeated calls with the same abstract operand shapes re-use the compiled
    program (``plan.traces`` counts actual traces).
    """

    def __init__(self, algorithm: Algorithm, geom: _Geom, mesh,
                 a_key: tuple, b_key: tuple, allow_pad: bool = False):
        self.algorithm = algorithm
        self.geom = geom
        self.mesh = mesh
        self._a_key = a_key
        self._b_key = b_key
        self._allow_pad = allow_pad
        self.traces = 0
        body = algorithm.body

        def fn(a, b):
            self.traces += 1          # runs at trace time only
            for hook in list(_TRACE_HOOKS):
                hook(self)
            return body(_local_view(a), _local_view(b), geom)

        self._exec = jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(_specs_for_keys(_tree_keys(a_key), geom.axr, geom.axc),
                      _specs_for_keys(_tree_keys(b_key), geom.axr, geom.axc)),
            out_specs=P(geom.axr, geom.axc),
            # pallas_call's out_shape carries no vma annotation; the engine's
            # collectives are explicit, so skip the varying-axes checker.
            check_vma=False))

    @property
    def kind(self) -> str:
        """"spmm" | "spgemm" | "dense" — what this plan dispatches to."""
        a_sparse = self._a_key[0] == "bsr"
        b_sparse = self._b_key[0] == "bsr"
        if a_sparse:
            return "spgemm" if b_sparse else "spmm"
        return "dense"

    def __call__(self, a, b) -> jnp.ndarray:
        a_h, b_h = _coerce_pair(a, b, g=self.geom.g,
                                allow_pad=self._allow_pad)
        if (a_h.abstract_key(), b_h.abstract_key()) != (self._a_key,
                                                        self._b_key):
            raise ValueError(
                "operands do not match this plan's abstract shapes "
                f"(plan: {self._a_key} @ {self._b_key}, got "
                f"{a_h.abstract_key()} @ {b_h.abstract_key()}); build a new "
                "plan with plan_matmul")
        c = self._exec(a_h.placed(self.algorithm.a_placement),
                       b_h.placed(self.algorithm.b_placement))
        return self._epilogue(c, a_h, b_h)

    def _epilogue(self, c: jnp.ndarray, a_h: DistMatrix,
                  b_h: DistMatrix) -> jnp.ndarray:
        """Shared output fix-up: invert the output skew, crop padding.

        One copy for all operand kinds — the sparse and dense paths get
        identical ``logical_shape`` cropping semantics.
        """
        if self.algorithm.unskew_out == "rows":
            c = unskew_c_rows(c, self.geom.g)
        elif self.algorithm.unskew_out is not None:
            raise ValueError(
                f"unknown unskew_out {self.algorithm.unskew_out!r}")
        return c[:a_h.logical_shape[0], :b_h.logical_shape[1]]

    # ------------------------------------------------------------- analysis
    def cost_model(self, a: Optional[DistBSR] = None) -> Dict[str, float]:
        """Per-step volume / flops of one plan execution (per device).

        Flop counts are the *executed* (padding included) MXU work, the
        quantity the static scheduler balances.  Pass the sparse left-hand
        handle to also get the paper's Fig-1 per-stage vs end-to-end
        imbalance from its tile counts (feeds ``core/schedule.py``).
        """
        geom, alg = self.geom, self.algorithm
        g = geom.g
        a_bytes = _tile_bytes(self._a_key)
        b_bytes = _tile_bytes(self._b_key)
        c_bytes = geom.tm * geom.tn * np.dtype(geom.out_dtype).itemsize
        if self._a_key[0] == "bsr":
            bs, cap = self._a_key[3], self._a_key[4]
            flops_step = 2 * cap * bs * bs * geom.tn
        else:
            tk = self._a_key[1][1] // g
            flops_step = 2 * geom.tm * tk * geom.tn
        tiles = {"a": a_bytes, "b": b_bytes, "c": c_bytes}
        step_bytes = sum(tiles[t] for t in alg.wire)
        if alg.wire_amortized:
            step_bytes = step_bytes * (g - 1) / g
        total_flops = float(flops_step * g)
        total_bytes = float(step_bytes * g)
        out = {
            "steps": float(g),
            "flops_per_step": float(flops_step),
            "net_bytes_per_step": float(step_bytes),
            "total_flops": total_flops,
            "total_net_bytes": total_bytes,
            "ai_net": total_flops / total_bytes if total_bytes else float("inf"),
            "ai_local": total_flops / (g * (a_bytes + b_bytes) + c_bytes),
        }
        if isinstance(a, DistBSR):
            per_stage, end_to_end = _schedule.stage_imbalance(
                np.asarray(a.counts, dtype=np.float64))
            out["per_stage_imbalance"] = per_stage
            out["end_to_end_imbalance"] = end_to_end
        return out

    def predicted_perf(self, machine: "_roofline.Machine") -> Dict[str, float]:
        """Paper SS4 inter-node roofline prediction for this plan."""
        cm = self.cost_model()
        peak = _roofline.local_peak(cm["ai_local"], machine)
        return {
            "perf": _roofline.internode_roofline(cm["ai_net"],
                                                 cm["ai_local"], machine),
            "local_peak": peak,
            "net_bound": cm["ai_net"] * machine.net_bw < peak,
            **cm,
        }


# ---------------------------------------------------------------------------
# Operand coercion + plan cache + public entry points
# ---------------------------------------------------------------------------
def _coerce_pair(a, b, *, g: Optional[int] = None, allow_pad: bool = False
                 ) -> Tuple[DistMatrix, DistMatrix]:
    if isinstance(a, DistMatrix):
        a_h = a
    elif isinstance(a, TiledBSR):
        a_h = DistBSR.from_tiled(a)
    else:
        arr = jnp.asarray(a)
        if g is None:
            raise ValueError(
                "a dense left operand needs g=<grid size> or a DistDense "
                "handle (DistDense.from_global)")
        a_h = DistDense.from_global(arr, g)
    if g is not None and a_h.g != g:
        raise ValueError(f"left operand lives on a {a_h.g}x{a_h.g} grid, "
                         f"but g={g} was requested")

    if isinstance(b, DistMatrix):
        b_h = b
    elif isinstance(b, TiledBSR):
        b_h = DistBSR.from_tiled(b)
    else:
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h, allow_pad=allow_pad)

    if isinstance(a_h, DistDense) and isinstance(b_h, DistBSR):
        raise NotImplementedError(
            "dense x sparse is not supported; compute the transposed "
            "product sparse x dense instead (B^T A^T = (AB)^T)")
    if a_h.g != b_h.g:
        raise ValueError(f"operands on different process grids: "
                         f"{a_h.g}x{a_h.g} vs {b_h.g}x{b_h.g}")
    if a_h.shape[1] != b_h.shape[0]:
        raise ValueError(
            f"inner (padded) dimensions disagree: A is {a_h.shape}, B is "
            f"{b_h.shape}; build the right operand with "
            "DistDense.for_rhs(b, a) to match A's padding")
    return a_h, b_h


def _geometry(a_h: DistMatrix, b_h: DistMatrix, *, impl: Optional[str],
              axis_row: str, axis_col: str) -> _Geom:
    a_bsr = isinstance(a_h, DistBSR)
    b_bsr = isinstance(b_h, DistBSR)
    return _Geom(
        g=a_h.g, tm=a_h.tile_shape[0], tn=b_h.tile_shape[1],
        a_nbr=(a_h.tile_shape[0] // a_h.block_size) if a_bsr else 0,
        b_nbr=(b_h.tile_shape[0] // b_h.block_size) if b_bsr else 0,
        b_nbc=(b_h.tile_shape[1] // b_h.block_size) if b_bsr else 0,
        impl=impl, axr=axis_row, axc=axis_col,
        out_dtype=jnp.promote_types(a_h.dtype, b_h.dtype))


def _mesh_key(mesh):
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return id(mesh)


def plan_matmul(a, b, *, algorithm: str = "ring_c", mesh=None,
                impl: Optional[str] = None, g: Optional[int] = None,
                axis_row: str = "row", axis_col: str = "col",
                allow_pad: bool = False, cache: bool = True) -> MatmulPlan:
    """Build (or fetch from the shared cache) a plan for ``a @ b``.

    ``a`` / ``b`` may be :class:`DistMatrix` handles (preferred — placement
    caches live on the handle), raw :class:`TiledBSR` values, or plain dense
    arrays (``g`` required when both are dense).  ``cache=False`` forces a
    fresh plan — i.e. the legacy per-call behaviour, retracing every time.
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    alg = REGISTRY.get(algorithm)
    mesh = _prep_mesh(mesh, a_h.g, axis_row, axis_col)
    key = (alg.name, impl, axis_row, axis_col, allow_pad, _mesh_key(mesh),
           a_h.abstract_key(), b_h.abstract_key())
    if cache:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            return plan
    plan = MatmulPlan(alg, _geometry(a_h, b_h, impl=impl, axis_row=axis_row,
                                     axis_col=axis_col),
                      mesh, a_h.abstract_key(), b_h.abstract_key(),
                      allow_pad=allow_pad)
    if cache:
        _PLAN_CACHE[key] = plan
    return plan


def matmul(a, b, *, algorithm: str = "ring_c", mesh=None,
           impl: Optional[str] = None, g: Optional[int] = None,
           axis_row: str = "row", axis_col: str = "col",
           allow_pad: bool = False) -> jnp.ndarray:
    """Polymorphic distributed ``a @ b``.

    Dispatches sparse x dense -> SpMM, sparse x sparse -> SpGEMM, and
    dense x dense -> the dense engine, all through the shared plan cache:
    repeated calls with the same abstract shapes never re-trace.
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    plan = plan_matmul(a_h, b_h, algorithm=algorithm, mesh=mesh, impl=impl,
                       axis_row=axis_row, axis_col=axis_col,
                       allow_pad=allow_pad)
    return plan(a_h, b_h)
