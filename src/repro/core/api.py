"""Plan-based public API for the distributed sparse-matmul engine.

The paper's NVSHMEM implementation builds its algorithms on *persistent*
distributed-matrix objects (BCL ``DMatrix``) with a global pointer
directory: placement and skew are decided once, at construction, and every
multiply afterwards is pure communication + compute.  This module is the
TPU analogue of that design:

* :class:`DistBSR` / :class:`DistDense` — distributed-matrix *handles*
  wrapping a :class:`~repro.core.bsr.TiledBSR` / a grid-padded dense array.
  A handle carries the process-grid geometry, dtype, logical (uncropped)
  shape and — crucially — a cache of *placements* (natural / skew-rows /
  skew-cols / stationary-A), so the paper's ``k_offset`` skew is
  materialized at most once per operand and reused across calls.
* :func:`plan_matmul` -> :class:`MatmulPlan` — precomputes the static
  :class:`_Geom`, operand pack specs and placement requirements, and holds
  one jit-compiled ``shard_map`` executable: calling the plan again with the
  same abstract shapes never re-traces.  ``plan.cost_model()`` exposes the
  per-step network volume / flops that feed ``core/roofline.py`` and
  ``core/schedule.py``.
* :func:`matmul` — one polymorphic entry point dispatching
  sparse x dense -> SpMM, sparse x sparse -> SpGEMM and dense x dense ->
  the dense engine through :data:`REGISTRY` (an :class:`AlgorithmRegistry`).
  Algorithms register declaratively with their required operand placements,
  output unskew and per-step wire traffic, so new schedules (work-stealing
  layouts, stationary-B, ...) plug in without touching the engine.

The algorithm family (see the body docstrings): ``summa_bcast`` /
``summa_ag`` are the bulk-synchronous baselines, ``ring_c`` / ``ring_a``
the RDMA-style stationary-C / stationary-A rings with placement-time
``k_offset`` skew and prefetch via early ``ppermute``, ``ring_c_bidir`` a
bidirectional stationary-C ring that splits the output into column
half-panels circulating in opposite directions (full-duplex links), and
``steal3d`` the static realization of the paper's SS3.4 locality-aware
work stealing: a plan-time LPT assignment of the 3D (i, k, j) work grid
(:mod:`repro.core.steal3d`) executed as per-device pair lists with static
moved-tile and owner-reduction ppermute rounds.  ``plan_matmul(...,
algorithm="auto")`` scores every registered schedule with the
alpha-beta-gamma cost model (:func:`auto_select`) and builds the cheapest
— the static analogue of Bharadwaj et al.'s observation that the best
distributed sparse schedule flips with sparsity and aspect ratio.

SpGEMM additionally supports **sparse outputs** (``output="sparse"`` /
``"auto"``): a host-side symbolic phase (:mod:`repro.core.symbolic`,
re-exported here as :func:`symbolic_spgemm`) predicts C's block structure
from the operands' structures, allocates a capacity-bounded packed layout,
and the numeric phase (``ops.bsr_pair_accumulate``) scatter-accumulates
matched block products straight into it — no dense C tile, no B
densification, and the plan returns a :class:`DistBSR` so chained
multiplies ``matmul(matmul(A, A), A)`` stay packed end to end.  See
DESIGN.md "Symbolic/numeric SpGEMM".

Plans can additionally use the **packed wire format** (``wire="packed"``;
:mod:`repro.core.wire`): every sparse operand shipment — ring ppermutes,
SUMMA broadcasts/all-gathers, steal3d panel gathers, moved-tile rounds
and partial-C reductions, and the sparse-output pair traffic — carries
only *real* blocks at a bucketed wire capacity, with plan-time consume
maps (static gathers) reconstructing structure on the receiver.  Packed
plans are specialized to the operands' structure (fingerprints join the
cache key); ``wire="auto"`` packs the already-structure-keyed
sparse-output plans and keeps dense-output plans padded so bucketed
handles keep sharing cached executables.

Two hot-loop invariants the bodies maintain (asserted by the jaxpr test in
``tests/test_api.py``): sparse A tiles arrive *pre-augmented* from
:class:`~repro.core.bsr.TiledBSR` (no coverage concat+sort inside the
scanned step), and sparse B tiles never scatter inside the scan — padded
plans densify once per ring pass before the scan (``_densify_b``), packed
plans densify per step by a static *gather* (``ops.densify_packed``).

The legacy free functions in ``core/spmm.py`` remain as deprecated shims
delegating to the shared plan cache here.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import obs as _obs
from ..compat import pvary, shard_map
from ..kernels import ops as kops
from ..kernels import ref as kref
from . import roofline as _roofline
from . import schedule as _schedule
from . import steal3d as _steal3d
from . import symbolic as _symbolic
from . import wire as _wire
from .bsr import TiledBSR
from .dist import (make_grid_mesh, place_b_for_stationary_a, skew_bsr,
                   skew_dense, unskew_c_rows)
from .grid import ProcessGrid, bucket_capacity, ceil_div, pad_to_multiple
from .symbolic import (SymbolicProduct, predicted_density,  # re-export
                       symbolic_spgemm)                     # (public)
from .wire import PackedOperand, wire_capacity              # re-export

__all__ = [
    "NATURAL", "SKEW_ROWS", "SKEW_COLS", "STATIONARY_A", "PLACEMENTS",
    "DistMatrix", "DistBSR", "DistDense",
    "Algorithm", "AlgorithmRegistry", "REGISTRY", "register_algorithm",
    "algorithms", "sparse_algorithms", "auto_select", "recommended_balance",
    "MatmulPlan", "plan_matmul", "matmul",
    "SymbolicProduct", "symbolic_spgemm", "predicted_density",
    "PackedOperand", "wire_capacity",
    "add_trace_hook", "remove_trace_hook",
    "clear_plan_cache", "plan_cache_size", "cache_stats",
    "invalidate_plans", "reshard",
    "validate_mesh",
]

# Placement states a DistMatrix can hold (the paper's directory remaps).
NATURAL = "natural"            # tile (i, j) at mesh position (i, j)
SKEW_ROWS = "skew_rows"        # position (i, j) holds tile (i, (i+j)%g)
SKEW_COLS = "skew_cols"        # position (i, j) holds tile ((i+j)%g, j)
STATIONARY_A = "stationary_a"  # position (i, j) holds tile (j, (i+j)%g)
PLACEMENTS = (NATURAL, SKEW_ROWS, SKEW_COLS, STATIONARY_A)


@dataclasses.dataclass(frozen=True)
class _Geom:
    """Static geometry threaded to the shard_map bodies via closure."""
    g: int
    tm: int           # local C tile rows
    tn: int           # local C tile cols
    a_nbr: int        # block-rows per A tile (0 => dense A)
    b_nbr: int        # block-rows per B tile (0 => dense B)
    b_nbc: int        # block-cols per B tile (0 => dense B)
    impl: Optional[str]
    axr: str
    axc: str
    out_dtype: object
    c_store: int = 0  # packed C slots per tile (sparse-output plans only)
    overlap: bool = False
    # split-step double-buffered bodies (plan_matmul(overlap=...)): each
    # scanned step issues step t+1's collective BEFORE step t's
    # accumulate, carrying a two-slot buffer per stream, so XLA's async
    # collectives can hide the transfer under the local matmul


# ---------------------------------------------------------------------------
# Local tile math (operand trees hold ONLY arrays)
# ---------------------------------------------------------------------------
def _densify_b(b: Dict, geom: _Geom) -> Dict:
    """Densify a sparse B tile ONCE, before the scanned ring steps.

    Every schedule consumes B as a dense tile; doing the scatter here means
    each B tile is densified at most once per ring pass, and the scanned
    step body stays free of scatter/sort work (asserted by the jaxpr test).
    The densified tile is also what rides the wire — see ``_cost_model``.
    """
    if "dense" in b:
        return b
    return {"dense": kref.densify_raw(b["blocks"], b["rows"], b["cols"],
                                      geom.b_nbr, geom.b_nbc)}


def _local_mm(a: Dict, b: Dict, geom: _Geom) -> jnp.ndarray:
    b_dense = b["dense"]    # bodies pre-densify sparse B via _densify_b
    if "dense" in a:
        out = jnp.dot(a["dense"], b_dense, preferred_element_type=jnp.float32)
    else:
        # TiledBSR tiles are pre-augmented/pre-sorted at tiling time, so the
        # kernel wrapper must not redo coverage inside the compiled loop.
        out = kops.bsr_spmm_raw(a["blocks"], a["rows"], a["cols"], b_dense,
                                n_block_rows=geom.a_nbr, impl=geom.impl,
                                augment=False)
    return out.astype(geom.out_dtype)


def _tree_ppermute(tree: Dict, axis: str, g: int, sign: int = 1) -> Dict:
    perm = [((d + sign) % g, d) for d in range(g)]
    return {k: lax.ppermute(v, axis, perm) for k, v in tree.items()}


def _tree_bcast(tree: Dict, axis: str, root, my_idx) -> Dict:
    sel = my_idx == root
    return {k: lax.psum(jnp.where(sel, v, jnp.zeros_like(v)), axis)
            for k, v in tree.items()}


def _pvary(x, geom: _Geom):
    return pvary(x, (geom.axr, geom.axc))


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------
class _LRUCache:
    """Small bounded cache: access-ordered, with an eviction counter.

    Plans, symbolic products and steal plans are all keyed (in part) on
    sparsity *structure*, so a long-running serving process that sees a
    stream of distinct structures would otherwise grow these caches — and
    the jitted executables / host index arrays they pin — without limit.
    Eviction is safe by construction: every entry is rebuilt on demand
    from its operands, so a cap only costs a rebuild on re-miss.
    ``evictions`` counts capacity evictions (not explicit invalidation)
    for observability; ``hits`` / ``misses`` count ``get`` outcomes so a
    serving layer can report plan reuse rates (hits/(hits+misses)) and
    plans-per-second without instrumenting every call site.  ``clear()``
    resets entries but keeps all counters.
    """

    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self.evictions = 0
        self.hits = 0
        self.misses = 0
        self._d: "collections.OrderedDict" = collections.OrderedDict()

    def get(self, key, default=None):
        try:
            value = self._d[key]
        except KeyError:
            self.misses += 1
            return default
        self.hits += 1
        self._d.move_to_end(key)
        return value

    def __setitem__(self, key, value) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self.evictions += 1

    def __delitem__(self, key) -> None:
        del self._d[key]

    def __contains__(self, key) -> bool:
        return key in self._d

    def __len__(self) -> int:
        return len(self._d)

    def __iter__(self):
        return iter(list(self._d))

    def clear(self) -> None:
        self._d.clear()

    def reset_counters(self) -> None:
        """Zero hit/miss/eviction counters (entries stay).  Lets a serving
        process window its plan-reuse rate without dropping hot plans."""
        self.evictions = 0
        self.hits = 0
        self.misses = 0


# Cache caps: small multiples of what a serving process legitimately keeps
# hot (a handful of operand structures x a few schedules/outputs each).
PLAN_CACHE_MAX = 128
SYMBOLIC_CACHE_MAX = 32
DENSITY_CACHE_MAX = 256
STEAL_CACHE_MAX = 32

# Shared plan cache (defined before the registry: registering over an
# existing algorithm name must evict that name's cached plans).
_PLAN_CACHE = _LRUCache(PLAN_CACHE_MAX)
# Symbolic-phase results, keyed on the operands' structure fingerprints
# (sparsity structure, not values): repeated sparse-output plans for the
# same structures skip the host-side pair-list construction.  Density-only
# results (the cheap prefix consulted by output="auto") cache separately so
# auto decisions that resolve to dense never build pair lists.
_SYMBOLIC_CACHE = _LRUCache(SYMBOLIC_CACHE_MAX)
_DENSITY_CACHE = _LRUCache(DENSITY_CACHE_MAX)
# steal3d assignments + pair lists, keyed on abstract shapes and (for
# sparse A) the structure fingerprint: repeated plans / auto_select scores
# for the same operands skip the host-side LPT + list construction.
_STEAL_CACHE = _LRUCache(STEAL_CACHE_MAX)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _SYMBOLIC_CACHE.clear()
    _DENSITY_CACHE.clear()
    _STEAL_CACHE.clear()


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def cache_stats(reset: bool = False) -> Dict[str, Dict[str, int]]:
    """Sizes, caps, hit/miss and eviction counts of the plan-layer caches.

    ``reset=True`` zeroes the hit/miss/eviction counters *after* reading
    them (cache entries stay), so long-running serving processes can window
    plan-reuse rates without a process restart.  The returned dict always
    holds the pre-reset values.
    """
    caches = (("plans", _PLAN_CACHE), ("symbolic", _SYMBOLIC_CACHE),
              ("density", _DENSITY_CACHE), ("steal", _STEAL_CACHE))
    out = {name: {"size": len(c), "maxsize": c.maxsize,
                  "evictions": c.evictions,
                  "hits": c.hits, "misses": c.misses}
           for name, c in caches}
    if reset:
        for _, c in caches:
            c.reset_counters()
    return out


# The plan caches surface in obs snapshots as a pull-time callback: the
# registry reads cache_stats() lazily, so there is no per-hit instrument
# update and no duplicate counter state.
_obs.registry().register_callback("plan_caches", cache_stats)

# Machine preset scoring the *predicted* side of drift records (measured
# side is always the blocking wall clock).  Default matches the bench
# tables' predicted_s_v5e column; harnesses on other hardware override.
_DRIFT_MACHINE: Optional["_roofline.Machine"] = None


def set_drift_machine(machine) -> None:
    """Set the Machine used for the predicted side of obs drift records
    (``None`` restores the TPU_V5E default)."""
    global _DRIFT_MACHINE
    _DRIFT_MACHINE = machine


def _key_g(abstract_key) -> Optional[int]:
    """Grid size of a handle abstract key (None for unrecognized keys)."""
    if not isinstance(abstract_key, tuple) or not abstract_key:
        return None
    if abstract_key[0] == "bsr":
        return int(abstract_key[2][0])
    if abstract_key[0] == "dense":
        return int(abstract_key[2])
    return None


def invalidate_plans(*, algorithm: Optional[str] = None,
                     structure: Optional[str] = None,
                     g: Optional[int] = None) -> int:
    """Keyed plan-cache invalidation: evict only the entries matching every
    given filter (AND semantics; at least one filter is required).

    * ``algorithm`` — a registry name: entries whose schedule it is.
    * ``structure`` — a structure fingerprint (``DistBSR.structure_key()``):
      entries planned against that sparsity structure, including the
      symbolic/density/steal side caches keyed on fingerprints.
    * ``g`` — a grid size: entries planned for a g x g mesh (the filter a
      mesh-shrink recovery uses to drop every plan of the lost grid).

    This is the elastic replanner's eviction primitive: a drift-triggered
    re-fit drops only the algorithm whose cost model moved, a device-loss
    recovery drops only the dead grid's plans, and everything else stays
    hot.  Returns the number of entries evicted across all caches.
    """
    if algorithm is None and structure is None and g is None:
        raise ValueError(
            "invalidate_plans requires at least one of algorithm=, "
            "structure=, g= (use clear_plan_cache() to drop everything)")

    def plan_key_matches(k) -> bool:
        if algorithm is not None and k[0] != algorithm:
            return False
        if g is not None and _key_g(k[7]) != g and _key_g(k[8]) != g:
            return False
        if structure is not None and structure not in k[9:]:
            return False
        return True

    evicted = 0
    for key in [k for k in _PLAN_CACHE if plan_key_matches(k)]:
        del _PLAN_CACHE[key]
        evicted += 1
    # Side caches are keyed on fingerprints/abstract shapes, not algorithm:
    # sweep them only for structure / grid filters.
    if structure is not None or g is not None:
        for key in [k for k in _STEAL_CACHE
                    if (structure is None or structure == k[2])
                    and (g is None or _key_g(k[0]) == g)]:
            del _STEAL_CACHE[key]
            evicted += 1
        if algorithm is None and structure is not None:
            for cache in (_SYMBOLIC_CACHE, _DENSITY_CACHE):
                for key in [k for k in cache if structure in k]:
                    del cache[key]
                    evicted += 1
    return evicted


def _evict_plans_for_algorithm(name: str) -> None:
    invalidate_plans(algorithm=name)


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered schedule: shard_map body + declarative placement needs.

    ``a_placement`` / ``b_placement`` name the :data:`PLACEMENTS` state each
    operand must be in before the body runs (the handle caches the
    transform); ``unskew_out`` names the inverse placement applied to the
    output; ``wire`` lists which tiles ride the network each inner step
    (repeats allowed — ``ring_c_bidir`` ships A in both directions; feeds
    :meth:`MatmulPlan.cost_model`); ``wire_amortized`` marks schedules whose
    communication happens once up front (all-gather) rather than per step;
    ``duplex=2`` marks schedules that split traffic over both directions of
    the full-duplex links, halving serialized wire time.
    """
    name: str
    body: Callable
    a_placement: str = NATURAL
    b_placement: str = NATURAL
    unskew_out: Optional[str] = None        # None | "rows"
    wire: Tuple[str, ...] = ("a", "b")      # tile names from {"a", "b", "c"}
    wire_amortized: bool = False
    style: str = "rdma"                     # "rdma" | "bsp"
    duplex: int = 1                         # link directions used per step
    msgs_per_step: Optional[int] = None     # alpha-term count; len(wire) if
                                            # None (bidir splits B: 4 msgs)
    sparse_body: Optional[Callable] = None  # packed-output SpGEMM body
    k_order: Optional[Callable] = None      # (i, j, t, g) -> inner index k
                                            # of step t on device (i, j);
                                            # schedules the symbolic phase's
                                            # pair lists (sparse_body only)
    balance_axis: str = "rows"              # operand balance this schedule
                                            # benefits from (planner hint)
    static_planner: Optional[Callable] = None
                                            # (a_h, b_h, geom, wire) ->
                                            # StealPlan: plan-time builder
                                            # of a static work-grid
                                            # dispatch; the body then runs
                                            # as body(a, b, aux, geom,
                                            # steal_plan)
    cost_fn: Optional[Callable] = None      # (alg, geom, a_h, b_h, wire)
                                            # -> cost dict, replacing the
                                            # generic _cost_model for
                                            # schedules whose cost is
                                            # structure-dependent (steal3d)
    packed_body: Optional[Callable] = None  # packed-wire dense-output body
                                            # body(a, b, aux, geom); aux is
                                            # the wire_planner's array dict
    packable: Tuple[str, ...] = ()          # operands this schedule can
                                            # ship packed ("a"/"b"); the
                                            # sparse-output path packs both
                                            # operands for every schedule
    wire_planner: Optional[Callable] = None
                                            # (a_po, b_po, geom) -> aux
                                            # dict of [g, g, ...] arrays
                                            # (consume maps for the packed
                                            # body; None po => operand not
                                            # packed on this plan)


class AlgorithmRegistry:
    """Name -> :class:`Algorithm` map driving :func:`matmul` dispatch."""

    def __init__(self):
        self._algorithms: Dict[str, Algorithm] = {}

    def register(self, alg: Algorithm, *, overwrite: bool = False) -> Algorithm:
        for placement, who in ((alg.a_placement, "a"), (alg.b_placement, "b")):
            if placement not in PLACEMENTS:
                raise ValueError(
                    f"algorithm {alg.name!r}: unknown {who}_placement "
                    f"{placement!r}; one of {PLACEMENTS}")
        if alg.name in self._algorithms:
            if not overwrite:
                raise ValueError(f"algorithm {alg.name!r} already registered")
            _evict_plans_for_algorithm(alg.name)
        self._algorithms[alg.name] = alg
        return alg

    def unregister(self, name: str) -> None:
        if self._algorithms.pop(name, None) is not None:
            _evict_plans_for_algorithm(name)

    def get(self, name: str) -> Algorithm:
        try:
            return self._algorithms[name]
        except KeyError:
            raise ValueError(
                f"unknown algorithm {name!r}; one of {self.names()}") from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._algorithms)

    def __contains__(self, name: str) -> bool:
        return name in self._algorithms

    def __iter__(self):
        return iter(self._algorithms.values())

    def __len__(self) -> int:
        return len(self._algorithms)


REGISTRY = AlgorithmRegistry()


def register_algorithm(name: str, *, a_placement: str = NATURAL,
                       b_placement: str = NATURAL,
                       unskew_out: Optional[str] = None,
                       wire: Tuple[str, ...] = ("a", "b"),
                       wire_amortized: bool = False, style: str = "rdma",
                       duplex: int = 1, msgs_per_step: Optional[int] = None,
                       sparse_body: Optional[Callable] = None,
                       k_order: Optional[Callable] = None,
                       balance_axis: str = "rows",
                       static_planner: Optional[Callable] = None,
                       cost_fn: Optional[Callable] = None,
                       packed_body: Optional[Callable] = None,
                       packable: Tuple[str, ...] = (),
                       wire_planner: Optional[Callable] = None,
                       registry: AlgorithmRegistry = REGISTRY):
    """Decorator registering a shard_map body as a named algorithm."""
    def deco(body):
        registry.register(Algorithm(
            name=name, body=body, a_placement=a_placement,
            b_placement=b_placement, unskew_out=unskew_out, wire=wire,
            wire_amortized=wire_amortized, style=style, duplex=duplex,
            msgs_per_step=msgs_per_step, sparse_body=sparse_body,
            k_order=k_order, balance_axis=balance_axis,
            static_planner=static_planner, cost_fn=cost_fn,
            packed_body=packed_body, packable=packable,
            wire_planner=wire_planner))
        return body
    return deco


def algorithms() -> Tuple[str, ...]:
    """Names of all registered algorithms (registration order)."""
    return REGISTRY.names()


def sparse_algorithms() -> Tuple[str, ...]:
    """Names of algorithms with a sparse-output (packed SpGEMM) body."""
    return tuple(a.name for a in REGISTRY if a.sparse_body is not None)


def recommended_balance(algorithm: str) -> str:
    """The operand balance axis the named schedule benefits from.

    Stationary-C schedules are dominated by the A tiles streamed each step,
    so spreading nonzero blocks over grid *rows* shrinks their capacity;
    the stationary-A ring's cost is dominated by B/C traffic and its output
    rides a reverse ring, so a *column* balance (compensated on the B side,
    leaving C unpermuted) composes better.  Feed the result to
    ``DistBSR.from_dense(balance=...)``.
    """
    return REGISTRY.get(algorithm).balance_axis


# ---------------------------------------------------------------------------
# Sparse-output bodies (packed SpGEMM; see plan_matmul(output="sparse"))
# ---------------------------------------------------------------------------
# The numeric phase of symbolic/numeric SpGEMM: both operands stay in their
# stored block form (only ``blocks`` rides the wire — the pair lists encode
# all structure, so rows/cols never leave the host), and each step
# scatter-accumulates matched block products into the packed output slots
# allocated by the symbolic phase.  No dense C tile, and no B densification,
# ever materializes on a device.
def _sparse_step(a_t: Dict, b_t: Dict, pa, pb, ps, geom: _Geom):
    return kops.bsr_pair_accumulate(
        a_t["blocks"], b_t["blocks"], pa, pb, ps, n_slots=geom.c_store,
        out_dtype=jnp.float32, impl=geom.impl)


def _sparse_c0(a: Dict, geom: _Geom):
    bs = a["blocks"].shape[-1]
    return _pvary(jnp.zeros((geom.c_store, bs, bs), jnp.float32), geom)


def _sparse_body_summa_bcast(a, b, pairs, geom: _Geom):
    """Bulk-synchronous SUMMA with packed sparse output."""
    my_row = lax.axis_index(geom.axr)
    my_col = lax.axis_index(geom.axc)
    if geom.overlap:
        # split-step (see _body_summa_bcast): step t carries inner step
        # t's panels and pairs while broadcasting step t+1's panels.
        a_c = _tree_bcast(a, geom.axc, jnp.int32(0), my_col)
        b_c = _tree_bcast(b, geom.axr, jnp.int32(0), my_row)

        def step(carry, xs):
            a_k, b_k, c = carry
            k, pa, pb, ps = xs
            a_n = _tree_bcast(a, geom.axc, k, my_col)
            b_n = _tree_bcast(b, geom.axr, k, my_row)
            c = c + _sparse_step(a_k, b_k, pa, pb, ps, geom)
            return (a_n, b_n, c), None

        (a_l, b_l, c), _ = lax.scan(
            step, (a_c, b_c, _sparse_c0(a, geom)),
            (jnp.arange(1, geom.g), pairs["pa"][:-1], pairs["pb"][:-1],
             pairs["ps"][:-1]))
        c = c + _sparse_step(a_l, b_l, pairs["pa"][-1], pairs["pb"][-1],
                             pairs["ps"][-1], geom)
        return c.astype(geom.out_dtype)

    def step(c, xs):
        k, pa, pb, ps = xs
        a_k = _tree_bcast(a, geom.axc, k, my_col)
        b_k = _tree_bcast(b, geom.axr, k, my_row)
        return c + _sparse_step(a_k, b_k, pa, pb, ps, geom), None

    c, _ = lax.scan(step, _sparse_c0(a, geom),
                    (jnp.arange(geom.g), pairs["pa"], pairs["pb"],
                     pairs["ps"]))
    return c.astype(geom.out_dtype)


def _sparse_body_summa_ag(a, b, pairs, geom: _Geom):
    """All-gather SUMMA with packed sparse output."""
    a_g = {k: lax.all_gather(v, geom.axc) for k, v in a.items()}
    b_g = {k: lax.all_gather(v, geom.axr) for k, v in b.items()}

    def step(c, xs):
        k, pa, pb, ps = xs
        a_k = {kk: v[k] for kk, v in a_g.items()}
        b_k = {kk: v[k] for kk, v in b_g.items()}
        return c + _sparse_step(a_k, b_k, pa, pb, ps, geom), None

    c, _ = lax.scan(step, _sparse_c0(a, geom),
                    (jnp.arange(geom.g), pairs["pa"], pairs["pb"],
                     pairs["ps"]))
    return c.astype(geom.out_dtype)


def _sparse_body_ring_c(a, b, pairs, geom: _Geom):
    """Stationary-C ring with packed sparse output.

    Same skewed placement and prefetch structure as ``ring_c``; B rides the
    ring in stored block form (its densified tile never exists), and the
    scanned step consumes the step-scheduled pair lists as scan inputs.
    """
    if geom.overlap:
        # two-slot double buffer (see _body_ring_c); scan input t pairs
        # with the tile of generation t, so the xs are sliced to g-1 and
        # the last pair list feeds the epilogue accumulate.
        a_f = _tree_ppermute(a, geom.axc, geom.g)
        b_f = _tree_ppermute(b, geom.axr, geom.g)

        def step(carry, xs):
            a_t, b_t, a_f, b_f, c = carry
            pa, pb, ps = xs
            a_n = _tree_ppermute(a_f, geom.axc, geom.g)
            b_n = _tree_ppermute(b_f, geom.axr, geom.g)
            c = c + _sparse_step(a_t, b_t, pa, pb, ps, geom)
            return (a_f, b_f, a_n, b_n, c), None

        (a_l, b_l, _, _, c), _ = lax.scan(
            step, (a, b, a_f, b_f, _sparse_c0(a, geom)),
            (pairs["pa"][:-1], pairs["pb"][:-1], pairs["ps"][:-1]))
        c = c + _sparse_step(a_l, b_l, pairs["pa"][-1], pairs["pb"][-1],
                             pairs["ps"][-1], geom)
        return c.astype(geom.out_dtype)

    def step(carry, xs):
        a_t, b_t, c = carry
        pa, pb, ps = xs
        a_n = _tree_ppermute(a_t, geom.axc, geom.g)   # prefetch (paper SS3.3)
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)
        c = c + _sparse_step(a_t, b_t, pa, pb, ps, geom)
        return (a_n, b_n, c), None

    (_, _, c), _ = lax.scan(step, (a, b, _sparse_c0(a, geom)),
                            (pairs["pa"], pairs["pb"], pairs["ps"]))
    return c.astype(geom.out_dtype)


# ---------------------------------------------------------------------------
# Packed-wire dense-output bodies (plan_matmul(wire="packed"))
# ---------------------------------------------------------------------------
# The packed variants ship ONLY real blocks: a sparse A tile rides as a
# packed [wire_capacity, bs, bs] buffer (no coverage blocks, no rows/cols
# index traffic) and a sparse B tile likewise, densified on the consumer
# by a static *gather* (ops.densify_packed) instead of the pre-scan
# scatter.  All structure lives in plan-time consume maps (repro.core.wire)
# riding as scan inputs — per-device local data, never on the network —
# so the scanned steps stay sort/scatter-free (the jaxpr invariant).
def _ring_perm(g: int, sign: int = 1):
    return [((d + sign) % g, d) for d in range(g)]


def _packed_a_mm(a_blocks, gidx, rows, cols, b_dense, geom: _Geom):
    """One packed local SpMM step: gather the coverage-augmented block
    list out of the packed buffer, then the standard augment-free kernel."""
    return kops.bsr_spmm_raw(a_blocks[gidx], rows, cols, b_dense,
                             n_block_rows=geom.a_nbr, impl=geom.impl,
                             augment=False).astype(geom.out_dtype)


def _packed_b_dense(b_buf, dmap, geom: _Geom):
    return kops.densify_packed(b_buf, dmap, n_block_rows=geom.b_nbr,
                               n_block_cols=geom.b_nbc)


def _packed_body_ring_c(a, b, aux, geom: _Geom):
    """Stationary-C ring over packed wire buffers (paper Alg 2)."""
    b_packed = "b_dmap" in aux
    b0 = b["blocks"] if b_packed else _densify_b(b, geom)["dense"]
    xs = {"ag": aux["a_gidx"], "ar": aux["a_rows"], "ac": aux["a_cols"]}
    if b_packed:
        xs["bd"] = aux["b_dmap"]
    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    if geom.overlap:
        # two-slot double buffer (see _body_ring_c); consume maps for
        # step t pair with tile generation t, so xs slice to g-1 and the
        # final maps feed the epilogue accumulate.
        last = {k: v[-1] for k, v in xs.items()}
        xs = {k: v[:-1] for k, v in xs.items()}
        a_f = lax.ppermute(a["blocks"], geom.axc, _ring_perm(geom.g))
        b_f = lax.ppermute(b0, geom.axr, _ring_perm(geom.g))

        def step(carry, xs):
            a_blk, b_buf, a_f, b_f, c = carry
            a_n = lax.ppermute(a_f, geom.axc, _ring_perm(geom.g))
            b_n = lax.ppermute(b_f, geom.axr, _ring_perm(geom.g))
            b_dense = _packed_b_dense(b_buf, xs["bd"], geom) if b_packed \
                else b_buf
            c = c + _packed_a_mm(a_blk, xs["ag"], xs["ar"], xs["ac"],
                                 b_dense, geom)
            return (a_f, b_f, a_n, b_n, c), None

        (a_l, b_l, _, _, c), _ = lax.scan(
            step, (a["blocks"], b0, a_f, b_f, c0), xs)
        b_dense = _packed_b_dense(b_l, last["bd"], geom) if b_packed else b_l
        return c + _packed_a_mm(a_l, last["ag"], last["ar"], last["ac"],
                                b_dense, geom)

    def step(carry, xs):
        a_blk, b_buf, c = carry
        a_n = lax.ppermute(a_blk, geom.axc, _ring_perm(geom.g))  # prefetch
        b_n = lax.ppermute(b_buf, geom.axr, _ring_perm(geom.g))
        b_dense = _packed_b_dense(b_buf, xs["bd"], geom) if b_packed \
            else b_buf
        c = c + _packed_a_mm(a_blk, xs["ag"], xs["ar"], xs["ac"], b_dense,
                             geom)
        return (a_n, b_n, c), None

    (_, _, c), _ = lax.scan(step, (a["blocks"], b0, c0), xs)
    return c


def _packed_body_ring_c_bidir(a, b, aux, geom: _Geom):
    """Bidirectional stationary-C ring, A packed in both directions.

    B's column half-panels are not block-aligned (tn // 2 need not be a
    block multiple), so B rides densified as in the padded body; only the
    A streams — the bidir schedule's doubled wire term — pack.
    """
    b = _densify_b(b, geom)
    half = geom.tn // 2
    b_fwd, b_bwd = b["dense"][:, :half], b["dense"][:, half:]
    xs = {"fg": aux["a_gidx"], "fr": aux["a_rows"], "fc": aux["a_cols"],
          "bg": aux["a_gidx_bwd"], "br": aux["a_rows_bwd"],
          "bc": aux["a_cols_bwd"]}
    c_l0 = _pvary(jnp.zeros((geom.tm, half), dtype=geom.out_dtype), geom)
    c_r0 = _pvary(jnp.zeros((geom.tm, geom.tn - half),
                            dtype=geom.out_dtype), geom)
    if geom.overlap:
        # four streams x two slots (see _body_ring_c_bidir), consume maps
        # sliced so step t's maps meet tile generation t
        last = {k: v[-1] for k, v in xs.items()}
        xs = {k: v[:-1] for k, v in xs.items()}
        a_ff = lax.ppermute(a["blocks"], geom.axc, _ring_perm(geom.g, +1))
        a_bf = lax.ppermute(a["blocks"], geom.axc, _ring_perm(geom.g, -1))
        b_ff = lax.ppermute(b_fwd, geom.axr, _ring_perm(geom.g, +1))
        b_bf = lax.ppermute(b_bwd, geom.axr, _ring_perm(geom.g, -1))

        def step(carry, xs):
            a_f, a_b, b_f, b_b, a_ff, a_bf, b_ff, b_bf, c_l, c_r = carry
            a_fn = lax.ppermute(a_ff, geom.axc, _ring_perm(geom.g, +1))
            a_bn = lax.ppermute(a_bf, geom.axc, _ring_perm(geom.g, -1))
            b_fn = lax.ppermute(b_ff, geom.axr, _ring_perm(geom.g, +1))
            b_bn = lax.ppermute(b_bf, geom.axr, _ring_perm(geom.g, -1))
            c_l = c_l + _packed_a_mm(a_f, xs["fg"], xs["fr"], xs["fc"],
                                     b_f, geom)
            c_r = c_r + _packed_a_mm(a_b, xs["bg"], xs["br"], xs["bc"],
                                     b_b, geom)
            return (a_ff, a_bf, b_ff, b_bf, a_fn, a_bn, b_fn, b_bn,
                    c_l, c_r), None

        (a_fl, a_bl, b_fl, b_bl, _, _, _, _, c_l, c_r), _ = lax.scan(
            step, (a["blocks"], a["blocks"], b_fwd, b_bwd,
                   a_ff, a_bf, b_ff, b_bf, c_l0, c_r0), xs)
        c_l = c_l + _packed_a_mm(a_fl, last["fg"], last["fr"], last["fc"],
                                 b_fl, geom)
        c_r = c_r + _packed_a_mm(a_bl, last["bg"], last["br"], last["bc"],
                                 b_bl, geom)
        return jnp.concatenate([c_l, c_r], axis=1)

    def step(carry, xs):
        a_f, a_b, b_f, b_b, c_l, c_r = carry
        a_fn = lax.ppermute(a_f, geom.axc, _ring_perm(geom.g, +1))
        a_bn = lax.ppermute(a_b, geom.axc, _ring_perm(geom.g, -1))
        b_fn = lax.ppermute(b_f, geom.axr, _ring_perm(geom.g, +1))
        b_bn = lax.ppermute(b_b, geom.axr, _ring_perm(geom.g, -1))
        c_l = c_l + _packed_a_mm(a_f, xs["fg"], xs["fr"], xs["fc"], b_f,
                                 geom)
        c_r = c_r + _packed_a_mm(a_b, xs["bg"], xs["br"], xs["bc"], b_b,
                                 geom)
        return (a_fn, a_bn, b_fn, b_bn, c_l, c_r), None

    (_, _, _, _, c_l, c_r), _ = lax.scan(
        step, (a["blocks"], a["blocks"], b_fwd, b_bwd, c_l0, c_r0), xs)
    return jnp.concatenate([c_l, c_r], axis=1)


def _packed_body_ring_a(a, b, aux, geom: _Geom):
    """Stationary-A ring with the sparse B operand packed on the wire.

    A never moves (nothing to pack); the win is B riding as real blocks
    instead of a densified tile, gather-densified each step.  Partial C
    tiles still ride back dense — their structure differs per hop (the
    ROADMAP's sparse-output ring_a item).
    """
    acc0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    if geom.overlap:
        # B stream two-slot only — the accumulator ring is a serial
        # dependence chain and cannot be double-buffered (see _body_ring_a)
        b_f = lax.ppermute(b["blocks"], geom.axr, _ring_perm(geom.g))

        def step(carry, bd):
            b_blk, b_f, acc = carry
            b_n = lax.ppermute(b_f, geom.axr, _ring_perm(geom.g))
            acc = acc + _local_mm(
                a, {"dense": _packed_b_dense(b_blk, bd, geom)}, geom)
            acc = lax.ppermute(acc, geom.axc, _ring_perm(geom.g))
            return (b_f, b_n, acc), None

        (b_l, _, acc), _ = lax.scan(step, (b["blocks"], b_f, acc0),
                                    aux["b_dmap"][:-1])
        acc = acc + _local_mm(
            a, {"dense": _packed_b_dense(b_l, aux["b_dmap"][-1], geom)},
            geom)
        return lax.ppermute(acc, geom.axc, _ring_perm(geom.g))

    def step(carry, bd):
        b_blk, acc = carry
        b_n = lax.ppermute(b_blk, geom.axr, _ring_perm(geom.g))  # prefetch
        acc = acc + _local_mm(a, {"dense": _packed_b_dense(b_blk, bd, geom)},
                              geom)
        acc = lax.ppermute(acc, geom.axc, _ring_perm(geom.g))
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (b["blocks"], acc0), aux["b_dmap"])
    return acc


def _packed_body_summa_ag(a, b, aux, geom: _Geom):
    """All-gather SUMMA over packed panels (per-source packed segments)."""
    b_packed = "b_dmap" in aux
    a_pool = lax.all_gather(a["blocks"], geom.axc)   # [g, wc_a, bs, bs]
    a_flat = a_pool.reshape((-1,) + a_pool.shape[-2:])
    xs = {"ag": aux["a_gidx"], "ar": aux["a_rows"], "ac": aux["a_cols"]}
    if b_packed:
        b_pool = lax.all_gather(b["blocks"], geom.axr)
        b_flat = b_pool.reshape((-1,) + b_pool.shape[-2:])
        xs["bd"] = aux["b_dmap"]
    else:
        b_g = lax.all_gather(_densify_b(b, geom)["dense"], geom.axr)
        xs["k"] = jnp.arange(geom.g)

    def step(c, xs):
        b_dense = _packed_b_dense(b_flat, xs["bd"], geom) if b_packed \
            else b_g[xs["k"]]
        c = c + _packed_a_mm(a_flat, xs["ag"], xs["ar"], xs["ac"], b_dense,
                             geom)
        return c, None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, xs)
    return c


def _packed_body_summa_bcast(a, b, aux, geom: _Geom):
    """Bulk-synchronous SUMMA broadcasting packed buffers per inner step."""
    b_packed = "b_dmap" in aux
    my_row = lax.axis_index(geom.axr)
    my_col = lax.axis_index(geom.axc)
    b0 = b["blocks"] if b_packed else _densify_b(b, geom)["dense"]
    xs = {"ag": aux["a_gidx"], "ar": aux["a_rows"], "ac": aux["a_cols"],
          "k": jnp.arange(geom.g)}
    if b_packed:
        xs["bd"] = aux["b_dmap"]

    def bcast(k):
        a_k = lax.psum(jnp.where(my_col == k, a["blocks"],
                                 jnp.zeros_like(a["blocks"])), geom.axc)
        b_k = lax.psum(jnp.where(my_row == k, b0, jnp.zeros_like(b0)),
                       geom.axr)
        return a_k, b_k

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    if geom.overlap:
        # split-step (see _body_summa_bcast): broadcast inner step k while
        # accumulating the carried panels of step k-1
        last = {k: v[-1] for k, v in xs.items()}
        xs = {k: v[1:] if k == "k" else v[:-1] for k, v in xs.items()}
        a_c, b_c = bcast(jnp.int32(0))

        def step(carry, xs):
            a_k, b_k, c = carry
            a_n, b_n = bcast(xs["k"])
            b_dense = _packed_b_dense(b_k, xs["bd"], geom) if b_packed \
                else b_k
            c = c + _packed_a_mm(a_k, xs["ag"], xs["ar"], xs["ac"],
                                 b_dense, geom)
            return (a_n, b_n, c), None

        (a_l, b_l, c), _ = lax.scan(step, (a_c, b_c, c0), xs)
        b_dense = _packed_b_dense(b_l, last["bd"], geom) if b_packed else b_l
        return c + _packed_a_mm(a_l, last["ag"], last["ar"], last["ac"],
                                b_dense, geom)

    def step(c, xs):
        a_k, b_k = bcast(xs["k"])
        b_dense = _packed_b_dense(b_k, xs["bd"], geom) if b_packed else b_k
        c = c + _packed_a_mm(a_k, xs["ag"], xs["ar"], xs["ac"], b_dense,
                             geom)
        return c, None

    c, _ = lax.scan(step, c0, xs)
    return c


# ---- per-schedule wire planners (consume-map construction) ----------------
def _wire_consume(aux, prefix, po, tiles, bases=None):
    cons = _wire.schedule_consume(po, tiles, bases)
    aux[f"{prefix}_gidx"] = cons["gidx"]
    aux[f"{prefix}_rows"] = cons["rows"]
    aux[f"{prefix}_cols"] = cons["cols"]


def _wire_planner_ring_c(a_po, b_po, geom: _Geom):
    aux: Dict[str, np.ndarray] = {}
    if a_po is not None:
        _wire_consume(aux, "a", a_po, _wire.tiles_ring_c(geom.g))
    if b_po is not None:
        aux["b_dmap"] = _wire.schedule_dense_map(
            b_po, _wire.tiles_ring_c_b(geom.g))
    return aux


def _wire_planner_ring_c_bidir(a_po, b_po, geom: _Geom):
    aux: Dict[str, np.ndarray] = {}
    _wire_consume(aux, "a", a_po, _wire.tiles_ring_c(geom.g))
    cons = _wire.schedule_consume(a_po, _wire.tiles_ring_c_bwd(geom.g))
    aux["a_gidx_bwd"] = cons["gidx"]
    aux["a_rows_bwd"] = cons["rows"]
    aux["a_cols_bwd"] = cons["cols"]
    return aux


def _wire_planner_ring_a(a_po, b_po, geom: _Geom):
    return {"b_dmap": _wire.schedule_dense_map(
        b_po, _wire.tiles_ring_a_b(geom.g))}


def _summa_bases(g: int, wc: int) -> np.ndarray:
    """Flat base offset of inner step k's tile in an all-gathered pool."""
    return np.broadcast_to(np.arange(g, dtype=np.int64) * wc, (g, g, g))


def _wire_planner_summa_ag(a_po, b_po, geom: _Geom):
    g = geom.g
    aux: Dict[str, np.ndarray] = {}
    if a_po is not None:
        _wire_consume(aux, "a", a_po, _wire.tiles_summa_a(g),
                      _summa_bases(g, a_po.wire_capacity))
    if b_po is not None:
        aux["b_dmap"] = _wire.schedule_dense_map(
            b_po, _wire.tiles_summa_b(g),
            _summa_bases(g, b_po.wire_capacity))
    return aux


def _wire_planner_summa_bcast(a_po, b_po, geom: _Geom):
    g = geom.g
    aux: Dict[str, np.ndarray] = {}
    if a_po is not None:
        _wire_consume(aux, "a", a_po, _wire.tiles_summa_a(g))
    if b_po is not None:
        aux["b_dmap"] = _wire.schedule_dense_map(b_po,
                                                 _wire.tiles_summa_b(g))
    return aux


# ---------------------------------------------------------------------------
# Algorithm bodies (run inside shard_map on local tile views)
# ---------------------------------------------------------------------------
@register_algorithm("summa_bcast", style="bsp",
                    sparse_body=_sparse_body_summa_bcast,
                    packed_body=_packed_body_summa_bcast,
                    packable=("a", "b"),
                    wire_planner=_wire_planner_summa_bcast,
                    k_order=lambda i, j, t, g: t + 0 * (i + j))
def _body_summa_bcast(a, b, geom: _Geom):
    """Bulk-synchronous SUMMA (paper SS2.2): a broadcast per inner step."""
    b = _densify_b(b, geom)
    my_row = lax.axis_index(geom.axr)
    my_col = lax.axis_index(geom.axc)
    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    if geom.overlap:
        # split-step: broadcast inner step k+1 before accumulating step k's
        # carried panels, so the collective overlaps the local matmul
        a_c = _tree_bcast(a, geom.axc, jnp.int32(0), my_col)
        b_c = _tree_bcast(b, geom.axr, jnp.int32(0), my_row)

        def step(carry, k):
            a_k, b_k, c = carry
            a_n = _tree_bcast(a, geom.axc, k, my_col)
            b_n = _tree_bcast(b, geom.axr, k, my_row)
            c = c + _local_mm(a_k, b_k, geom)
            return (a_n, b_n, c), None

        (a_l, b_l, c), _ = lax.scan(step, (a_c, b_c, c0),
                                    jnp.arange(1, geom.g))
        return c + _local_mm(a_l, b_l, geom)

    def step(c, k):
        a_k = _tree_bcast(a, geom.axc, k, my_col)  # bcast A[:, k] along rows
        b_k = _tree_bcast(b, geom.axr, k, my_row)  # bcast B[k, :] along cols
        return c + _local_mm(a_k, b_k, geom), None

    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


@register_algorithm("summa_ag", style="bsp", wire_amortized=True,
                    sparse_body=_sparse_body_summa_ag,
                    packed_body=_packed_body_summa_ag,
                    packable=("a", "b"),
                    wire_planner=_wire_planner_summa_ag,
                    k_order=lambda i, j, t, g: t + 0 * (i + j))
def _body_summa_ag(a, b, geom: _Geom):
    """All-gather SUMMA: one big up-front collective, g x tile footprint.

    No overlap variant: the schedule is wire-amortized — every inner step
    depends on the single up-front all-gather, so there is no per-step
    transfer to double-buffer (the gather gates all compute).
    """
    b = _densify_b(b, geom)
    a_g = {k: lax.all_gather(v, geom.axc) for k, v in a.items()}
    b_g = {k: lax.all_gather(v, geom.axr) for k, v in b.items()}

    def step(c, k):
        a_k = {kk: v[k] for kk, v in a_g.items()}
        b_k = {kk: v[k] for kk, v in b_g.items()}
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


@register_algorithm("ring_c", a_placement=SKEW_ROWS, b_placement=SKEW_COLS,
                    sparse_body=_sparse_body_ring_c,
                    packed_body=_packed_body_ring_c,
                    packable=("a", "b"),
                    wire_planner=_wire_planner_ring_c,
                    k_order=lambda i, j, t, g: (i + j + t) % g)
def _body_ring_c(a, b, geom: _Geom):
    """Paper Alg 2 (stationary-C): skewed placement + neighbour ppermute."""
    b = _densify_b(b, geom)
    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    if geom.overlap:
        # Split-step double buffer: the carry holds the tile being
        # consumed AND the tile in flight, so the transfer consumed at
        # step t+1 was issued at step t-1 — a full local matmul of slack
        # for the collective-permute DMA.  The prologue issues step 1's
        # transfer, the scan runs g-1 steps, and the epilogue accumulates
        # the last tile with nothing left to prefetch: g permutes per
        # stream total, exactly the bulk body's wire traffic.
        a_f = _tree_ppermute(a, geom.axc, geom.g)
        b_f = _tree_ppermute(b, geom.axr, geom.g)

        def step(carry, _):
            a_t, b_t, a_f, b_f, c = carry
            a_n = _tree_ppermute(a_f, geom.axc, geom.g)   # step t+2's tile
            b_n = _tree_ppermute(b_f, geom.axr, geom.g)
            c = c + _local_mm(a_t, b_t, geom)
            return (a_f, b_f, a_n, b_n, c), None

        (a_l, b_l, _, _, c), _ = lax.scan(step, (a, b, a_f, b_f, c0), None,
                                          length=geom.g - 1)
        return c + _local_mm(a_l, b_l, geom)

    def step(carry, _):
        a_t, b_t, c = carry
        # "async_get_tile" for step k+1, issued before the local matmul so
        # the collective-permute DMA overlaps MXU work (paper SS3.3 prefetch).
        a_n = _tree_ppermute(a_t, geom.axc, geom.g)
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)
        c = c + _local_mm(a_t, b_t, geom)
        return (a_n, b_n, c), None

    (_, _, c), _ = lax.scan(step, (a, b, c0), None, length=geom.g)
    return c


@register_algorithm("ring_a", b_placement=STATIONARY_A, unskew_out="rows",
                    wire=("b", "c"), balance_axis="cols",
                    packed_body=_packed_body_ring_a, packable=("b",),
                    wire_planner=_wire_planner_ring_a)
def _body_ring_a(a, b, geom: _Geom):
    """Paper Alg 1 (stationary-A): B rides the ring, partial C rides back."""
    b = _densify_b(b, geom)
    acc0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    if geom.overlap:
        # Only the B stream double-buffers: the partial-C permute depends
        # on the accumulate it follows (the ride-home chain is inherently
        # serial), so C's hop count stays g while B's transfers gain a
        # full matmul of slack.
        b_f = _tree_ppermute(b, geom.axr, geom.g)

        def step(carry, _):
            b_t, b_f, acc = carry
            b_n = _tree_ppermute(b_f, geom.axr, geom.g)
            acc = acc + _local_mm(a, b_t, geom)
            acc = lax.ppermute(acc, geom.axc, _ring_perm(geom.g))
            return (b_f, b_n, acc), None

        (b_l, _, acc), _ = lax.scan(step, (b, b_f, acc0), None,
                                    length=geom.g - 1)
        acc = acc + _local_mm(a, b_l, geom)
        return lax.ppermute(acc, geom.axc, _ring_perm(geom.g))

    def step(carry, _):
        b_t, acc = carry
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)   # prefetch next B tile
        acc = acc + _local_mm(a, b_t, geom)
        # route the partial C tile one hop toward its owner (the TPU
        # replacement for the paper's remote accumulation queue push)
        acc = lax.ppermute(acc, geom.axc,
                           [((d + 1) % geom.g, d) for d in range(geom.g)])
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (b, acc0), None, length=geom.g)
    return acc


@register_algorithm("ring_c_bidir", a_placement=SKEW_ROWS,
                    b_placement=SKEW_COLS, wire=("a", "a", "b"), duplex=2,
                    packed_body=_packed_body_ring_c_bidir, packable=("a",),
                    wire_planner=_wire_planner_ring_c_bidir,
                    msgs_per_step=4)   # a_fwd, a_bwd, b_left, b_right
def _body_ring_c_bidir(a, b, geom: _Geom):
    """Bidirectional stationary-C ring: C split into column half-panels.

    The left half-panel's operands (the full A tile + the left half of the
    dense B tile) ride the +1 ring computing ``k = i+j+t``; the right
    half-panel's ride the -1 ring computing ``k = i+j-t``.  Both start from
    the same skewed placement as ``ring_c``, so no new placement state is
    materialized.  The two streams use opposite directions of the
    full-duplex torus links concurrently, halving B's serialized wire time
    at the cost of shipping A both ways — a genuinely different
    comm/compute trade for ``algorithm="auto"`` (wins for sparse-A x wide-B
    SpMM, loses when A's tile bytes dominate).
    """
    b = _densify_b(b, geom)
    half = geom.tn // 2
    b_fwd = {"dense": b["dense"][:, :half]}
    b_bwd = {"dense": b["dense"][:, half:]}
    c_l0 = _pvary(jnp.zeros((geom.tm, half), dtype=geom.out_dtype), geom)
    c_r0 = _pvary(jnp.zeros((geom.tm, geom.tn - half), dtype=geom.out_dtype),
                  geom)
    if geom.overlap:
        # four streams, each with a two-slot buffer (see _body_ring_c)
        a_ff = _tree_ppermute(a, geom.axc, geom.g, +1)
        a_bf = _tree_ppermute(a, geom.axc, geom.g, -1)
        b_ff = _tree_ppermute(b_fwd, geom.axr, geom.g, +1)
        b_bf = _tree_ppermute(b_bwd, geom.axr, geom.g, -1)

        def step(carry, _):
            a_f, a_b, b_f, b_b, a_ff, a_bf, b_ff, b_bf, c_l, c_r = carry
            a_fn = _tree_ppermute(a_ff, geom.axc, geom.g, +1)
            a_bn = _tree_ppermute(a_bf, geom.axc, geom.g, -1)
            b_fn = _tree_ppermute(b_ff, geom.axr, geom.g, +1)
            b_bn = _tree_ppermute(b_bf, geom.axr, geom.g, -1)
            c_l = c_l + _local_mm(a_f, b_f, geom)
            c_r = c_r + _local_mm(a_b, b_b, geom)
            return (a_ff, a_bf, b_ff, b_bf, a_fn, a_bn, b_fn, b_bn,
                    c_l, c_r), None

        (a_fl, a_bl, b_fl, b_bl, _, _, _, _, c_l, c_r), _ = lax.scan(
            step, (a, a, b_fwd, b_bwd, a_ff, a_bf, b_ff, b_bf, c_l0, c_r0),
            None, length=geom.g - 1)
        c_l = c_l + _local_mm(a_fl, b_fl, geom)
        c_r = c_r + _local_mm(a_bl, b_bl, geom)
        return jnp.concatenate([c_l, c_r], axis=1)

    def step(carry, _):
        a_f, a_b, b_f, b_b, c_l, c_r = carry
        # prefetch both directions before the local matmuls (paper SS3.3)
        a_fn = _tree_ppermute(a_f, geom.axc, geom.g, +1)
        a_bn = _tree_ppermute(a_b, geom.axc, geom.g, -1)
        b_fn = _tree_ppermute(b_f, geom.axr, geom.g, +1)
        b_bn = _tree_ppermute(b_b, geom.axr, geom.g, -1)
        c_l = c_l + _local_mm(a_f, b_f, geom)
        c_r = c_r + _local_mm(a_b, b_b, geom)
        return (a_fn, a_bn, b_fn, b_bn, c_l, c_r), None

    (_, _, _, _, c_l, c_r), _ = lax.scan(
        step, (a, a, b_fwd, b_bwd, c_l0, c_r0), None, length=geom.g)
    return jnp.concatenate([c_l, c_r], axis=1)


# ---------------------------------------------------------------------------
# steal3d: static 3D work-grid dispatch from the stealing equilibrium
# ---------------------------------------------------------------------------
def _steal_plan_for(a_h: "DistMatrix", b_h: "DistMatrix", geom: _Geom,
                    wire: str = "padded",
                    assignment=None) -> "_steal3d.StealPlan":
    """Memoized steal3d planner (LPT assignment + pair lists + rounds).

    auto_select scoring shares this cache with plan construction: the one
    full build per operand structure (and wire mode) also serves the cost
    entry, and is reused outright if steal3d wins the race.

    An injected ``assignment`` (elastic recovery) bypasses the memo both
    ways: the plan is built fresh against it (``build_steal_plan`` runs
    its fail-fast invariant checks) and never enters the shared cache.
    """
    skey = a_h.structure_key() if isinstance(a_h, DistBSR) else None
    if not (wire == "packed" and isinstance(a_h, DistBSR)):
        wire = "padded"      # dense A has no packable steal3d traffic
    if assignment is not None:
        with _obs.span("plan_build.steal", wire=wire, injected=True):
            return _steal3d.build_steal_plan(a_h, b_h, geom, wire=wire,
                                             overlap=geom.overlap,
                                             assignment=assignment)
    key = (a_h.abstract_key(), b_h.abstract_key(), skey, wire, geom.overlap)
    sp = _STEAL_CACHE.get(key)
    if sp is None:
        with _obs.span("plan_build.steal", wire=wire):
            sp = _steal3d.build_steal_plan(a_h, b_h, geom, wire=wire,
                                           overlap=geom.overlap)
        _STEAL_CACHE[key] = sp
    return sp


def _steal3d_cost(alg: "Algorithm", geom: _Geom, a_h: "DistMatrix",
                  b_h: "DistMatrix", wire: str = "padded"
                  ) -> Dict[str, float]:
    """auto_select cost entry: the *simulated equilibrium* made a score.

    The flop term is the realized LPT makespan (pair capacity — executed
    block products on the most-loaded device, padding included) and the
    byte term counts panel gathers + moved tiles + owner reductions —
    packed to real blocks when ``wire="packed"`` — so ``algorithm="auto"``
    picks steal3d exactly when the plan-time stealing simulation says the
    equilibrium beats every owner-computes schedule's capacity-padded
    uniform work.
    """
    return dict(_steal_plan_for(a_h, b_h, geom, wire=wire).cost)


def _steal3d_perm(g: int, delta: int):
    return [(d, (d + delta) % g) for d in range(g)]


@register_algorithm("steal3d", style="bsp", wire=("a", "b", "c"),
                    static_planner=_steal_plan_for, cost_fn=_steal3d_cost,
                    packable=("a",))
def _body_steal3d(a, b, aux, geom: _Geom, splan: "_steal3d.StealPlan"):
    """Static realization of the paper's SS3.4 locality-aware work stealing.

    Executes the plan-time LPT assignment of (i, k, j) items: each device
    all-gathers its A grid-row panel and (densified) B grid-column panel,
    receives the moved tiles of its off-owner items in static ppermute
    rounds, runs ONE packed pair-accumulate over its item list (length =
    the stealing equilibrium's makespan, not the uniform g x capacity of
    the owner-computes rings), and ships partial C tiles home in static
    reduce rounds.  No scan: the whole dispatch is one flat program.

    Under ``splan.wire == "packed"`` (sparse A) every A-side shipment
    carries only real blocks: the panel gather rides at the packed wire
    capacity, each moved-tile round is sliced to its own per-move real
    max (the packed prefix makes that a slice, not a gather), and the
    partial-C reduce rounds ship only the block-rows their items can
    touch, scatter-added into the owner's tile outside any scan.
    """
    g = geom.g
    packed = splan.wire == "packed"
    if splan.a_kind == "bsr":
        a_tiles = lax.all_gather(a["blocks"], geom.axc)  # [g, stride, bs, bs]
    else:
        a_tiles = lax.all_gather(a["dense"], geom.axc)   # [g, tm, tk]
    b_dense = _densify_b(b, geom)["dense"]
    b_tiles = lax.all_gather(b_dense, geom.axr)          # [g, tk, tn]
    # moved tiles: one ppermute round per hop distance, source-side static
    # gather indices select what each source packs (paper's "one moving
    # tile" for locality-constrained steals).  Issued here, before any
    # accumulate — on the overlap path (splan.overlap) the own-item
    # segment depends only on the panel gathers, so these transfers fly
    # while it computes.
    if packed:
        # flat segments: strides differ per round (per-move real max)
        moved_a = [
            lax.ppermute(a_tiles[aux[f"amk{delta}"]][:, :rcap], geom.axr,
                         _steal3d_perm(g, delta))
            .reshape((-1,) + a_tiles.shape[-2:])
            for delta, rcap in zip(splan.a_deltas, splan.a_round_cap)]
    else:
        moved_a = [lax.ppermute(a_tiles[aux[f"amk{delta}"]], geom.axr,
                                _steal3d_perm(g, delta))
                   for delta in splan.a_deltas]
    moved_b = [lax.ppermute(b_tiles[aux[f"bmk{delta}"]], geom.axc,
                            _steal3d_perm(g, delta))
               for delta in splan.b_deltas]
    if packed:
        panel_a = a_tiles.reshape((-1,) + a_tiles.shape[-2:])
        zero_a = _pvary(jnp.zeros((1,) + a_tiles.shape[-2:],
                                  a_tiles.dtype), geom)
    else:
        panel_a = a_tiles
        zero_a = _pvary(jnp.zeros((1,) + a_tiles.shape[1:],
                                  a_tiles.dtype), geom)
    a_pool = jnp.concatenate([panel_a] + moved_a + [zero_a])
    b_pool = jnp.concatenate([b_tiles] + moved_b) if moved_b else b_tiles

    def _accum(a_p, b_p, pa, pb, ps):
        if splan.a_kind == "bsr":
            blocks = a_p if packed else a_p.reshape((-1,) + a_p.shape[-2:])
            b_flat = b_p.reshape(-1, b_p.shape[-1])
            cc = kops.steal_pair_accumulate(blocks, b_flat, pa, pb, ps,
                                            n_slots=splan.n_slots,
                                            impl=geom.impl)
            return cc.reshape(splan.n_out, geom.tm, geom.tn)
        prods = jnp.einsum("pij,pjk->pik", a_p[pa], b_p[pb],
                           preferred_element_type=jnp.float32)
        return jax.ops.segment_sum(prods, ps, num_segments=splan.n_out,
                                   indices_are_sorted=True)

    if splan.overlap:
        # two-segment split: own items (panel-only pool, zero block right
        # after the g panel tiles) accumulate while the moved-tile rounds
        # are in flight; stolen items consume the full pools after
        a_own = jnp.concatenate([panel_a, zero_a])
        c = _accum(a_own, b_tiles, aux["pa0"], aux["pb0"], aux["ps0"]) \
            + _accum(a_pool, b_pool, aux["pa1"], aux["pb1"], aux["ps1"])
    else:
        c = _accum(a_pool, b_pool, aux["pa"], aux["pb"], aux["ps"])
    own = c[0]
    if packed:
        # row-packed reduce rounds: ship only the block-rows the sender's
        # items can touch; receivers scatter-add them home (a dummy target
        # row absorbs the padding).  This is outside any scan, so the
        # hot-loop jaxpr invariants are unaffected.
        nbr, bs = geom.a_nbr, geom.tm // geom.a_nbr
        c_rows = c.reshape(splan.n_out, nbr, bs, geom.tn)
        own_ext = jnp.concatenate(
            [c_rows[0],
             _pvary(jnp.zeros((1, bs, geom.tn), c.dtype), geom)])
        for axis, deltas in ((geom.axc, splan.row_deltas),
                             (geom.axr, splan.col_deltas)):
            pre = "r" if axis == geom.axc else "c"
            for delta in deltas:
                part = c_rows[aux[f"{pre}send{delta}"],
                              aux[f"{pre}row{delta}"]]
                part = lax.ppermute(part, axis, _steal3d_perm(g, delta))
                own_ext = own_ext.at[aux[f"{pre}tgt{delta}"]].add(part)
        return own_ext[:nbr].reshape(geom.tm, geom.tn).astype(geom.out_dtype)
    # reduce rounds: partial C tiles ride home to their owners; idle
    # senders point at the guaranteed-zero dummy slot
    for delta in splan.row_deltas:
        part = jnp.take(c, aux[f"rsend{delta}"], axis=0)
        own = own + lax.ppermute(part, geom.axc, _steal3d_perm(g, delta))
    for delta in splan.col_deltas:
        part = jnp.take(c, aux[f"csend{delta}"], axis=0)
        own = own + lax.ppermute(part, geom.axr, _steal3d_perm(g, delta))
    return own.astype(geom.out_dtype)


# ---------------------------------------------------------------------------
# Distributed-matrix handles
# ---------------------------------------------------------------------------
def _place_bsr(t: TiledBSR, placement: str) -> TiledBSR:
    if placement == NATURAL:
        return t
    if placement in (SKEW_ROWS, SKEW_COLS):
        return skew_bsr(t, placement[len("skew_"):])
    if placement == STATIONARY_A:
        g = t.grid_shape[0]
        i = np.arange(g)[:, None]
        j = np.arange(g)[None, :]
        si, sj = j + 0 * i, (i + j) % g   # position (i,j) <- tile (j,(i+j)%g)
        take = lambda arr: arr[si, sj]
        return TiledBSR(
            blocks=take(t.blocks), rows=take(t.rows), cols=take(t.cols),
            counts=take(t.counts), shape=t.shape, block_size=t.block_size,
            grid_shape=t.grid_shape, capacity=t.capacity,
            logical_shape=t.logical_shape, row_block_perm=t.row_block_perm,
            col_block_perm=t.col_block_perm)
    raise ValueError(f"unknown placement {placement!r}; one of {PLACEMENTS}")


def _place_dense(x: jnp.ndarray, g: int, placement: str) -> jnp.ndarray:
    if placement == NATURAL:
        return x
    if placement == SKEW_ROWS:
        return skew_dense(x, g, "rows")
    if placement == SKEW_COLS:
        return skew_dense(x, g, "cols")
    if placement == STATIONARY_A:
        return place_b_for_stationary_a(x, g)
    raise ValueError(f"unknown placement {placement!r}; one of {PLACEMENTS}")


class DistMatrix:
    """A matrix distributed over a square ``g x g`` process grid.

    Subclasses cache placement transforms: ``placed(p)`` materializes the
    operand tree for placement ``p`` at most once per handle, the way the
    paper's DMatrix resolves its pointer directory once at construction.
    """

    kind = "abstract"

    @property
    def g(self) -> int:
        raise NotImplementedError

    @property
    def shape(self) -> Tuple[int, int]:      # padded global shape
        raise NotImplementedError

    @property
    def logical_shape(self) -> Tuple[int, int]:
        raise NotImplementedError

    @property
    def tile_shape(self) -> Tuple[int, int]:
        s = self.shape
        return s[0] // self.g, s[1] // self.g

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def abstract_key(self) -> tuple:
        """Hashable abstract signature (shapes/dtypes, no data) for caching."""
        raise NotImplementedError

    def placements(self) -> Tuple[str, ...]:
        """Placement states materialized so far (diagnostics/tests)."""
        return tuple(self._placed)


class DistBSR(DistMatrix):
    """Handle for a block-sparse distributed matrix (wraps TiledBSR)."""

    kind = "bsr"

    def __init__(self, tiled: TiledBSR):
        if tiled.grid_shape[0] != tiled.grid_shape[1]:
            raise ValueError("square process grid required, got "
                             f"{tiled.grid_shape}")
        self.tiled = tiled
        self._placed: Dict[str, Dict[str, jnp.ndarray]] = {}

    @classmethod
    def from_tiled(cls, tiled: TiledBSR, *, balance: str = "none",
                   capacity="keep") -> "DistBSR":
        """Wrap a TiledBSR; ``balance != "none"`` re-tiles with balancing.

        Re-balancing an already-tiled matrix goes through a dense round
        trip (tiling is host-side construction, not a hot path); a tiled
        matrix that already carries a balance permutation is kept as-is.

        ``capacity`` controls the rebuilt uniform capacity: ``"keep"``
        (default) preserves the handle's existing value — a caller who
        pinned it to unify abstract shapes across matrices (plan-cache
        sharing) must not get a silently re-derived one — while ``None``
        re-derives the minimal capacity, realizing the balancing shrink
        (balancing never *increases* the needed capacity: the balancer
        falls back to the identity layout when it would), and ``"bucket"``
        re-derives it rounded up to a 1.25x bucket.  An int pins a new
        value.  A non-``"keep"`` capacity on a call that does not re-tile
        raises (it cannot be honored, and ignoring it would desync
        abstract keys).
        """
        if balance not in ("none", "rows", "cols", "auto"):
            raise ValueError(f"unknown balance {balance!r}; one of "
                             "('none', 'rows', 'cols', 'auto')")
        rebuilds = balance != "none" and tiled.row_block_perm is None \
            and tiled.col_block_perm is None
        if capacity != "keep" and not rebuilds:
            raise ValueError(
                "capacity can only be changed when from_tiled re-tiles "
                "(balance= on an unbalanced value); otherwise rebuild "
                "with TiledBSR.from_dense(capacity=...)")
        if rebuilds:
            m, n = tiled.logical_shape or tiled.shape
            dense = np.asarray(tiled.to_dense())[:m, :n]
            cap = tiled.capacity if capacity == "keep" else capacity
            tiled = TiledBSR.from_dense(
                dense, ProcessGrid(*tiled.grid_shape), tiled.block_size,
                capacity=cap, dtype=tiled.dtype, balance=balance)
        return cls(tiled)

    @classmethod
    def from_dense(cls, dense, *, g: int, block_size: int,
                   capacity="bucket", dtype=None,
                   balance: str = "none") -> "DistBSR":
        """Tile + wrap a dense array.

        Unlike raw ``TiledBSR.from_dense``, the default capacity here is
        ``"bucket"``: the minimal capacity rounded up to the next 1.25x
        bucket, so handles for near-identical sparsity patterns share
        abstract shapes — and therefore cached, jitted plans.  Pass
        ``capacity=None`` for the exact minimum or an int to pin.
        """
        return cls(TiledBSR.from_dense(dense, ProcessGrid(g, g), block_size,
                                       capacity=capacity, dtype=dtype,
                                       balance=balance))

    @property
    def g(self) -> int:
        return self.tiled.grid_shape[0]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.tiled.shape

    @property
    def logical_shape(self) -> Tuple[int, int]:
        return self.tiled.logical_shape or self.tiled.shape

    @property
    def dtype(self):
        return self.tiled.dtype

    @property
    def block_size(self) -> int:
        return self.tiled.block_size

    @property
    def capacity(self) -> int:
        return self.tiled.capacity

    @property
    def counts(self):
        return self.tiled.counts

    @property
    def row_block_perm(self) -> Optional[Tuple[int, ...]]:
        """Row-block balance permutation (None unless ``balance="rows"``)."""
        return self.tiled.row_block_perm

    @property
    def col_block_perm(self) -> Optional[Tuple[int, ...]]:
        """Column-block balance permutation (``balance="cols"``)."""
        return self.tiled.col_block_perm

    def inv_row_perm(self) -> Optional[jnp.ndarray]:
        """Device array of the inverse balance permutation, cached on the
        handle so repeated plan calls don't recompute/re-upload it."""
        if self.tiled.row_block_perm is None:
            return None
        inv = getattr(self, "_inv_row_perm", None)
        if inv is None:
            inv = jnp.asarray(
                _schedule.invert_perm(self.tiled.row_block_perm))
            self._inv_row_perm = inv
        return inv

    def inv_col_perm(self) -> Optional[jnp.ndarray]:
        """Cached inverse of ``col_block_perm`` (see :meth:`inv_row_perm`)."""
        if self.tiled.col_block_perm is None:
            return None
        inv = getattr(self, "_inv_col_perm", None)
        if inv is None:
            inv = jnp.asarray(
                _schedule.invert_perm(self.tiled.col_block_perm))
            self._inv_col_perm = inv
        return inv

    def grid_structure(self) -> "_symbolic.GridStructure":
        """Host-side structural view of the stored slots (cached).

        One device read per handle lifetime, shared by everything that is
        specialized to the structure: the fingerprint, the symbolic phase,
        the steal3d planner and the packed wire layout.
        """
        s = getattr(self, "_grid_structure", None)
        if s is None:
            s = _symbolic.extract_structure(self.tiled)
            self._grid_structure = s
        return s

    def structure_key(self) -> str:
        """Fingerprint of the block *structure* (which slots hold data).

        Sparse-output, packed-wire and steal3d plans are specialized to
        the operands' structures (pair lists / consume maps are baked into
        the executable), so this joins the plan-cache key the way
        ``abstract_key`` does for shapes.  Cached on the handle.
        """
        return self.grid_structure().fingerprint

    def packed_operand(self) -> "_wire.PackedOperand":
        """Packed wire layout of this handle's structure (cached)."""
        po = getattr(self, "_packed_operand", None)
        if po is None:
            po = _wire.pack_operand(self.grid_structure())
            self._packed_operand = po
        return po

    def packed_wire(self, placement: str) -> Dict[str, jnp.ndarray]:
        """Packed wire blocks for a placement: ``{"blocks": [g, g, wc,
        bs, bs]}`` — each tile's real blocks gathered into the packed
        prefix, trailing slots guaranteed zero.  Cached per placement,
        like :meth:`placed` (one gather per handle x placement lifetime).
        """
        cache = getattr(self, "_packed_placed", None)
        if cache is None:
            cache = self._packed_placed = {}
        tree = cache.get(placement)
        if tree is None:
            po = self.packed_operand()
            placed = self.placed(placement)["blocks"]
            tiles = _wire.placement_tiles(placement, self.g)
            pidx = po.pack_idx[tiles[..., 0], tiles[..., 1]]  # [g, g, wc]
            g = self.g
            ii = jnp.arange(g)[:, None, None]
            jj = jnp.arange(g)[None, :, None]
            tree = {"blocks": placed[ii, jj, jnp.asarray(pidx)]}
            cache[placement] = tree
        return tree

    def densify(self) -> jnp.ndarray:
        """Dense logical-shape value (inverts balance perms, crops padding).

        Host-side convenience for tests/benchmarks — the whole point of
        sparse-output plans is that chained multiplies never need this.
        """
        d = self.tiled.to_dense()
        bs = self.block_size
        if self.tiled.row_block_perm is not None:
            inv = np.asarray(self.inv_row_perm())
            d = d.reshape(-1, bs, d.shape[1])[inv].reshape(d.shape)
        if self.tiled.col_block_perm is not None:
            inv = np.asarray(self.inv_col_perm())
            d = d.reshape(d.shape[0], -1, bs)[:, inv].reshape(d.shape)
        m, n = self.logical_shape
        return d[:m, :n]

    def footprint_bytes(self) -> int:
        """Bytes of the packed representation (blocks + structure arrays)."""
        t = self.tiled
        return int(t.blocks.nbytes + t.rows.nbytes + t.cols.nbytes
                   + t.counts.nbytes)

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        tree = self._placed.get(placement)
        if tree is None:
            t = _place_bsr(self.tiled, placement)
            tree = {"blocks": t.blocks, "rows": t.rows, "cols": t.cols}
            self._placed[placement] = tree
        return tree

    def abstract_key(self) -> tuple:
        t = self.tiled
        return ("bsr", t.shape, t.grid_shape, t.block_size, t.capacity,
                jnp.dtype(t.dtype).name)


class DistDense(DistMatrix):
    """Handle for a dense distributed matrix (grid-padded global array)."""

    kind = "dense"

    def __init__(self, data, g: int,
                 logical_shape: Optional[Tuple[int, int]] = None):
        data = jnp.asarray(data)
        if data.ndim != 2:
            raise ValueError(f"expected a 2-D array, got shape {data.shape}")
        if data.shape[0] % g or data.shape[1] % g:
            raise ValueError(
                f"padded shape {data.shape} not divisible by grid size {g}; "
                "use DistDense.from_global to pad")
        self.data = data
        self._g = g
        self._logical = tuple(logical_shape or data.shape)
        self._placed: Dict[str, Dict[str, jnp.ndarray]] = {}

    @classmethod
    def from_global(cls, x, g: int, *, rows_pad: Optional[int] = None,
                    cols_pad: Optional[int] = None) -> "DistDense":
        """Wrap a global array, zero-padding each dim to a multiple of g."""
        x = jnp.asarray(x)
        m, n = x.shape
        rp = pad_to_multiple(m, g) if rows_pad is None else rows_pad
        cp = pad_to_multiple(n, g) if cols_pad is None else cols_pad
        if rp < m or cp < n or rp % g or cp % g:
            raise ValueError(f"bad padded shape ({rp}, {cp}) for array "
                             f"{x.shape} on a {g}x{g} grid")
        if (rp, cp) != (m, n):
            x = jnp.zeros((rp, cp), x.dtype).at[:m, :n].set(x)
        return cls(x, g, logical_shape=(m, n))

    @classmethod
    def for_rhs(cls, x, a: DistMatrix, *, allow_pad: bool = False
                ) -> "DistDense":
        """Wrap the right operand of ``a @ x``, matching a's padded K dim.

        The inner dimension must equal a's logical or padded column count;
        anything smaller is only zero-padded with an explicit
        ``allow_pad=True`` (silent padding hides shape bugs).
        """
        x = jnp.asarray(x)
        k = x.shape[0]
        k_pad, k_log = a.shape[1], a.logical_shape[1]
        if k > k_pad:
            raise ValueError(
                f"inner dimensions disagree: right operand has {k} rows, "
                f"left operand has only {k_pad} (padded) columns")
        if k not in (k_pad, k_log) and not allow_pad:
            raise ValueError(
                f"inner dimension mismatch: right operand has {k} rows but "
                f"the left operand has {k_log} logical / {k_pad} padded "
                "columns; pass allow_pad=True to zero-pad explicitly")
        return cls.from_global(x, a.g, rows_pad=k_pad)

    @property
    def g(self) -> int:
        return self._g

    @property
    def shape(self) -> Tuple[int, int]:
        return self.data.shape

    @property
    def logical_shape(self) -> Tuple[int, int]:
        return self._logical

    @property
    def dtype(self):
        return self.data.dtype

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        tree = self._placed.get(placement)
        if tree is None:
            tree = {"dense": _place_dense(self.data, self._g, placement)}
            self._placed[placement] = tree
        return tree

    def abstract_key(self) -> tuple:
        return ("dense", self.data.shape, self._g,
                jnp.dtype(self.data.dtype).name)


def _reshard_bsr(h: DistBSR, g: int, capacity) -> DistBSR:
    t = h.tiled
    if t.row_block_perm is not None or t.col_block_perm is not None:
        raise ValueError(
            "reshard does not support balanced handles (the balance "
            "permutation is tied to the old grid); rebuild with "
            "DistBSR.from_dense(balance=...) on the new grid")
    bs = t.block_size
    g_old = h.g
    s = h.grid_structure()          # host-side rows/cols/real (cached)
    nbr_old, nbc_old = s.tile_nbr, s.tile_nbc
    m, n = h.logical_shape
    tm = pad_to_multiple(ceil_div(m, g), bs)
    tn = pad_to_multiple(ceil_div(n, g), bs)
    nbr, nbc = tm // bs, tn // bs
    rows_h = np.asarray(s.rows)
    cols_h = np.asarray(s.cols)
    real_h = np.asarray(s.real)
    store_old = rows_h.shape[2]
    # Bucket every real stored block by its *new* tile, in (row, col)
    # order — the order TiledBSR.from_dense's nonzero scan would produce.
    per_tile: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
    for i in range(g_old):
        for j in range(g_old):
            for slot in np.nonzero(real_h[i, j])[0]:
                gbr = i * nbr_old + int(rows_h[i, j, slot])
                gbc = j * nbc_old + int(cols_h[i, j, slot])
                src = (i * g_old + j) * store_old + int(slot)
                key = (gbr // nbr, gbc // nbc)
                per_tile.setdefault(key, []).append(
                    (gbr % nbr, gbc % nbc, src))
    max_nnzb = max((len(v) for v in per_tile.values()), default=0)
    if capacity == "bucket":
        cap = bucket_capacity(max_nnzb)
    elif capacity is None:
        cap = max_nnzb
    else:
        cap = int(capacity)
        if cap < max_nnzb:
            raise ValueError(f"capacity {cap} < max tile nnzb {max_nnzb}")
    store = cap + nbr
    rows_new = np.zeros((g, g, store), dtype=np.int32)
    cols_new = np.zeros((g, g, store), dtype=np.int32)
    src_new = np.full((g, g, store), -1, dtype=np.int64)
    counts_new = np.zeros((g, g), dtype=np.int32)
    cov = np.arange(nbr, dtype=np.int32)
    for i in range(g):
        for j in range(g):
            ent = sorted(per_tile.get((i, j), []))
            counts_new[i, j] = len(ent)
            r = np.array([e[0] for e in ent], dtype=np.int32)
            c = np.array([e[1] for e in ent], dtype=np.int32)
            src = np.array([e[2] for e in ent], dtype=np.int64)
            # pad to uniform capacity the way BSR.with_capacity does
            # (repeat the last coordinate, zero block), then merge the
            # coverage blocks in sorted order like _augment_tile
            pad = cap - len(ent)
            last_r = r[-1] if len(ent) else np.int32(0)
            last_c = c[-1] if len(ent) else np.int32(0)
            r = np.concatenate([r, np.full(pad, last_r, np.int32), cov])
            c = np.concatenate([c, np.full(pad, last_c, np.int32),
                                np.zeros(nbr, np.int32)])
            src = np.concatenate([src, np.full(pad + nbr, -1, np.int64)])
            order = np.argsort(r, kind="stable")
            rows_new[i, j] = r[order]
            cols_new[i, j] = c[order]
            src_new[i, j] = src[order]
    # One device gather moves every block value to its new tile slot: no
    # host round-trip of block data, no dense materialization.
    old_flat = t.blocks.reshape(-1, bs, bs)
    pool = jnp.concatenate(
        [old_flat, jnp.zeros((1, bs, bs), t.blocks.dtype)])
    idx = np.where(src_new < 0, old_flat.shape[0], src_new)
    blocks_new = pool[jnp.asarray(idx.reshape(-1))].reshape(
        g, g, store, bs, bs)
    return DistBSR(TiledBSR(
        blocks=blocks_new, rows=jnp.asarray(rows_new),
        cols=jnp.asarray(cols_new), counts=jnp.asarray(counts_new),
        shape=(tm * g, tn * g), block_size=bs, grid_shape=(g, g),
        capacity=cap, logical_shape=(m, n)))


def reshard(h: DistMatrix, g: int, *, capacity="bucket") -> DistMatrix:
    """Re-tile a handle onto a ``g x g`` grid without a host round-trip.

    The elastic-recovery path: after device loss the surviving mesh gets a
    smaller grid (``runtime.elastic.choose_grid_shape``) and the live
    operands must move onto it.  Dense handles re-pad the logical region;
    BSR handles re-bucket their stored blocks by new-tile coordinates on
    the host's cached *structure* view (integer index arithmetic only)
    and move the block *values* with a single device gather — the data
    plane never leaves the devices and nothing is re-densified.

    ``capacity`` is the rebuilt uniform tile capacity (``"bucket"`` |
    ``None`` | int, as in :meth:`DistBSR.from_dense`).  Balanced BSR
    handles are rejected: their permutation is tied to the old grid.
    Returns a new handle (``h`` itself when ``g`` already matches).
    """
    if g < 1:
        raise ValueError(f"grid size must be >= 1, got {g}")
    if isinstance(h, DistBSR):
        if g == h.g:
            return h
        return _reshard_bsr(h, g, capacity)
    if isinstance(h, DistDense):
        if g == h.g:
            return h
        m, n = h.logical_shape
        return DistDense.from_global(h.data[:m, :n], g)
    raise TypeError(f"cannot reshard {type(h).__name__}")


# ---------------------------------------------------------------------------
# Mesh preparation / validation
# ---------------------------------------------------------------------------
def validate_mesh(mesh, g: int, axis_row: str, axis_col: str) -> None:
    """Fail fast (and clearly) on a mesh that can't carry the g x g grid."""
    names = tuple(mesh.axis_names)
    if axis_row not in names or axis_col not in names:
        raise ValueError(
            f"mesh axes {names} do not include the required axes "
            f"({axis_row!r}, {axis_col!r}); build one with "
            f"make_grid_mesh({g}, {axis_row!r}, {axis_col!r})")
    if len(names) != 2:
        raise ValueError(
            f"expected a 2-axis ({axis_row!r}, {axis_col!r}) mesh, got axes "
            f"{names}")
    shape = dict(mesh.shape)
    got = (shape[axis_row], shape[axis_col])
    if got != (g, g):
        raise ValueError(
            f"mesh shape {axis_row}={got[0]}, {axis_col}={got[1]} does not "
            f"match the {g}x{g} process grid of the operands")


def _prep_mesh(mesh, g: int, axis_row: str, axis_col: str):
    if mesh is None:
        return make_grid_mesh(g, axis_row, axis_col)
    validate_mesh(mesh, g, axis_row, axis_col)
    return mesh


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------
_TRACE_HOOKS: List[Callable] = []


def add_trace_hook(hook: Callable) -> Callable:
    """Register ``hook(plan)`` to fire once per executable (re)trace."""
    _TRACE_HOOKS.append(hook)
    return hook


def remove_trace_hook(hook: Callable) -> None:
    _TRACE_HOOKS.remove(hook)


def _tree_keys(abstract_key: tuple) -> Tuple[str, ...]:
    return ("blocks", "rows", "cols") if abstract_key[0] == "bsr" \
        else ("dense",)


def _specs_for_keys(keys: Tuple[str, ...], axr: str, axc: str) -> Dict:
    out = {}
    for k in keys:
        if k == "dense":
            out[k] = P(axr, axc)
        elif k == "blocks":
            out[k] = P(axr, axc, None, None, None)
        else:  # rows / cols
            out[k] = P(axr, axc, None)
    return out


def _local_view(tree: Dict) -> Dict:
    """Strip the leading (1, 1) grid dims of TiledBSR leaves inside shard_map."""
    return {k: (v if k == "dense" else v[0, 0]) for k, v in tree.items()}


def _key_dtype(abstract_key: tuple):
    return abstract_key[5] if abstract_key[0] == "bsr" else abstract_key[3]


def _cost_model(alg: Algorithm, geom: _Geom, a_key: tuple, b_key: tuple,
                symbolic: Optional["SymbolicProduct"] = None,
                wire_caps: Optional[Dict[str, int]] = None
                ) -> Dict[str, float]:
    """Per-step wire volume / executed flops of one plan execution.

    Reflects what the bodies actually move and execute: the A tile rides in
    its stored *pre-augmented* BSR form (``capacity + tile block-rows``
    block products per step, padding included — the quantity the static
    scheduler balances); the B tile rides *densified* regardless of kind
    (``_densify_b`` hoists the scatter out of the scanned step); ``wire``
    may name a tile twice (bidirectional schedules) and ``duplex`` credits
    full-duplex links in :func:`_predicted_time`, not here.

    With ``symbolic`` (a sparse-output plan), the model charges what the
    sparse path actually does instead: B rides in stored block form (never
    densified), the step executes ``pair_capacity`` block-pair products
    (padding included), and C is the packed slot array — so sparse-output
    schedules are scored on their true output traffic, which is what makes
    ``output="auto"`` flip for hypersparse products.

    With ``wire_caps`` (a packed-wire plan: ``{"a": wc}`` and/or
    ``{"b": wc}``), a packed operand is charged blocks-only at its wire
    capacity — no coverage padding, no rows/cols index traffic, and for a
    packed sparse B no densified tile — and the packed A step executes
    the gathered coverage-augmented list (``wc + tile block-rows``
    products) instead of the stored stride.  This is what lets
    :func:`auto_select` scores flip where packing changes the
    comm/compute trade.
    """
    g = geom.g
    wire_caps = wire_caps or {}
    if symbolic is not None:
        bs = symbolic.block_size
        store_a = a_key[4] + geom.a_nbr
        store_b = b_key[4] + geom.b_nbr
        wa = np.dtype(_key_dtype(a_key)).itemsize
        wb = np.dtype(_key_dtype(b_key)).itemsize
        a_slots = wire_caps.get("a", store_a)
        b_slots = wire_caps.get("b", store_b)
        a_bytes = a_slots * bs * bs * wa
        b_bytes = b_slots * bs * bs * wb
        c_bytes = symbolic.store_capacity * bs * bs \
            * np.dtype(geom.out_dtype).itemsize
        flops_step = 2 * symbolic.pair_capacity * bs ** 3
        tiles = {"a": a_bytes, "b": b_bytes, "c": c_bytes}
        return _assemble_cost(alg, g, a_bytes, b_bytes, c_bytes, flops_step,
                              tiles)
    if a_key[0] == "bsr":
        bs, cap = a_key[3], a_key[4]
        wa = np.dtype(_key_dtype(a_key)).itemsize
        if "a" in wire_caps:
            wc = wire_caps["a"]             # packed: blocks only
            a_bytes = wc * bs * bs * wa
            # the step executes the gathered augmented list, never more
            # than the padded stride
            slots = min(wc + geom.a_nbr, cap + geom.a_nbr)
            flops_step = 2 * slots * bs * bs * geom.tn
        else:
            store = cap + geom.a_nbr        # pre-augmented stored slots
            a_bytes = store * bs * bs * wa \
                + store * 2 * 4             # + rows/cols int32
            flops_step = 2 * store * bs * bs * geom.tn
    else:
        tk = a_key[1][1] // g
        a_bytes = geom.tm * tk * np.dtype(_key_dtype(a_key)).itemsize
        flops_step = 2 * geom.tm * tk * geom.tn
    wb = np.dtype(_key_dtype(b_key)).itemsize
    if "b" in wire_caps and b_key[0] == "bsr":
        b_bytes = wire_caps["b"] * b_key[3] * b_key[3] * wb
    else:
        tk_b = b_key[1][0] // g
        b_bytes = tk_b * geom.tn * wb
    c_bytes = geom.tm * geom.tn * np.dtype(geom.out_dtype).itemsize
    tiles = {"a": a_bytes, "b": b_bytes, "c": c_bytes}
    return _assemble_cost(alg, g, a_bytes, b_bytes, c_bytes, flops_step,
                          tiles)


def _assemble_cost(alg: Algorithm, g: int, a_bytes, b_bytes, c_bytes,
                   flops_step, tiles) -> Dict[str, float]:
    step_bytes = sum(tiles[t] for t in alg.wire)
    if alg.wire_amortized:
        step_bytes = step_bytes * (g - 1) / g
    total_flops = float(flops_step * g)
    total_bytes = float(step_bytes * g)
    return {
        "steps": float(g),
        "flops_per_step": float(flops_step),
        "net_bytes_per_step": float(step_bytes),
        "total_flops": total_flops,
        "total_net_bytes": total_bytes,
        "ai_net": total_flops / total_bytes if total_bytes else float("inf"),
        "ai_local": total_flops / (g * (a_bytes + b_bytes) + c_bytes),
    }


def _overlap_eff(alg: Algorithm, machine: "_roofline.Machine",
                 overlap: str) -> float:
    """The comm-hiding fraction the cost model credits this schedule.

    ``"off"`` serializes everything.  ``"on"`` credits the machine's
    fitted ``overlap_eff`` to every schedule whose per-step transfers the
    split-step bodies can double-buffer — i.e. all but the wire-amortized
    ones (summa_ag's single up-front gather gates all compute; nothing to
    hide under).  ``"auto"`` (the scoring default) credits it only to the
    RDMA-style prefetch schedules, which reproduces the legacy
    sum-vs-max scoring exactly at the preset ``overlap_eff = 1.0``:
    bulk-synchronous schedules pay ``comp + comm`` (a barrier per stage),
    rings pay ``comp + max(0, comm - comp) = max(comp, comm)`` — the
    paper's SS3.3 overlap claim as a scheduling preference.
    """
    if overlap == "off":
        return 0.0
    if overlap == "on":
        return 0.0 if alg.wire_amortized else machine.overlap_eff
    return machine.overlap_eff if alg.style != "bsp" else 0.0


def _time_breakdown(cm: Dict[str, float], alg: Algorithm,
                    machine: "_roofline.Machine",
                    overlap: str = "auto") -> Dict[str, float]:
    """Alpha-beta-gamma time decomposition for one execution.

    Compute time is capped by the local roofline; wire time is serialized
    bytes over the per-chip link share (credited for ``duplex``) plus a
    per-message alpha term (``machine.hop_latency``).  The overlap term
    (:func:`_overlap_eff`, ``machine.overlap_eff``) converts raw comm
    into *exposed* comm — ``max(0, comm - eff * comp)`` — and the
    predicted seconds are ``comp + exposed``.
    """
    t_comp = cm["total_flops"] / _roofline.local_peak(cm["ai_local"], machine)
    if "n_msgs" in cm:
        # structure-dependent schedules (steal3d) count their actual
        # collective rounds in the cost model instead of wire x steps
        msgs = cm["n_msgs"]
    else:
        n_msgs = alg.msgs_per_step if alg.msgs_per_step is not None \
            else len(alg.wire)
        msgs = n_msgs * (1.0 if alg.wire_amortized else cm["steps"])
    t_comm = cm["total_net_bytes"] / (machine.net_bw * alg.duplex) \
        + msgs * machine.hop_latency
    eff = _overlap_eff(alg, machine, overlap)
    exposed = max(0.0, t_comm - eff * t_comp)
    return {
        "t_comp": t_comp,
        "t_comm": t_comm,
        "t_comm_exposed": exposed,
        "msgs": float(msgs),
        "duplex": float(alg.duplex),
        "overlap_eff": eff,
        "predicted_s": t_comp + exposed,
    }


def _predicted_time(cm: Dict[str, float], alg: Algorithm,
                    machine: "_roofline.Machine",
                    overlap: str = "auto") -> float:
    """Predicted seconds for one execution — the auto-select score."""
    return _time_breakdown(cm, alg, machine, overlap)["predicted_s"]


class MatmulPlan:
    """A reusable distributed multiply: placement + one compiled executable.

    Create via :func:`plan_matmul`; execute with ``plan(a, b)``.  The
    executable is ``jax.jit(shard_map(body))`` built once at plan time, so
    repeated calls with the same abstract operand shapes re-use the compiled
    program (``plan.traces`` counts actual traces).
    """

    def __init__(self, algorithm: Algorithm, geom: _Geom, mesh,
                 a_key: tuple, b_key: tuple, allow_pad: bool = False,
                 requested: Optional[str] = None,
                 auto_scores: Optional[Dict[str, float]] = None,
                 symbolic: Optional["SymbolicProduct"] = None,
                 steal: Optional["_steal3d.StealPlan"] = None,
                 wire: str = "padded", packs: Tuple[str, ...] = (),
                 wire_aux: Optional[Dict[str, np.ndarray]] = None,
                 wire_caps: Optional[Dict[str, int]] = None,
                 wire_fps: Optional[Dict[str, str]] = None,
                 overlap: str = "auto"):
        self.algorithm = algorithm
        self.geom = geom
        # the overlap mode this plan was built under ("auto"|"on"|"off");
        # geom.overlap holds the resolved body structure, this records
        # the request for cost reporting (cost_model / predicted_cost)
        self.overlap = overlap
        self.mesh = mesh
        self._a_key = a_key
        self._b_key = b_key
        self._allow_pad = allow_pad
        # Introspection: what the request that FIRST BUILT this plan asked
        # for ("auto" or a name) and, if auto ever selected this plan, the
        # candidate scores from that selection.  Cached plans are shared
        # across requests, so these describe the plan's provenance, not
        # necessarily the current call (auto re-scores on every call; see
        # plan_matmul).
        self.requested = requested or algorithm.name
        self.auto_scores = auto_scores
        self.symbolic = symbolic
        self.steal = steal
        # Packed-wire state: which operands ship packed ("a"/"b"), their
        # wire capacities (the cost-model byte terms) and the structure
        # fingerprints the consume maps were built for (the call guard).
        self.wire = wire
        self._packs = packs
        self._wire_caps = wire_caps
        self._wire_fps = wire_fps or {}
        self.traces = 0
        # static-verification memo: modes this plan already passed
        # ("fast"/"full") — revalidating a cached plan is a set lookup
        self._validated: set = set()
        specs = (_specs_for_keys(_tree_keys(a_key), geom.axr, geom.axc),
                 _specs_for_keys(_tree_keys(b_key), geom.axr, geom.axc))

        if steal is not None:
            # steal3d plan: the executable is specialized to the LPT
            # assignment — pair lists, move-round gather indices and
            # reduce-round slot selectors ride as a third operand tree
            # (committed in their mesh sharding once, like sparse-output
            # pair lists); only A's block data is sharded in for sparse A.
            body = algorithm.body
            aux_specs = {k: P(geom.axr, geom.axc, *(None,) * (v.ndim - 2))
                         for k, v in steal.aux.items()}
            self._aux = {
                k: jax.device_put(
                    np.ascontiguousarray(v),
                    jax.sharding.NamedSharding(mesh, aux_specs[k]))
                for k, v in steal.aux.items()}

            def fn(a, b, aux):
                self.traces += 1          # runs at trace time only
                for hook in list(_TRACE_HOOKS):
                    hook(self)
                return body(_local_view(a), _local_view(b),
                            {k: v[0, 0] for k, v in aux.items()}, geom,
                            steal)

            a_keys = ("blocks",) if a_key[0] == "bsr" else ("dense",)
            in_specs = (_specs_for_keys(a_keys, geom.axr, geom.axc),
                        specs[1], aux_specs)
            out_specs = P(geom.axr, geom.axc)
        elif symbolic is None and wire_aux is not None:
            # Packed-wire dense-output plan: the executable is specialized
            # to the packed operands' structures — the consume maps
            # (augmented-list gathers / densify-by-gather maps built by
            # repro.core.wire) ride as a third operand tree, committed in
            # their mesh sharding once like steal3d aux; a packed operand
            # ships blocks-only at the wire capacity.
            packed_body = algorithm.packed_body
            aux_specs = {k: P(geom.axr, geom.axc, *(None,) * (v.ndim - 2))
                         for k, v in wire_aux.items()}
            self._aux = {
                k: jax.device_put(
                    np.ascontiguousarray(v),
                    jax.sharding.NamedSharding(mesh, aux_specs[k]))
                for k, v in wire_aux.items()}

            def fn(a, b, aux):
                self.traces += 1          # runs at trace time only
                for hook in list(_TRACE_HOOKS):
                    hook(self)
                return packed_body(_local_view(a), _local_view(b),
                                   {k: v[0, 0] for k, v in aux.items()},
                                   geom)

            blocks_spec = {"blocks": P(geom.axr, geom.axc, None, None,
                                       None)}
            in_specs = (blocks_spec if "a" in packs else specs[0],
                        blocks_spec if "b" in packs else specs[1],
                        aux_specs)
            out_specs = P(geom.axr, geom.axc)
        elif symbolic is None:
            body = algorithm.body

            def fn(a, b):
                self.traces += 1          # runs at trace time only
                for hook in list(_TRACE_HOOKS):
                    hook(self)
                return body(_local_view(a), _local_view(b), geom)

            in_specs, out_specs = specs, P(geom.axr, geom.axc)
        else:
            # Sparse-output plan: the executable is specialized to the
            # operands' structures — pair lists (scheduled per the
            # algorithm's k_order) ride as a third operand tree, only the
            # block data of A and B is sharded in, and the result is the
            # packed per-tile slot array wrapped into a DistBSR by
            # _epilogue_sparse.  Under wire="packed" the operands' blocks
            # ride in packed wire form and the stored->packed slot map is
            # already composed into the (remapped) pair lists.
            sparse_body = algorithm.sparse_body
            sched = symbolic.scheduled_pairs(
                algorithm.k_order,
                pair_a=None if wire_aux is None else wire_aux.get("pa"),
                pair_b=None if wire_aux is None else wire_aux.get("pb"))
            # Pair lists are plan-lifetime constants; commit them in their
            # mesh sharding once so repeated calls don't re-transfer them
            # to every device (measurably dominates small multiplies).
            pair_sharding = jax.sharding.NamedSharding(
                mesh, P(geom.axr, geom.axc, None, None))
            self._pairs = {k: jax.device_put(np.asarray(v, dtype=np.int32),
                                             pair_sharding)
                           for k, v in sched.items()}
            self._c_rows = jnp.asarray(symbolic.c_rows, dtype=jnp.int32)
            self._c_cols = jnp.asarray(symbolic.c_cols, dtype=jnp.int32)
            self._c_counts = jnp.asarray(symbolic.c_counts, dtype=jnp.int32)

            def fn(a, b, pairs):
                self.traces += 1          # runs at trace time only
                for hook in list(_TRACE_HOOKS):
                    hook(self)
                c = sparse_body(_local_view(a), _local_view(b),
                                _local_view(pairs), geom)
                return c[None, None]      # restore the (1, 1) grid dims

            blocks_spec = {"blocks": P(geom.axr, geom.axc, None, None, None)}
            pair_spec = {k: P(geom.axr, geom.axc, None, None)
                         for k in ("pa", "pb", "ps")}
            in_specs = (blocks_spec, blocks_spec, pair_spec)
            out_specs = P(geom.axr, geom.axc, None, None, None)

        # named_scope is trace-time-only HLO metadata: XLA profiles (and
        # hlo_analysis.scope_op_counts) attribute device time to this
        # plan's schedule by name, at zero runtime cost and zero added
        # retraces (tests assert plan.traces stays 1).
        inner_fn = fn
        scope_label = f"plan.{algorithm.name}.{wire}"

        def fn(*operands):
            with jax.named_scope(scope_label):
                return inner_fn(*operands)

        self._exec = jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            # pallas_call's out_shape carries no vma annotation; the engine's
            # collectives are explicit, so skip the varying-axes checker.
            check_vma=False))

    @property
    def kind(self) -> str:
        """"spmm" | "spgemm" | "dense" — what this plan dispatches to."""
        a_sparse = self._a_key[0] == "bsr"
        b_sparse = self._b_key[0] == "bsr"
        if a_sparse:
            return "spgemm" if b_sparse else "spmm"
        return "dense"

    @property
    def output(self) -> str:
        """"sparse" (returns a DistBSR) or "dense" (returns an array)."""
        return "dense" if self.symbolic is None else "sparse"

    def __call__(self, a, b):
        # Tracing off (the default): straight to the executable — no clock
        # reads, no blocking, async dispatch preserved.
        if not _obs.enabled():
            return self._execute(a, b)
        t0 = time.perf_counter()
        sp = _obs.span(f"multiply.{self.algorithm.name}", kind=self.kind,
                       wire=self.wire, output=self.output,
                       overlap=self.overlap)
        with sp:
            out = self._execute(a, b)
            # Per-multiply seconds follow the sync_elapsed discipline:
            # block on the result tree, then read the clock.
            tree = out.tiled.blocks if isinstance(out, DistBSR) else out
            measured = _obs.sync_elapsed(t0, tree)
            sp.note(measured_s=measured)
        machine = _DRIFT_MACHINE or _roofline.TPU_V5E
        cm = self.cost_model()
        _obs.record_drift(
            self.algorithm.name, self.wire, self.overlap,
            predicted_s=_predicted_time(cm, self.algorithm, machine,
                                        self.overlap),
            measured_s=measured, cm=cm, kind=self.kind,
            machine=machine.name)
        return out

    def _operands(self, a_h: DistMatrix, b_h: DistMatrix) -> tuple:
        """Guard-check coerced handles and build the executable's operand
        tuple — exactly the arguments ``self._exec`` is called with.

        Shared by ``_execute`` and the static analyzer
        (``repro.analysis.jaxpr_lint.trace_plan``), so the linted trace is
        the executed trace: packed wire trees, steal3d aux and sparse
        pair lists included.
        """
        if (a_h.abstract_key(), b_h.abstract_key()) != (self._a_key,
                                                        self._b_key):
            raise ValueError(
                "operands do not match this plan's abstract shapes "
                f"(plan: {self._a_key} @ {self._b_key}, got "
                f"{a_h.abstract_key()} @ {b_h.abstract_key()}); build a new "
                "plan with plan_matmul")
        if self.steal is not None:
            if self._a_key[0] == "bsr":
                if a_h.structure_key() != self.steal.a_fingerprint:
                    raise ValueError(
                        "left operand's sparsity structure does not match "
                        "this steal3d plan (the LPT assignment and pair "
                        "lists are specialized to the structure); build a "
                        "new plan with plan_matmul")
                if self.steal.wire == "packed":
                    a_tree = a_h.packed_wire(self.algorithm.a_placement)
                else:
                    a_tree = {"blocks": a_h.placed(
                        self.algorithm.a_placement)["blocks"]}
            else:
                a_tree = a_h.placed(self.algorithm.a_placement)
            return (a_tree, b_h.placed(self.algorithm.b_placement),
                    self._aux)
        packed = self.wire == "packed"
        if self.symbolic is not None:
            sym = self.symbolic
            if (a_h.structure_key(), b_h.structure_key()) != \
                    (sym.a_fingerprint, sym.b_fingerprint):
                raise ValueError(
                    "operands' sparsity structure does not match this "
                    "sparse-output plan (pair lists are specialized to the "
                    "structure); build a new plan with plan_matmul")
            pl_a, pl_b = self.algorithm.a_placement, \
                self.algorithm.b_placement
            a_tree = a_h.packed_wire(pl_a) if packed \
                else {"blocks": a_h.placed(pl_a)["blocks"]}
            b_tree = b_h.packed_wire(pl_b) if packed \
                else {"blocks": b_h.placed(pl_b)["blocks"]}
            return (a_tree, b_tree, self._pairs)
        if packed:
            for who, h in (("a", a_h), ("b", b_h)):
                if who in self._packs \
                        and h.structure_key() != self._wire_fps.get(who):
                    raise ValueError(
                        f"{'left' if who == 'a' else 'right'} operand's "
                        "sparsity structure does not match this packed-wire "
                        "plan (the consume maps are specialized to the "
                        "structure); build a new plan with plan_matmul")
            a_tree = a_h.packed_wire(self.algorithm.a_placement) \
                if "a" in self._packs \
                else a_h.placed(self.algorithm.a_placement)
            b_tree = b_h.packed_wire(self.algorithm.b_placement) \
                if "b" in self._packs \
                else b_h.placed(self.algorithm.b_placement)
            return (a_tree, b_tree, self._aux)
        return (a_h.placed(self.algorithm.a_placement),
                b_h.placed(self.algorithm.b_placement))

    def _execute(self, a, b):
        a_h, b_h = _coerce_pair(a, b, g=self.geom.g,
                                allow_pad=self._allow_pad)
        c = self._exec(*self._operands(a_h, b_h))
        if self.symbolic is not None:
            return self._epilogue_sparse(c, a_h, b_h)
        return self._epilogue(c, a_h, b_h)

    def _epilogue_sparse(self, c_blocks: jnp.ndarray, a_h: DistBSR,
                         b_h: DistBSR) -> DistBSR:
        """Wrap the packed numeric result into a DistBSR handle.

        The symbolic layout already satisfies the TiledBSR storage contract
        (row-sorted, coverage-augmented, uniformly padded), so the handle
        is immediately usable as an operand of further multiplies — chained
        A @ A @ A never densifies or re-tiles.
        """
        sym = self.symbolic
        tiled = TiledBSR(
            blocks=c_blocks, rows=self._c_rows, cols=self._c_cols,
            counts=self._c_counts, shape=sym.shape,
            block_size=sym.block_size, grid_shape=(sym.g, sym.g),
            capacity=sym.capacity,
            logical_shape=(a_h.logical_shape[0], b_h.logical_shape[1]))
        return DistBSR(tiled)

    def _epilogue(self, c: jnp.ndarray, a_h: DistMatrix,
                  b_h: DistMatrix) -> jnp.ndarray:
        """Shared output fix-up: unskew, un-balance, crop padding.

        One copy for all operand kinds — the sparse and dense paths get
        identical ``logical_shape`` cropping semantics.  A balanced left
        operand permuted its global row blocks before tiling; C inherits
        that permutation, so it is inverted here (after the tile-grid
        unskew, before the crop) to keep balanced and unbalanced plans
        bit-compatible.
        """
        if self.algorithm.unskew_out == "rows":
            c = unskew_c_rows(c, self.geom.g)
        elif self.algorithm.unskew_out is not None:
            raise ValueError(
                f"unknown unskew_out {self.algorithm.unskew_out!r}")
        perm = getattr(a_h, "row_block_perm", None)
        if perm:
            bs = a_h.block_size
            inv = a_h.inv_row_perm()   # cached on the handle
            c = c.reshape(len(perm), bs, -1)[inv].reshape(c.shape)
        cperm = getattr(b_h, "col_block_perm", None)
        if cperm:
            # a cols-balanced RIGHT operand permutes C's column blocks
            # (C = A (B P) = (A B) P); invert before the crop
            bs = b_h.block_size
            inv = b_h.inv_col_perm()
            c = c.reshape(c.shape[0], len(cperm), bs)[:, inv]
            c = c.reshape(c.shape[0], -1)
        return c[:a_h.logical_shape[0], :b_h.logical_shape[1]]

    # ------------------------------------------------------------- analysis
    def validate(self, mode: str = "fast", a=None, b=None) -> None:
        """Statically verify this plan (see DESIGN.md "Static analysis").

        ``mode="fast"`` runs the host-side schedule checker over the
        plan's metadata (ppermute bijections, steal3d exactly-once +
        conservation, packed-wire consume-map contracts, sparse pair
        lists, balance perms).  ``mode="full"`` additionally traces the
        executable and runs the jaxpr lint (sort/scatter-free scan
        steps, collective count vs the cost model, overlap-carry
        happens-before).  Raises
        :class:`repro.analysis.PlanValidationError` on any finding.

        Results are memoized per plan and mode, so validating a cached
        plan is a set lookup — ``plan_matmul(validate="fast")`` on a
        warm cache costs nothing.
        """
        if mode == "off":
            return
        if mode not in ("fast", "full"):
            raise ValueError(
                f"unknown validate mode {mode!r} "
                "(expected 'off', 'fast' or 'full')")
        if mode in self._validated:
            return
        from repro import analysis as _analysis
        with _obs.span("plan_build.validate", mode=mode,
                       algorithm=self.algorithm.name):
            findings = _analysis.check_plan(self, a, b)
            if mode == "full" and not findings:
                findings = _analysis.lint_plan(self, a, b)
            if findings:
                raise _analysis.PlanValidationError(findings)
        self._validated.add(mode)
        if mode == "full":
            self._validated.add("fast")   # full subsumes fast

    def cost_model(self, a: Optional[DistBSR] = None) -> Dict[str, float]:
        """Per-step volume / flops of one plan execution (per device).

        Flop counts are the *executed* (padding and coverage included) MXU
        work, the quantity the static scheduler balances.  Pass the sparse
        left-hand handle to also get the paper's Fig-1 per-stage vs
        end-to-end imbalance from its tile counts (feeds
        ``core/schedule.py``).
        """
        if self.steal is not None:
            # structure-true cost precomputed by the steal3d planner
            # (makespan flops + gather/moved/reduce traffic)
            out = dict(self.steal.cost)
        else:
            out = _cost_model(self.algorithm, self.geom, self._a_key,
                              self._b_key, symbolic=self.symbolic,
                              wire_caps=self._wire_caps)
        if isinstance(a, DistBSR):
            per_stage, end_to_end = _schedule.stage_imbalance(
                np.asarray(a.counts, dtype=np.float64))
            out["per_stage_imbalance"] = per_stage
            out["end_to_end_imbalance"] = end_to_end
        out["duplex"] = float(self.algorithm.duplex)
        out["overlap"] = self.overlap
        return out

    def predicted_cost(self, machine: Optional["_roofline.Machine"] = None
                       ) -> float:
        """Predicted seconds per execution (the ``algorithm="auto"`` score)."""
        machine = machine or _roofline.TPU_V5E
        return _predicted_time(self.cost_model(), self.algorithm, machine,
                               self.overlap)

    def predicted_perf(self, machine: "_roofline.Machine") -> Dict[str, float]:
        """Paper SS4 inter-node roofline prediction for this plan.

        Besides the roofline point, includes the alpha-beta-gamma time
        breakdown under this plan's overlap mode: ``t_comp``, ``t_comm``,
        ``t_comm_exposed`` (comm left over after hiding
        ``overlap_eff * t_comp`` of it), and ``predicted_s``.
        """
        cm = self.cost_model()
        peak = _roofline.local_peak(cm["ai_local"], machine)
        return {
            "perf": _roofline.internode_roofline(cm["ai_net"],
                                                 cm["ai_local"], machine),
            "local_peak": peak,
            "net_bound": cm["ai_net"] * machine.net_bw < peak,
            **_time_breakdown(cm, self.algorithm, machine, self.overlap),
            **cm,
        }


# ---------------------------------------------------------------------------
# Operand coercion + plan cache + public entry points
# ---------------------------------------------------------------------------
def _compensate_rhs(b_h: DistMatrix, perm: Tuple[int, ...],
                    block_size: int) -> DistMatrix:
    """Undo a cols-balanced left operand on the right operand's row blocks.

    A ``balance="cols"`` left operand stores ``A' = A P`` (column blocks
    permuted), which permutes the contraction dimension; multiplying by
    ``B' = P^T B`` (row blocks gathered by the same permutation) restores
    ``A' B' = A B``, so the output needs no fix-up — the ROADMAP's "invert
    on B instead".  The compensated handle is cached on the right operand,
    keyed by the permutation, so repeated plans/calls reuse one transform
    (and one abstract key).
    """
    cache = getattr(b_h, "_col_compensated", None)
    if cache is None:
        cache = b_h._col_compensated = {}
    if getattr(b_h, "_compensated_for", None) == perm:
        return b_h                       # already the compensated handle
    got = cache.get(perm)
    if got is not None:
        return got
    perm_arr = np.asarray(perm)
    if isinstance(b_h, DistDense):
        data = b_h.data
        nbr = data.shape[0] // block_size
        data = data.reshape(nbr, block_size, -1)[jnp.asarray(perm_arr)]
        new = DistDense(data.reshape(b_h.shape), b_h.g,
                        logical_shape=b_h.logical_shape)
    else:
        # sparse right operand: host-side dense round trip (construction
        # time, like from_tiled re-balancing), preserving any carried
        # column permutation of B itself (the epilogue inverts it on C)
        t = b_h.tiled
        d = np.asarray(t.to_dense())
        nbr = d.shape[0] // block_size
        d = d.reshape(nbr, block_size, -1)[perm_arr].reshape(d.shape)
        m, n = t.logical_shape or t.shape
        newt = TiledBSR.from_dense(d, ProcessGrid(*t.grid_shape),
                                   t.block_size, capacity="bucket",
                                   dtype=t.dtype)
        newt = dataclasses.replace(newt, logical_shape=(m, n),
                                   col_block_perm=t.col_block_perm)
        new = DistBSR(newt)
    new._compensated_for = perm          # idempotence marker (re-coercion)
    cache[perm] = new
    return new


def _coerce_pair(a, b, *, g: Optional[int] = None, allow_pad: bool = False
                 ) -> Tuple[DistMatrix, DistMatrix]:
    if isinstance(a, DistMatrix):
        a_h = a
    elif isinstance(a, TiledBSR):
        a_h = DistBSR.from_tiled(a)
    else:
        arr = jnp.asarray(a)
        if g is None:
            raise ValueError(
                "a dense left operand needs g=<grid size> or a DistDense "
                "handle (DistDense.from_global)")
        a_h = DistDense.from_global(arr, g)
    if g is not None and a_h.g != g:
        raise ValueError(f"left operand lives on a {a_h.g}x{a_h.g} grid, "
                         f"but g={g} was requested")

    if isinstance(b, DistMatrix):
        b_h = b
    elif isinstance(b, TiledBSR):
        b_h = DistBSR.from_tiled(b)
    else:
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h, allow_pad=allow_pad)

    if getattr(b_h, "row_block_perm", None):
        raise ValueError(
            "the right operand carries a balance='rows' row-block "
            "permutation, which would permute the contraction dimension; "
            "balanced matrices may only be the left operand (the epilogue "
            "inverts the permutation on output rows)")
    if isinstance(a_h, DistDense) and isinstance(b_h, DistBSR):
        raise NotImplementedError(
            "dense x sparse is not supported; compute the transposed "
            "product sparse x dense instead (B^T A^T = (AB)^T)")
    if a_h.g != b_h.g:
        raise ValueError(f"operands on different process grids: "
                         f"{a_h.g}x{a_h.g} vs {b_h.g}x{b_h.g}")
    if a_h.shape[1] != b_h.shape[0]:
        raise ValueError(
            f"inner (padded) dimensions disagree: A is {a_h.shape}, B is "
            f"{b_h.shape}; build the right operand with "
            "DistDense.for_rhs(b, a) to match A's padding")
    cperm = getattr(a_h, "col_block_perm", None)
    if cperm:
        # cols-balanced left operand: permute B's row blocks to compensate
        b_h = _compensate_rhs(b_h, cperm, a_h.block_size)
    return a_h, b_h


def _geometry(a_h: DistMatrix, b_h: DistMatrix, *, impl: Optional[str],
              axis_row: str, axis_col: str, c_store: int = 0,
              overlap: bool = False) -> _Geom:
    a_bsr = isinstance(a_h, DistBSR)
    b_bsr = isinstance(b_h, DistBSR)
    return _Geom(
        g=a_h.g, tm=a_h.tile_shape[0], tn=b_h.tile_shape[1],
        a_nbr=(a_h.tile_shape[0] // a_h.block_size) if a_bsr else 0,
        b_nbr=(b_h.tile_shape[0] // b_h.block_size) if b_bsr else 0,
        b_nbc=(b_h.tile_shape[1] // b_h.block_size) if b_bsr else 0,
        impl=impl, axr=axis_row, axc=axis_col,
        out_dtype=jnp.promote_types(a_h.dtype, b_h.dtype), c_store=c_store,
        overlap=overlap)


def _symbolic_for(a_h: DistBSR, b_h: DistBSR) -> "SymbolicProduct":
    """Memoized symbolic phase, keyed on the operands' structures."""
    key = (a_h.structure_key(), b_h.structure_key())
    sym = _SYMBOLIC_CACHE.get(key)
    if sym is None:
        with _obs.span("plan_build.symbolic"):
            sym = _symbolic.symbolic_spgemm(a_h.tiled, b_h.tiled)
        _SYMBOLIC_CACHE[key] = sym
    return sym


def _predicted_density_for(a_h: DistBSR, b_h: DistBSR) -> float:
    """Memoized structure-only density (the output="auto" decision input)."""
    key = (a_h.structure_key(), b_h.structure_key())
    sym = _SYMBOLIC_CACHE.get(key)
    if sym is not None:
        return sym.density()
    d = _DENSITY_CACHE.get(key)
    if d is None:
        d = _symbolic.predicted_density(a_h.tiled, b_h.tiled)
        _DENSITY_CACHE[key] = d
    return d


def _sparse_output_eligible(a_h: DistMatrix, b_h: DistMatrix) -> Optional[str]:
    """None when output="sparse" can serve these operands, else the reason."""
    if not (isinstance(a_h, DistBSR) and isinstance(b_h, DistBSR)):
        return "sparse output needs two block-sparse (DistBSR) operands"
    if a_h.block_size != b_h.block_size:
        return (f"sparse output needs equal block sizes, got "
                f"{a_h.block_size} and {b_h.block_size}")
    for h, who in ((a_h, "left"), (b_h, "right")):
        if getattr(h, "row_block_perm", None) or \
                getattr(h, "col_block_perm", None):
            return (
                f"sparse output does not support balanced operands: the "
                f"{who} operand carries a balance permutation, which the "
                "symbolic phase cannot compose into its pair lists yet; "
                'either keep a dense output for this multiply '
                '(output="dense") or rebuild the operand without balancing '
                '(balance="none")')
    return None


def _mesh_key(mesh):
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return id(mesh)


def _resolve_overlap(overlap: str) -> str:
    """Validate the ``overlap=`` request ("auto" | "on" | "off").

    ``"auto"`` (default) builds the split-step double-buffered bodies for
    the scanned schedules (steal3d's segment split stays opt-in — see
    :func:`plan_matmul`) and
    scores schedules with the legacy per-style overlap preference;
    ``"on"`` additionally credits the fitted ``machine.overlap_eff`` to
    every non-amortized schedule when scoring; ``"off"`` builds the
    bulk-synchronous bodies and serializes comm in every score (the A/B
    baseline ``benchmarks/overlap_bench.py`` measures against).
    """
    if overlap not in ("auto", "on", "off"):
        raise ValueError(f"unknown overlap {overlap!r}; one of "
                         "('auto', 'on', 'off')")
    return overlap


def _resolve_wire(wire: str, output: str) -> str:
    """Resolve the ``wire=`` request ("auto" | "padded" | "packed").

    ``"auto"`` keeps today's behaviour for dense-output plans (padded
    wire, so structurally different operands with equal abstract shapes
    keep sharing one cached plan) and resolves to ``"packed"`` for
    sparse-output plans, which are specialized to the operands' structure
    anyway — there packing is a strict win.
    """
    if wire not in ("auto", "padded", "packed"):
        raise ValueError(f"unknown wire {wire!r}; one of "
                         "('auto', 'padded', 'packed')")
    if wire == "auto":
        return "packed" if output == "sparse" else "padded"
    return wire


def _wire_caps_for(a_h: DistMatrix, b_h: DistMatrix,
                   packable: Tuple[str, ...]) -> Dict[str, int]:
    """Estimated packed wire capacities from the handles' stored counts.

    ``counts`` bounds the data-real block count from above (a chained
    sparse-output handle may store structurally-predicted blocks that are
    numerically zero), so scoring stays devices-free while actual plans
    pack against the exact structure.
    """
    caps = {}
    for who, h in (("a", a_h), ("b", b_h)):
        if who in packable and isinstance(h, DistBSR):
            counts = np.asarray(h.counts)
            caps[who] = wire_capacity(
                int(counts.max()) if counts.size else 0,
                h.tiled.store_capacity)
    return caps


def _b_pack_wins(b_h: DistMatrix) -> bool:
    """Whether packing B beats the densified tile on a dense-output path.

    Packed A always wins (wire capacity <= stored stride, and the
    rows/cols index traffic stays home), but a dense-output body consumes
    B as a dense tile either way — so shipping B packed only pays when
    its real blocks cover less than the tile: near-block-dense operands
    keep riding densified.  Decided on stored ``counts`` (an upper bound
    on real blocks), so a win is never claimed that packing can't keep.
    """
    if not isinstance(b_h, DistBSR):
        return False
    counts = np.asarray(b_h.counts)
    wc = wire_capacity(int(counts.max()) if counts.size else 0,
                       b_h.tiled.store_capacity)
    bs = b_h.block_size
    tm, tn = b_h.tile_shape
    return wc * bs * bs < tm * tn


def auto_select(a, b, *, machine: Optional["_roofline.Machine"] = None,
                g: Optional[int] = None, allow_pad: bool = False,
                axis_row: str = "row", axis_col: str = "col",
                registry: Optional[AlgorithmRegistry] = None,
                output: str = "dense", wire: str = "auto",
                overlap: str = "auto", _symbolic=None
                ) -> Tuple[str, Dict[str, float]]:
    """Score every registered schedule for ``a @ b``; pick the cheapest.

    Returns ``(name, scores)`` where ``scores`` maps every algorithm to its
    predicted seconds (:func:`_predicted_time` on its cost model).  Pure
    planning — no mesh or devices needed, so large grids can be scored on
    a single host.  Ties resolve to registration order.

    ``output="sparse"`` scores only the schedules with a sparse-output
    body, against the symbolic-phase cost model: B rides in stored block
    form and C is charged at its *actual* packed size, so the ranking can
    differ from the dense-output one for the same operands.

    ``wire="packed"`` scores every schedule against its *packed* wire
    terms (each schedule's packable operands at their wire capacities;
    steal3d's packed gather/moved/reduce rounds), so the choice flips
    where shipping only real blocks changes the comm/compute trade.

    ``overlap`` feeds the cost model's comm-hiding term (see
    :func:`_overlap_eff`): ``"on"`` credits the machine's fitted
    ``overlap_eff`` to every non-amortized schedule, so with a fitted
    machine the choice can flip toward a schedule whose comm hides
    under its compute.
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    machine = machine or _roofline.TPU_V5E
    registry = registry or REGISTRY
    wire = _resolve_wire(wire, output)
    overlap = _resolve_overlap(overlap)
    if wire == "packed" and not (isinstance(a_h, DistBSR)
                                 or isinstance(b_h, DistBSR)):
        raise ValueError(
            "wire='packed' needs at least one block-sparse (DistBSR) "
            "operand — dense operands have no packable structure; use "
            "wire='padded'")
    sym = None
    candidates = list(registry)
    if output == "sparse":
        reason = _sparse_output_eligible(a_h, b_h)
        if reason:
            raise ValueError(reason)
        sym = _symbolic if _symbolic is not None else _symbolic_for(a_h, b_h)
        candidates = [alg for alg in candidates
                      if alg.sparse_body is not None]
    # geom.overlap here only reaches the steal3d planner cache (cost
    # scoring never reads it); match plan_matmul's opt-in rule so the
    # scoring build is the one a steal3d win then reuses.
    geom = _geometry(a_h, b_h, impl=None, axis_row=axis_row,
                     axis_col=axis_col,
                     c_store=sym.store_capacity if sym else 0,
                     overlap=overlap == "on")
    a_key, b_key = a_h.abstract_key(), b_h.abstract_key()
    scores = {}
    for alg in candidates:
        if alg.cost_fn is not None:       # structure-dependent (steal3d)
            cm = alg.cost_fn(alg, geom, a_h, b_h, wire=wire)
        else:
            caps = None
            if wire == "packed":
                packable = ("a", "b") if sym is not None else alg.packable
                caps = _wire_caps_for(a_h, b_h, packable)
                if sym is None and "b" in caps and not _b_pack_wins(b_h):
                    del caps["b"]
            cm = _cost_model(alg, geom, a_key, b_key, symbolic=sym,
                             wire_caps=caps)
        scores[alg.name] = _predicted_time(cm, alg, machine, overlap)
    if not scores:
        raise ValueError("no algorithms registered" if output != "sparse"
                         else "no sparse-output algorithms registered")
    return min(scores, key=scores.get), scores


# output="auto" emits a sparse DistBSR when the symbolic phase predicts C's
# block density at or below this threshold; above it, the packed form loses
# its footprint advantage and scatter overhead dominates the dense MXU path.
SPARSE_OUTPUT_DENSITY_THRESHOLD = 0.25


def _plan_matmul_impl(a, b, *, algorithm: str = "ring_c", mesh=None,
                impl: Optional[str] = None, g: Optional[int] = None,
                axis_row: str = "row", axis_col: str = "col",
                allow_pad: bool = False, cache: bool = True,
                machine: Optional["_roofline.Machine"] = None,
                output: str = "dense",
                sparse_threshold: Optional[float] = None,
                wire: str = "auto", overlap: str = "auto",
                validate: str = "off", assignment=None) -> MatmulPlan:
    """Build (or fetch from the shared cache) a plan for ``a @ b``.

    ``a`` / ``b`` may be :class:`DistMatrix` handles (preferred — placement
    caches live on the handle), raw :class:`TiledBSR` values, or plain dense
    arrays (``g`` required when both are dense).  ``cache=False`` forces a
    fresh plan — i.e. the legacy per-call behaviour, retracing every time.

    ``algorithm="auto"`` scores every registered schedule with
    :func:`auto_select` (against ``machine``, default TPU v5e) and builds
    the min-predicted-cost one; the choice and all candidate scores are
    recorded on the plan (``plan.requested``, ``plan.auto_scores``).

    ``output`` selects the SpGEMM output representation: ``"dense"`` (the
    default — the plan returns a cropped dense array), ``"sparse"`` (two
    DistBSR operands only; the symbolic phase predicts C's block structure,
    the numeric phase accumulates straight into packed blocks, and the plan
    returns a :class:`DistBSR` that chains into further multiplies without
    a densify/re-tile round trip), or ``"auto"`` (sparse when the predicted
    output block density is at or below ``sparse_threshold``, default
    :data:`SPARSE_OUTPUT_DENSITY_THRESHOLD`).  Sparse-output plans are
    specialized to the operands' sparsity *structure* (not values), which
    joins the cache key.

    ``wire`` selects the communication layout: ``"padded"`` ships sparse
    tiles at their stored ``store_capacity`` stride, ``"packed"`` ships
    only real blocks (``repro.core.wire``: blocks-only buffers at the
    bucketed wire capacity, consume maps stay home) on every path the
    schedule supports, and ``"auto"`` (default) packs sparse-output plans
    — already structure-specialized, so packing there is a strict win —
    while keeping dense-output plans padded so structurally different
    operands with equal abstract shapes keep sharing one cached plan.
    Packed plans join the cache keyed on the packed operands' structure
    fingerprints; a schedule with no packable traffic for these operands
    (e.g. ``ring_a`` with a dense B) degrades to its padded plan.

    ``overlap`` selects the schedule bodies' dependence structure:
    ``"auto"`` (default) and ``"on"`` build the split-step
    double-buffered bodies — step t+1's collective issues *before* step
    t's accumulate, carrying a two-slot buffer per stream, so the
    compiler/runtime can fly transfers under compute — while ``"off"``
    builds the bulk-synchronous bodies (the measurement baseline).
    Exception: steal3d's own/stolen segment split costs an extra kernel
    dispatch, so ``"auto"`` keeps its bulk single-segment plan and only
    explicit ``"on"`` splits it.  The mode also feeds auto-selection's
    comm-hiding credit (see :func:`auto_select`) and joins the cache
    key.

    ``validate`` statically verifies the plan before handing it back
    (see DESIGN.md "Static analysis"): ``"off"`` (default) skips,
    ``"fast"`` runs the host-side schedule checker (ppermute bijections,
    steal3d exactly-once, packed consume-map contracts, sparse pair
    lists, balance perms), ``"full"`` additionally traces the executable
    and runs the jaxpr lint.  Verification is memoized per plan, so a
    cache hit revalidates for free; any finding raises
    :class:`repro.analysis.PlanValidationError` with named rule ids.

    ``assignment`` injects a prebuilt :class:`repro.core.schedule.Assignment3D`
    into a static-planner schedule (steal3d) in place of the plan-time LPT
    — the elastic-recovery path, where the assignment was rebuilt for a
    surviving mesh.  It requires an explicit static-planner ``algorithm``
    (not ``"auto"``), runs ``validate_assignment``'s fail-fast invariant
    checks inside ``build_steal_plan``, and bypasses the plan cache in
    both directions (an injected plan is never shared).
    """
    if validate not in ("off", "fast", "full"):
        raise ValueError(f"unknown validate {validate!r}; one of "
                         "('off', 'fast', 'full')")
    if assignment is not None:
        if algorithm == "auto" \
                or REGISTRY.get(algorithm).static_planner is None:
            raise ValueError(
                "assignment= requires an explicit algorithm with a static "
                "planner (steal3d); "
                f"got algorithm={algorithm!r}")
        cache = False
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    overlap = _resolve_overlap(overlap)
    if output not in ("dense", "sparse", "auto"):
        raise ValueError(f"unknown output {output!r}; one of "
                         "('dense', 'sparse', 'auto')")
    if output == "sparse":
        reason = _sparse_output_eligible(a_h, b_h)
        if reason:
            raise ValueError(reason)
    elif output == "auto":
        if sparse_threshold is None:
            sparse_threshold = SPARSE_OUTPUT_DENSITY_THRESHOLD
        alg_can_sparse = algorithm == "auto" or \
            REGISTRY.get(algorithm).sparse_body is not None
        if alg_can_sparse and _sparse_output_eligible(a_h, b_h) is None \
                and _predicted_density_for(a_h, b_h) <= sparse_threshold:
            output = "sparse"
        else:
            output = "dense"
    requested = algorithm
    auto_scores = None
    wire = _resolve_wire(wire, output)
    if wire == "packed" and not (isinstance(a_h, DistBSR)
                                 or isinstance(b_h, DistBSR)):
        raise ValueError(
            "wire='packed' needs at least one block-sparse (DistBSR) "
            "operand — dense operands have no packable structure; use "
            "wire='padded'")
    sym = _symbolic_for(a_h, b_h) if output == "sparse" else None
    if algorithm == "auto":
        with _obs.span("plan_build.auto_select"):
            algorithm, auto_scores = auto_select(
                a_h, b_h, machine=machine, axis_row=axis_row,
                axis_col=axis_col, allow_pad=allow_pad, output=output,
                wire=wire, overlap=overlap, _symbolic=sym)
    alg = REGISTRY.get(algorithm)
    if sym is not None and alg.sparse_body is None:
        raise ValueError(
            f"algorithm {algorithm!r} has no sparse-output body; one of "
            f"{sparse_algorithms()} (or use output='dense')")
    # which operands actually ship packed on this plan (a schedule with no
    # packable traffic for these operands degrades to its padded plan)
    packs: Tuple[str, ...] = ()
    if wire == "packed":
        if sym is not None:
            packs = ("a", "b")
        elif alg.static_planner is not None:
            # static planners pack the A side only (declared via packable)
            packs = ("a",) if "a" in alg.packable \
                and isinstance(a_h, DistBSR) else ()
        elif alg.packed_body is not None:
            packs = tuple(
                t for t in alg.packable
                if isinstance(a_h if t == "a" else b_h, DistBSR))
            if "b" in packs and not _b_pack_wins(b_h):
                # a near-block-dense B is cheaper densified than packed;
                # keep it riding as a dense tile (see _b_pack_wins)
                packs = tuple(t for t in packs if t != "b")
        if not packs:
            wire = "padded"
    mesh = _prep_mesh(mesh, a_h.g, axis_row, axis_col)
    key = (alg.name, impl, axis_row, axis_col, allow_pad, overlap,
           _mesh_key(mesh), a_h.abstract_key(), b_h.abstract_key())
    if sym is not None:
        # pair lists are baked into the executable, so the structure is
        # part of the plan's identity, not just its abstract shapes
        key += ("sparse", a_h.structure_key(), b_h.structure_key())
    if alg.static_planner is not None:
        # the LPT assignment (and therefore the executable's pair lists
        # and rounds) is a function of A's sparsity structure
        key += ("steal", a_h.structure_key()
                if isinstance(a_h, DistBSR) else None)
    if wire == "packed":
        # consume maps / remapped pair lists are baked per structure
        key += ("wire-packed",) + tuple(
            (a_h if t == "a" else b_h).structure_key() for t in packs)
    if cache:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            if auto_scores is not None and plan.auto_scores is None:
                plan.auto_scores = auto_scores   # record for introspection
            plan.validate(validate, a_h, b_h)
            return plan
    # Scanned schedules double-buffer on "auto" (the split is a pure
    # scan reordering — free).  steal3d's own/stolen segment split costs
    # a second kernel dispatch, which only pays for itself when the
    # stolen-tile transfers are genuinely asynchronous — so it is
    # opt-in: explicit overlap="on" only.
    body_overlap = (overlap == "on") if alg.static_planner is not None \
        else (overlap != "off")
    geom = _geometry(a_h, b_h, impl=impl, axis_row=axis_row,
                     axis_col=axis_col,
                     c_store=sym.store_capacity if sym else 0,
                     overlap=body_overlap)
    steal = alg.static_planner(a_h, b_h, geom, wire=wire,
                               assignment=assignment) \
        if alg.static_planner is not None else None
    wire_aux = wire_caps = wire_fps = None
    if wire == "packed" and steal is None:
        with _obs.span("plan_build.wire", packs="".join(packs)):
            a_po = a_h.packed_operand() if "a" in packs else None
            b_po = b_h.packed_operand() if "b" in packs else None
            wire_caps = {t: po.wire_capacity for t, po in
                         (("a", a_po), ("b", b_po)) if po is not None}
            wire_fps = {t: po.fingerprint for t, po in
                        (("a", a_po), ("b", b_po)) if po is not None}
            if sym is not None:
                # compose the stored->packed slot maps into the pair lists
                wire_aux = {
                    "pa": _wire.remap_pairs_packed(sym.pair_a, a_po, "a"),
                    "pb": _wire.remap_pairs_packed(sym.pair_b, b_po, "b"),
                }
            else:
                wire_aux = alg.wire_planner(a_po, b_po, geom)
    elif steal is not None and steal.wire == "packed":
        wire_caps = {"a": steal.a_wire_capacity}
    with _obs.span("plan_build.executable", algorithm=alg.name):
        plan = MatmulPlan(alg, geom,
                          mesh, a_h.abstract_key(), b_h.abstract_key(),
                          allow_pad=allow_pad, requested=requested,
                          auto_scores=auto_scores, symbolic=sym,
                          steal=steal, wire=wire, packs=packs,
                          wire_aux=wire_aux, wire_caps=wire_caps,
                          wire_fps=wire_fps, overlap=overlap)
    plan.validate(validate, a_h, b_h)
    if cache:
        _PLAN_CACHE[key] = plan
    return plan


def plan_matmul(a, b, **kw) -> MatmulPlan:
    sp = _obs.span("plan_build",
                   algorithm=str(kw.get("algorithm", "ring_c")),
                   output=str(kw.get("output", "dense")),
                   wire=str(kw.get("wire", "auto")),
                   overlap=str(kw.get("overlap", "auto")))
    hits0 = _PLAN_CACHE.hits
    with sp:
        plan = _plan_matmul_impl(a, b, **kw)
        sp.note(algorithm=plan.algorithm.name, wire=plan.wire,
                output=plan.output, cached=_PLAN_CACHE.hits > hits0)
    return plan


plan_matmul.__doc__ = _plan_matmul_impl.__doc__


def matmul(a, b, *, algorithm: str = "ring_c", mesh=None,
           impl: Optional[str] = None, g: Optional[int] = None,
           axis_row: str = "row", axis_col: str = "col",
           allow_pad: bool = False,
           machine: Optional["_roofline.Machine"] = None,
           output: str = "dense",
           sparse_threshold: Optional[float] = None,
           wire: str = "auto", overlap: str = "auto"):
    """Polymorphic distributed ``a @ b``.

    Dispatches sparse x dense -> SpMM, sparse x sparse -> SpGEMM, and
    dense x dense -> the dense engine, all through the shared plan cache:
    repeated calls with the same abstract shapes never re-trace.
    ``algorithm="auto"`` cost-model-selects the schedule and
    ``output="sparse"|"auto"`` returns a :class:`DistBSR` for sparse
    products, so chained multiplies ``matmul(matmul(a, a), a)`` stay packed
    end to end (see :func:`plan_matmul`).
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    plan = plan_matmul(a_h, b_h, algorithm=algorithm, mesh=mesh, impl=impl,
                       axis_row=axis_row, axis_col=axis_col,
                       allow_pad=allow_pad, machine=machine, output=output,
                       sparse_threshold=sparse_threshold, wire=wire,
                       overlap=overlap)
    return plan(a_h, b_h)
