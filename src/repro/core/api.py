"""Plan-based public API for the distributed sparse-matmul engine.

The paper's NVSHMEM implementation builds its algorithms on *persistent*
distributed-matrix objects (BCL ``DMatrix``) with a global pointer
directory: placement and skew are decided once, at construction, and every
multiply afterwards is pure communication + compute.  This module is the
TPU analogue of that design:

* :class:`DistBSR` / :class:`DistDense` — distributed-matrix *handles*
  wrapping a :class:`~repro.core.bsr.TiledBSR` / a grid-padded dense array.
  A handle carries the process-grid geometry, dtype, logical (uncropped)
  shape and — crucially — a cache of *placements* (natural / skew-rows /
  skew-cols / stationary-A), so the paper's ``k_offset`` skew is
  materialized at most once per operand and reused across calls.
* :func:`plan_matmul` -> :class:`MatmulPlan` — precomputes the static
  :class:`_Geom`, operand pack specs and placement requirements, and holds
  one jit-compiled ``shard_map`` executable: calling the plan again with the
  same abstract shapes never re-traces.  ``plan.cost_model()`` exposes the
  per-step network volume / flops that feed ``core/roofline.py`` and
  ``core/schedule.py``.
* :func:`matmul` — one polymorphic entry point dispatching
  sparse x dense -> SpMM, sparse x sparse -> SpGEMM and dense x dense ->
  the dense engine through :data:`REGISTRY` (an :class:`AlgorithmRegistry`).
  Algorithms register declaratively with their required operand placements,
  output unskew and per-step wire traffic, so new schedules (work-stealing
  layouts, stationary-B, ...) plug in without touching the engine.

The algorithm family (see the body docstrings): ``summa_bcast`` /
``summa_ag`` are the bulk-synchronous baselines, ``ring_c`` / ``ring_a``
the RDMA-style stationary-C / stationary-A rings with placement-time
``k_offset`` skew and prefetch via early ``ppermute``, and
``ring_c_bidir`` a bidirectional stationary-C ring that splits the output
into column half-panels circulating in opposite directions (full-duplex
links).  ``plan_matmul(..., algorithm="auto")`` scores every registered
schedule with the alpha-beta-gamma cost model (:func:`auto_select`) and
builds the cheapest — the static analogue of Bharadwaj et al.'s
observation that the best distributed sparse schedule flips with sparsity
and aspect ratio.

Two hot-loop invariants the bodies maintain (asserted by the jaxpr test in
``tests/test_api.py``): sparse A tiles arrive *pre-augmented* from
:class:`~repro.core.bsr.TiledBSR` (no coverage concat+sort inside the
scanned step), and sparse B tiles are densified once per ring pass, before
the scan (``_densify_b``), never inside it.

The legacy free functions in ``core/spmm.py`` remain as deprecated shims
delegating to the shared plan cache here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import pvary, shard_map
from ..kernels import ops as kops
from ..kernels import ref as kref
from . import roofline as _roofline
from . import schedule as _schedule
from .bsr import TiledBSR
from .dist import (make_grid_mesh, place_b_for_stationary_a, skew_bsr,
                   skew_dense, unskew_c_rows)
from .grid import ProcessGrid, pad_to_multiple

__all__ = [
    "NATURAL", "SKEW_ROWS", "SKEW_COLS", "STATIONARY_A", "PLACEMENTS",
    "DistMatrix", "DistBSR", "DistDense",
    "Algorithm", "AlgorithmRegistry", "REGISTRY", "register_algorithm",
    "algorithms", "auto_select",
    "MatmulPlan", "plan_matmul", "matmul",
    "add_trace_hook", "remove_trace_hook",
    "clear_plan_cache", "plan_cache_size",
    "validate_mesh",
]

# Placement states a DistMatrix can hold (the paper's directory remaps).
NATURAL = "natural"            # tile (i, j) at mesh position (i, j)
SKEW_ROWS = "skew_rows"        # position (i, j) holds tile (i, (i+j)%g)
SKEW_COLS = "skew_cols"        # position (i, j) holds tile ((i+j)%g, j)
STATIONARY_A = "stationary_a"  # position (i, j) holds tile (j, (i+j)%g)
PLACEMENTS = (NATURAL, SKEW_ROWS, SKEW_COLS, STATIONARY_A)


@dataclasses.dataclass(frozen=True)
class _Geom:
    """Static geometry threaded to the shard_map bodies via closure."""
    g: int
    tm: int           # local C tile rows
    tn: int           # local C tile cols
    a_nbr: int        # block-rows per A tile (0 => dense A)
    b_nbr: int        # block-rows per B tile (0 => dense B)
    b_nbc: int        # block-cols per B tile (0 => dense B)
    impl: Optional[str]
    axr: str
    axc: str
    out_dtype: object


# ---------------------------------------------------------------------------
# Local tile math (operand trees hold ONLY arrays)
# ---------------------------------------------------------------------------
def _densify_b(b: Dict, geom: _Geom) -> Dict:
    """Densify a sparse B tile ONCE, before the scanned ring steps.

    Every schedule consumes B as a dense tile; doing the scatter here means
    each B tile is densified at most once per ring pass, and the scanned
    step body stays free of scatter/sort work (asserted by the jaxpr test).
    The densified tile is also what rides the wire — see ``_cost_model``.
    """
    if "dense" in b:
        return b
    return {"dense": kref.densify_raw(b["blocks"], b["rows"], b["cols"],
                                      geom.b_nbr, geom.b_nbc)}


def _local_mm(a: Dict, b: Dict, geom: _Geom) -> jnp.ndarray:
    b_dense = b["dense"]    # bodies pre-densify sparse B via _densify_b
    if "dense" in a:
        out = jnp.dot(a["dense"], b_dense, preferred_element_type=jnp.float32)
    else:
        # TiledBSR tiles are pre-augmented/pre-sorted at tiling time, so the
        # kernel wrapper must not redo coverage inside the compiled loop.
        out = kops.bsr_spmm_raw(a["blocks"], a["rows"], a["cols"], b_dense,
                                n_block_rows=geom.a_nbr, impl=geom.impl,
                                augment=False)
    return out.astype(geom.out_dtype)


def _tree_ppermute(tree: Dict, axis: str, g: int, sign: int = 1) -> Dict:
    perm = [((d + sign) % g, d) for d in range(g)]
    return {k: lax.ppermute(v, axis, perm) for k, v in tree.items()}


def _tree_bcast(tree: Dict, axis: str, root, my_idx) -> Dict:
    sel = my_idx == root
    return {k: lax.psum(jnp.where(sel, v, jnp.zeros_like(v)), axis)
            for k, v in tree.items()}


def _pvary(x, geom: _Geom):
    return pvary(x, (geom.axr, geom.axc))


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------
# Shared plan cache (defined before the registry: registering over an
# existing algorithm name must evict that name's cached plans).
_PLAN_CACHE: Dict[tuple, "MatmulPlan"] = {}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def _evict_plans_for_algorithm(name: str) -> None:
    for key in [k for k in _PLAN_CACHE if k[0] == name]:
        del _PLAN_CACHE[key]


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered schedule: shard_map body + declarative placement needs.

    ``a_placement`` / ``b_placement`` name the :data:`PLACEMENTS` state each
    operand must be in before the body runs (the handle caches the
    transform); ``unskew_out`` names the inverse placement applied to the
    output; ``wire`` lists which tiles ride the network each inner step
    (repeats allowed — ``ring_c_bidir`` ships A in both directions; feeds
    :meth:`MatmulPlan.cost_model`); ``wire_amortized`` marks schedules whose
    communication happens once up front (all-gather) rather than per step;
    ``duplex=2`` marks schedules that split traffic over both directions of
    the full-duplex links, halving serialized wire time.
    """
    name: str
    body: Callable
    a_placement: str = NATURAL
    b_placement: str = NATURAL
    unskew_out: Optional[str] = None        # None | "rows"
    wire: Tuple[str, ...] = ("a", "b")      # tile names from {"a", "b", "c"}
    wire_amortized: bool = False
    style: str = "rdma"                     # "rdma" | "bsp"
    duplex: int = 1                         # link directions used per step
    msgs_per_step: Optional[int] = None     # alpha-term count; len(wire) if
                                            # None (bidir splits B: 4 msgs)


class AlgorithmRegistry:
    """Name -> :class:`Algorithm` map driving :func:`matmul` dispatch."""

    def __init__(self):
        self._algorithms: Dict[str, Algorithm] = {}

    def register(self, alg: Algorithm, *, overwrite: bool = False) -> Algorithm:
        for placement, who in ((alg.a_placement, "a"), (alg.b_placement, "b")):
            if placement not in PLACEMENTS:
                raise ValueError(
                    f"algorithm {alg.name!r}: unknown {who}_placement "
                    f"{placement!r}; one of {PLACEMENTS}")
        if alg.name in self._algorithms:
            if not overwrite:
                raise ValueError(f"algorithm {alg.name!r} already registered")
            _evict_plans_for_algorithm(alg.name)
        self._algorithms[alg.name] = alg
        return alg

    def unregister(self, name: str) -> None:
        if self._algorithms.pop(name, None) is not None:
            _evict_plans_for_algorithm(name)

    def get(self, name: str) -> Algorithm:
        try:
            return self._algorithms[name]
        except KeyError:
            raise ValueError(
                f"unknown algorithm {name!r}; one of {self.names()}") from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._algorithms)

    def __contains__(self, name: str) -> bool:
        return name in self._algorithms

    def __iter__(self):
        return iter(self._algorithms.values())

    def __len__(self) -> int:
        return len(self._algorithms)


REGISTRY = AlgorithmRegistry()


def register_algorithm(name: str, *, a_placement: str = NATURAL,
                       b_placement: str = NATURAL,
                       unskew_out: Optional[str] = None,
                       wire: Tuple[str, ...] = ("a", "b"),
                       wire_amortized: bool = False, style: str = "rdma",
                       duplex: int = 1, msgs_per_step: Optional[int] = None,
                       registry: AlgorithmRegistry = REGISTRY):
    """Decorator registering a shard_map body as a named algorithm."""
    def deco(body):
        registry.register(Algorithm(
            name=name, body=body, a_placement=a_placement,
            b_placement=b_placement, unskew_out=unskew_out, wire=wire,
            wire_amortized=wire_amortized, style=style, duplex=duplex,
            msgs_per_step=msgs_per_step))
        return body
    return deco


def algorithms() -> Tuple[str, ...]:
    """Names of all registered algorithms (registration order)."""
    return REGISTRY.names()


# ---------------------------------------------------------------------------
# Algorithm bodies (run inside shard_map on local tile views)
# ---------------------------------------------------------------------------
@register_algorithm("summa_bcast", style="bsp")
def _body_summa_bcast(a, b, geom: _Geom):
    """Bulk-synchronous SUMMA (paper SS2.2): a broadcast per inner step."""
    b = _densify_b(b, geom)
    my_row = lax.axis_index(geom.axr)
    my_col = lax.axis_index(geom.axc)

    def step(c, k):
        a_k = _tree_bcast(a, geom.axc, k, my_col)  # bcast A[:, k] along rows
        b_k = _tree_bcast(b, geom.axr, k, my_row)  # bcast B[k, :] along cols
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


@register_algorithm("summa_ag", style="bsp", wire_amortized=True)
def _body_summa_ag(a, b, geom: _Geom):
    """All-gather SUMMA: one big up-front collective, g x tile footprint."""
    b = _densify_b(b, geom)
    a_g = {k: lax.all_gather(v, geom.axc) for k, v in a.items()}
    b_g = {k: lax.all_gather(v, geom.axr) for k, v in b.items()}

    def step(c, k):
        a_k = {kk: v[k] for kk, v in a_g.items()}
        b_k = {kk: v[k] for kk, v in b_g.items()}
        return c + _local_mm(a_k, b_k, geom), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    c, _ = lax.scan(step, c0, jnp.arange(geom.g))
    return c


@register_algorithm("ring_c", a_placement=SKEW_ROWS, b_placement=SKEW_COLS)
def _body_ring_c(a, b, geom: _Geom):
    """Paper Alg 2 (stationary-C): skewed placement + neighbour ppermute."""
    b = _densify_b(b, geom)

    def step(carry, _):
        a_t, b_t, c = carry
        # "async_get_tile" for step k+1, issued before the local matmul so
        # the collective-permute DMA overlaps MXU work (paper SS3.3 prefetch).
        a_n = _tree_ppermute(a_t, geom.axc, geom.g)
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)
        c = c + _local_mm(a_t, b_t, geom)
        return (a_n, b_n, c), None

    c0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)
    (_, _, c), _ = lax.scan(step, (a, b, c0), None, length=geom.g)
    return c


@register_algorithm("ring_a", b_placement=STATIONARY_A, unskew_out="rows",
                    wire=("b", "c"))
def _body_ring_a(a, b, geom: _Geom):
    """Paper Alg 1 (stationary-A): B rides the ring, partial C rides back."""
    b = _densify_b(b, geom)
    acc0 = _pvary(jnp.zeros((geom.tm, geom.tn), dtype=geom.out_dtype), geom)

    def step(carry, _):
        b_t, acc = carry
        b_n = _tree_ppermute(b_t, geom.axr, geom.g)   # prefetch next B tile
        acc = acc + _local_mm(a, b_t, geom)
        # route the partial C tile one hop toward its owner (the TPU
        # replacement for the paper's remote accumulation queue push)
        acc = lax.ppermute(acc, geom.axc,
                           [((d + 1) % geom.g, d) for d in range(geom.g)])
        return (b_n, acc), None

    (_, acc), _ = lax.scan(step, (b, acc0), None, length=geom.g)
    return acc


@register_algorithm("ring_c_bidir", a_placement=SKEW_ROWS,
                    b_placement=SKEW_COLS, wire=("a", "a", "b"), duplex=2,
                    msgs_per_step=4)   # a_fwd, a_bwd, b_left, b_right
def _body_ring_c_bidir(a, b, geom: _Geom):
    """Bidirectional stationary-C ring: C split into column half-panels.

    The left half-panel's operands (the full A tile + the left half of the
    dense B tile) ride the +1 ring computing ``k = i+j+t``; the right
    half-panel's ride the -1 ring computing ``k = i+j-t``.  Both start from
    the same skewed placement as ``ring_c``, so no new placement state is
    materialized.  The two streams use opposite directions of the
    full-duplex torus links concurrently, halving B's serialized wire time
    at the cost of shipping A both ways — a genuinely different
    comm/compute trade for ``algorithm="auto"`` (wins for sparse-A x wide-B
    SpMM, loses when A's tile bytes dominate).
    """
    b = _densify_b(b, geom)
    half = geom.tn // 2
    b_fwd = {"dense": b["dense"][:, :half]}
    b_bwd = {"dense": b["dense"][:, half:]}

    def step(carry, _):
        a_f, a_b, b_f, b_b, c_l, c_r = carry
        # prefetch both directions before the local matmuls (paper SS3.3)
        a_fn = _tree_ppermute(a_f, geom.axc, geom.g, +1)
        a_bn = _tree_ppermute(a_b, geom.axc, geom.g, -1)
        b_fn = _tree_ppermute(b_f, geom.axr, geom.g, +1)
        b_bn = _tree_ppermute(b_b, geom.axr, geom.g, -1)
        c_l = c_l + _local_mm(a_f, b_f, geom)
        c_r = c_r + _local_mm(a_b, b_b, geom)
        return (a_fn, a_bn, b_fn, b_bn, c_l, c_r), None

    c_l0 = _pvary(jnp.zeros((geom.tm, half), dtype=geom.out_dtype), geom)
    c_r0 = _pvary(jnp.zeros((geom.tm, geom.tn - half), dtype=geom.out_dtype),
                  geom)
    (_, _, _, _, c_l, c_r), _ = lax.scan(
        step, (a, a, b_fwd, b_bwd, c_l0, c_r0), None, length=geom.g)
    return jnp.concatenate([c_l, c_r], axis=1)


# ---------------------------------------------------------------------------
# Distributed-matrix handles
# ---------------------------------------------------------------------------
def _place_bsr(t: TiledBSR, placement: str) -> TiledBSR:
    if placement == NATURAL:
        return t
    if placement in (SKEW_ROWS, SKEW_COLS):
        return skew_bsr(t, placement[len("skew_"):])
    if placement == STATIONARY_A:
        g = t.grid_shape[0]
        i = np.arange(g)[:, None]
        j = np.arange(g)[None, :]
        si, sj = j + 0 * i, (i + j) % g   # position (i,j) <- tile (j,(i+j)%g)
        take = lambda arr: arr[si, sj]
        return TiledBSR(
            blocks=take(t.blocks), rows=take(t.rows), cols=take(t.cols),
            counts=take(t.counts), shape=t.shape, block_size=t.block_size,
            grid_shape=t.grid_shape, capacity=t.capacity,
            logical_shape=t.logical_shape, row_block_perm=t.row_block_perm)
    raise ValueError(f"unknown placement {placement!r}; one of {PLACEMENTS}")


def _place_dense(x: jnp.ndarray, g: int, placement: str) -> jnp.ndarray:
    if placement == NATURAL:
        return x
    if placement == SKEW_ROWS:
        return skew_dense(x, g, "rows")
    if placement == SKEW_COLS:
        return skew_dense(x, g, "cols")
    if placement == STATIONARY_A:
        return place_b_for_stationary_a(x, g)
    raise ValueError(f"unknown placement {placement!r}; one of {PLACEMENTS}")


class DistMatrix:
    """A matrix distributed over a square ``g x g`` process grid.

    Subclasses cache placement transforms: ``placed(p)`` materializes the
    operand tree for placement ``p`` at most once per handle, the way the
    paper's DMatrix resolves its pointer directory once at construction.
    """

    kind = "abstract"

    @property
    def g(self) -> int:
        raise NotImplementedError

    @property
    def shape(self) -> Tuple[int, int]:      # padded global shape
        raise NotImplementedError

    @property
    def logical_shape(self) -> Tuple[int, int]:
        raise NotImplementedError

    @property
    def tile_shape(self) -> Tuple[int, int]:
        s = self.shape
        return s[0] // self.g, s[1] // self.g

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def abstract_key(self) -> tuple:
        """Hashable abstract signature (shapes/dtypes, no data) for caching."""
        raise NotImplementedError

    def placements(self) -> Tuple[str, ...]:
        """Placement states materialized so far (diagnostics/tests)."""
        return tuple(self._placed)


class DistBSR(DistMatrix):
    """Handle for a block-sparse distributed matrix (wraps TiledBSR)."""

    kind = "bsr"

    def __init__(self, tiled: TiledBSR):
        if tiled.grid_shape[0] != tiled.grid_shape[1]:
            raise ValueError("square process grid required, got "
                             f"{tiled.grid_shape}")
        self.tiled = tiled
        self._placed: Dict[str, Dict[str, jnp.ndarray]] = {}

    @classmethod
    def from_tiled(cls, tiled: TiledBSR, *, balance: str = "none",
                   capacity="keep") -> "DistBSR":
        """Wrap a TiledBSR; ``balance="rows"`` re-tiles with row balancing.

        Re-balancing an already-tiled matrix goes through a dense round
        trip (tiling is host-side construction, not a hot path); a tiled
        matrix that already carries a ``row_block_perm`` is kept as-is.

        ``capacity`` controls the rebuilt uniform capacity: ``"keep"``
        (default) preserves the handle's existing value — a caller who
        pinned it to unify abstract shapes across matrices (plan-cache
        sharing) must not get a silently re-derived one — while ``None``
        re-derives the minimal capacity, realizing the balancing shrink
        (balancing never *increases* the needed capacity: the balancer
        falls back to the identity layout when it would).  An int pins a
        new value.  A non-``"keep"`` capacity on a call that does not
        re-tile raises (it cannot be honored, and ignoring it would desync
        abstract keys).
        """
        if balance not in ("none", "rows"):
            raise ValueError(
                f"unknown balance {balance!r}; one of ('none', 'rows')")
        rebuilds = balance == "rows" and tiled.row_block_perm is None
        if capacity != "keep" and not rebuilds:
            raise ValueError(
                "capacity can only be changed when from_tiled re-tiles "
                "(balance='rows' on an unbalanced value); otherwise rebuild "
                "with TiledBSR.from_dense(capacity=...)")
        if rebuilds:
            m, n = tiled.logical_shape or tiled.shape
            dense = np.asarray(tiled.to_dense())[:m, :n]
            cap = tiled.capacity if capacity == "keep" else capacity
            tiled = TiledBSR.from_dense(
                dense, ProcessGrid(*tiled.grid_shape), tiled.block_size,
                capacity=cap, dtype=tiled.dtype, balance="rows")
        return cls(tiled)

    @classmethod
    def from_dense(cls, dense, *, g: int, block_size: int,
                   capacity: Optional[int] = None, dtype=None,
                   balance: str = "none") -> "DistBSR":
        return cls(TiledBSR.from_dense(dense, ProcessGrid(g, g), block_size,
                                       capacity=capacity, dtype=dtype,
                                       balance=balance))

    @property
    def g(self) -> int:
        return self.tiled.grid_shape[0]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.tiled.shape

    @property
    def logical_shape(self) -> Tuple[int, int]:
        return self.tiled.logical_shape or self.tiled.shape

    @property
    def dtype(self):
        return self.tiled.dtype

    @property
    def block_size(self) -> int:
        return self.tiled.block_size

    @property
    def capacity(self) -> int:
        return self.tiled.capacity

    @property
    def counts(self):
        return self.tiled.counts

    @property
    def row_block_perm(self) -> Optional[Tuple[int, ...]]:
        """Row-block balance permutation (None unless ``balance="rows"``)."""
        return self.tiled.row_block_perm

    def inv_row_perm(self) -> Optional[jnp.ndarray]:
        """Device array of the inverse balance permutation, cached on the
        handle so repeated plan calls don't recompute/re-upload it."""
        if self.tiled.row_block_perm is None:
            return None
        inv = getattr(self, "_inv_row_perm", None)
        if inv is None:
            inv = jnp.asarray(
                _schedule.invert_perm(self.tiled.row_block_perm))
            self._inv_row_perm = inv
        return inv

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        tree = self._placed.get(placement)
        if tree is None:
            t = _place_bsr(self.tiled, placement)
            tree = {"blocks": t.blocks, "rows": t.rows, "cols": t.cols}
            self._placed[placement] = tree
        return tree

    def abstract_key(self) -> tuple:
        t = self.tiled
        return ("bsr", t.shape, t.grid_shape, t.block_size, t.capacity,
                jnp.dtype(t.dtype).name)


class DistDense(DistMatrix):
    """Handle for a dense distributed matrix (grid-padded global array)."""

    kind = "dense"

    def __init__(self, data, g: int,
                 logical_shape: Optional[Tuple[int, int]] = None):
        data = jnp.asarray(data)
        if data.ndim != 2:
            raise ValueError(f"expected a 2-D array, got shape {data.shape}")
        if data.shape[0] % g or data.shape[1] % g:
            raise ValueError(
                f"padded shape {data.shape} not divisible by grid size {g}; "
                "use DistDense.from_global to pad")
        self.data = data
        self._g = g
        self._logical = tuple(logical_shape or data.shape)
        self._placed: Dict[str, Dict[str, jnp.ndarray]] = {}

    @classmethod
    def from_global(cls, x, g: int, *, rows_pad: Optional[int] = None,
                    cols_pad: Optional[int] = None) -> "DistDense":
        """Wrap a global array, zero-padding each dim to a multiple of g."""
        x = jnp.asarray(x)
        m, n = x.shape
        rp = pad_to_multiple(m, g) if rows_pad is None else rows_pad
        cp = pad_to_multiple(n, g) if cols_pad is None else cols_pad
        if rp < m or cp < n or rp % g or cp % g:
            raise ValueError(f"bad padded shape ({rp}, {cp}) for array "
                             f"{x.shape} on a {g}x{g} grid")
        if (rp, cp) != (m, n):
            x = jnp.zeros((rp, cp), x.dtype).at[:m, :n].set(x)
        return cls(x, g, logical_shape=(m, n))

    @classmethod
    def for_rhs(cls, x, a: DistMatrix, *, allow_pad: bool = False
                ) -> "DistDense":
        """Wrap the right operand of ``a @ x``, matching a's padded K dim.

        The inner dimension must equal a's logical or padded column count;
        anything smaller is only zero-padded with an explicit
        ``allow_pad=True`` (silent padding hides shape bugs).
        """
        x = jnp.asarray(x)
        k = x.shape[0]
        k_pad, k_log = a.shape[1], a.logical_shape[1]
        if k > k_pad:
            raise ValueError(
                f"inner dimensions disagree: right operand has {k} rows, "
                f"left operand has only {k_pad} (padded) columns")
        if k not in (k_pad, k_log) and not allow_pad:
            raise ValueError(
                f"inner dimension mismatch: right operand has {k} rows but "
                f"the left operand has {k_log} logical / {k_pad} padded "
                "columns; pass allow_pad=True to zero-pad explicitly")
        return cls.from_global(x, a.g, rows_pad=k_pad)

    @property
    def g(self) -> int:
        return self._g

    @property
    def shape(self) -> Tuple[int, int]:
        return self.data.shape

    @property
    def logical_shape(self) -> Tuple[int, int]:
        return self._logical

    @property
    def dtype(self):
        return self.data.dtype

    def placed(self, placement: str) -> Dict[str, jnp.ndarray]:
        tree = self._placed.get(placement)
        if tree is None:
            tree = {"dense": _place_dense(self.data, self._g, placement)}
            self._placed[placement] = tree
        return tree

    def abstract_key(self) -> tuple:
        return ("dense", self.data.shape, self._g,
                jnp.dtype(self.data.dtype).name)


# ---------------------------------------------------------------------------
# Mesh preparation / validation
# ---------------------------------------------------------------------------
def validate_mesh(mesh, g: int, axis_row: str, axis_col: str) -> None:
    """Fail fast (and clearly) on a mesh that can't carry the g x g grid."""
    names = tuple(mesh.axis_names)
    if axis_row not in names or axis_col not in names:
        raise ValueError(
            f"mesh axes {names} do not include the required axes "
            f"({axis_row!r}, {axis_col!r}); build one with "
            f"make_grid_mesh({g}, {axis_row!r}, {axis_col!r})")
    if len(names) != 2:
        raise ValueError(
            f"expected a 2-axis ({axis_row!r}, {axis_col!r}) mesh, got axes "
            f"{names}")
    shape = dict(mesh.shape)
    got = (shape[axis_row], shape[axis_col])
    if got != (g, g):
        raise ValueError(
            f"mesh shape {axis_row}={got[0]}, {axis_col}={got[1]} does not "
            f"match the {g}x{g} process grid of the operands")


def _prep_mesh(mesh, g: int, axis_row: str, axis_col: str):
    if mesh is None:
        return make_grid_mesh(g, axis_row, axis_col)
    validate_mesh(mesh, g, axis_row, axis_col)
    return mesh


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------
_TRACE_HOOKS: List[Callable] = []


def add_trace_hook(hook: Callable) -> Callable:
    """Register ``hook(plan)`` to fire once per executable (re)trace."""
    _TRACE_HOOKS.append(hook)
    return hook


def remove_trace_hook(hook: Callable) -> None:
    _TRACE_HOOKS.remove(hook)


def _tree_keys(abstract_key: tuple) -> Tuple[str, ...]:
    return ("blocks", "rows", "cols") if abstract_key[0] == "bsr" \
        else ("dense",)


def _specs_for_keys(keys: Tuple[str, ...], axr: str, axc: str) -> Dict:
    out = {}
    for k in keys:
        if k == "dense":
            out[k] = P(axr, axc)
        elif k == "blocks":
            out[k] = P(axr, axc, None, None, None)
        else:  # rows / cols
            out[k] = P(axr, axc, None)
    return out


def _local_view(tree: Dict) -> Dict:
    """Strip the leading (1, 1) grid dims of TiledBSR leaves inside shard_map."""
    return {k: (v if k == "dense" else v[0, 0]) for k, v in tree.items()}


def _key_dtype(abstract_key: tuple):
    return abstract_key[5] if abstract_key[0] == "bsr" else abstract_key[3]


def _cost_model(alg: Algorithm, geom: _Geom, a_key: tuple,
                b_key: tuple) -> Dict[str, float]:
    """Per-step wire volume / executed flops of one plan execution.

    Reflects what the bodies actually move and execute: the A tile rides in
    its stored *pre-augmented* BSR form (``capacity + tile block-rows``
    block products per step, padding included — the quantity the static
    scheduler balances); the B tile rides *densified* regardless of kind
    (``_densify_b`` hoists the scatter out of the scanned step); ``wire``
    may name a tile twice (bidirectional schedules) and ``duplex`` credits
    full-duplex links in :func:`_predicted_time`, not here.
    """
    g = geom.g
    if a_key[0] == "bsr":
        bs, cap = a_key[3], a_key[4]
        store = cap + geom.a_nbr            # pre-augmented stored slots
        a_bytes = store * bs * bs * np.dtype(_key_dtype(a_key)).itemsize \
            + store * 2 * 4                 # + rows/cols int32
        flops_step = 2 * store * bs * bs * geom.tn
    else:
        tk = a_key[1][1] // g
        a_bytes = geom.tm * tk * np.dtype(_key_dtype(a_key)).itemsize
        flops_step = 2 * geom.tm * tk * geom.tn
    tk_b = b_key[1][0] // g
    b_bytes = tk_b * geom.tn * np.dtype(_key_dtype(b_key)).itemsize
    c_bytes = geom.tm * geom.tn * np.dtype(geom.out_dtype).itemsize
    tiles = {"a": a_bytes, "b": b_bytes, "c": c_bytes}
    step_bytes = sum(tiles[t] for t in alg.wire)
    if alg.wire_amortized:
        step_bytes = step_bytes * (g - 1) / g
    total_flops = float(flops_step * g)
    total_bytes = float(step_bytes * g)
    return {
        "steps": float(g),
        "flops_per_step": float(flops_step),
        "net_bytes_per_step": float(step_bytes),
        "total_flops": total_flops,
        "total_net_bytes": total_bytes,
        "ai_net": total_flops / total_bytes if total_bytes else float("inf"),
        "ai_local": total_flops / (g * (a_bytes + b_bytes) + c_bytes),
    }


def _predicted_time(cm: Dict[str, float], alg: Algorithm,
                    machine: "_roofline.Machine") -> float:
    """Alpha-beta-gamma seconds for one execution — the auto-select score.

    Compute time is capped by the local roofline; wire time is serialized
    bytes over the per-chip link share (credited for ``duplex``) plus a
    per-message alpha term (``machine.hop_latency``).  Bulk-synchronous
    schedules pay compute + comm (a barrier per stage forbids overlap);
    the RDMA-style rings prefetch, so they pay max(compute, comm) — the
    paper's SS3.3 overlap claim, encoded as a scheduling preference.
    """
    t_comp = cm["total_flops"] / _roofline.local_peak(cm["ai_local"], machine)
    n_msgs = alg.msgs_per_step if alg.msgs_per_step is not None \
        else len(alg.wire)
    msgs = n_msgs * (1.0 if alg.wire_amortized else cm["steps"])
    t_comm = cm["total_net_bytes"] / (machine.net_bw * alg.duplex) \
        + msgs * machine.hop_latency
    if alg.style == "bsp":
        return t_comp + t_comm
    return max(t_comp, t_comm)


class MatmulPlan:
    """A reusable distributed multiply: placement + one compiled executable.

    Create via :func:`plan_matmul`; execute with ``plan(a, b)``.  The
    executable is ``jax.jit(shard_map(body))`` built once at plan time, so
    repeated calls with the same abstract operand shapes re-use the compiled
    program (``plan.traces`` counts actual traces).
    """

    def __init__(self, algorithm: Algorithm, geom: _Geom, mesh,
                 a_key: tuple, b_key: tuple, allow_pad: bool = False,
                 requested: Optional[str] = None,
                 auto_scores: Optional[Dict[str, float]] = None):
        self.algorithm = algorithm
        self.geom = geom
        self.mesh = mesh
        self._a_key = a_key
        self._b_key = b_key
        self._allow_pad = allow_pad
        # Introspection: what the request that FIRST BUILT this plan asked
        # for ("auto" or a name) and, if auto ever selected this plan, the
        # candidate scores from that selection.  Cached plans are shared
        # across requests, so these describe the plan's provenance, not
        # necessarily the current call (auto re-scores on every call; see
        # plan_matmul).
        self.requested = requested or algorithm.name
        self.auto_scores = auto_scores
        self.traces = 0
        body = algorithm.body

        def fn(a, b):
            self.traces += 1          # runs at trace time only
            for hook in list(_TRACE_HOOKS):
                hook(self)
            return body(_local_view(a), _local_view(b), geom)

        self._exec = jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(_specs_for_keys(_tree_keys(a_key), geom.axr, geom.axc),
                      _specs_for_keys(_tree_keys(b_key), geom.axr, geom.axc)),
            out_specs=P(geom.axr, geom.axc),
            # pallas_call's out_shape carries no vma annotation; the engine's
            # collectives are explicit, so skip the varying-axes checker.
            check_vma=False))

    @property
    def kind(self) -> str:
        """"spmm" | "spgemm" | "dense" — what this plan dispatches to."""
        a_sparse = self._a_key[0] == "bsr"
        b_sparse = self._b_key[0] == "bsr"
        if a_sparse:
            return "spgemm" if b_sparse else "spmm"
        return "dense"

    def __call__(self, a, b) -> jnp.ndarray:
        a_h, b_h = _coerce_pair(a, b, g=self.geom.g,
                                allow_pad=self._allow_pad)
        if (a_h.abstract_key(), b_h.abstract_key()) != (self._a_key,
                                                        self._b_key):
            raise ValueError(
                "operands do not match this plan's abstract shapes "
                f"(plan: {self._a_key} @ {self._b_key}, got "
                f"{a_h.abstract_key()} @ {b_h.abstract_key()}); build a new "
                "plan with plan_matmul")
        c = self._exec(a_h.placed(self.algorithm.a_placement),
                       b_h.placed(self.algorithm.b_placement))
        return self._epilogue(c, a_h, b_h)

    def _epilogue(self, c: jnp.ndarray, a_h: DistMatrix,
                  b_h: DistMatrix) -> jnp.ndarray:
        """Shared output fix-up: unskew, un-balance, crop padding.

        One copy for all operand kinds — the sparse and dense paths get
        identical ``logical_shape`` cropping semantics.  A balanced left
        operand permuted its global row blocks before tiling; C inherits
        that permutation, so it is inverted here (after the tile-grid
        unskew, before the crop) to keep balanced and unbalanced plans
        bit-compatible.
        """
        if self.algorithm.unskew_out == "rows":
            c = unskew_c_rows(c, self.geom.g)
        elif self.algorithm.unskew_out is not None:
            raise ValueError(
                f"unknown unskew_out {self.algorithm.unskew_out!r}")
        perm = getattr(a_h, "row_block_perm", None)
        if perm:
            bs = a_h.block_size
            inv = a_h.inv_row_perm()   # cached on the handle
            c = c.reshape(len(perm), bs, -1)[inv].reshape(c.shape)
        return c[:a_h.logical_shape[0], :b_h.logical_shape[1]]

    # ------------------------------------------------------------- analysis
    def cost_model(self, a: Optional[DistBSR] = None) -> Dict[str, float]:
        """Per-step volume / flops of one plan execution (per device).

        Flop counts are the *executed* (padding and coverage included) MXU
        work, the quantity the static scheduler balances.  Pass the sparse
        left-hand handle to also get the paper's Fig-1 per-stage vs
        end-to-end imbalance from its tile counts (feeds
        ``core/schedule.py``).
        """
        out = _cost_model(self.algorithm, self.geom, self._a_key,
                          self._b_key)
        if isinstance(a, DistBSR):
            per_stage, end_to_end = _schedule.stage_imbalance(
                np.asarray(a.counts, dtype=np.float64))
            out["per_stage_imbalance"] = per_stage
            out["end_to_end_imbalance"] = end_to_end
        return out

    def predicted_cost(self, machine: Optional["_roofline.Machine"] = None
                       ) -> float:
        """Predicted seconds per execution (the ``algorithm="auto"`` score)."""
        machine = machine or _roofline.TPU_V5E
        return _predicted_time(self.cost_model(), self.algorithm, machine)

    def predicted_perf(self, machine: "_roofline.Machine") -> Dict[str, float]:
        """Paper SS4 inter-node roofline prediction for this plan."""
        cm = self.cost_model()
        peak = _roofline.local_peak(cm["ai_local"], machine)
        return {
            "perf": _roofline.internode_roofline(cm["ai_net"],
                                                 cm["ai_local"], machine),
            "local_peak": peak,
            "net_bound": cm["ai_net"] * machine.net_bw < peak,
            **cm,
        }


# ---------------------------------------------------------------------------
# Operand coercion + plan cache + public entry points
# ---------------------------------------------------------------------------
def _coerce_pair(a, b, *, g: Optional[int] = None, allow_pad: bool = False
                 ) -> Tuple[DistMatrix, DistMatrix]:
    if isinstance(a, DistMatrix):
        a_h = a
    elif isinstance(a, TiledBSR):
        a_h = DistBSR.from_tiled(a)
    else:
        arr = jnp.asarray(a)
        if g is None:
            raise ValueError(
                "a dense left operand needs g=<grid size> or a DistDense "
                "handle (DistDense.from_global)")
        a_h = DistDense.from_global(arr, g)
    if g is not None and a_h.g != g:
        raise ValueError(f"left operand lives on a {a_h.g}x{a_h.g} grid, "
                         f"but g={g} was requested")

    if isinstance(b, DistMatrix):
        b_h = b
    elif isinstance(b, TiledBSR):
        b_h = DistBSR.from_tiled(b)
    else:
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h, allow_pad=allow_pad)

    if getattr(b_h, "row_block_perm", None):
        raise ValueError(
            "the right operand carries a balance='rows' row-block "
            "permutation, which would permute the contraction dimension; "
            "balanced matrices may only be the left operand (the epilogue "
            "inverts the permutation on output rows)")
    if isinstance(a_h, DistDense) and isinstance(b_h, DistBSR):
        raise NotImplementedError(
            "dense x sparse is not supported; compute the transposed "
            "product sparse x dense instead (B^T A^T = (AB)^T)")
    if a_h.g != b_h.g:
        raise ValueError(f"operands on different process grids: "
                         f"{a_h.g}x{a_h.g} vs {b_h.g}x{b_h.g}")
    if a_h.shape[1] != b_h.shape[0]:
        raise ValueError(
            f"inner (padded) dimensions disagree: A is {a_h.shape}, B is "
            f"{b_h.shape}; build the right operand with "
            "DistDense.for_rhs(b, a) to match A's padding")
    return a_h, b_h


def _geometry(a_h: DistMatrix, b_h: DistMatrix, *, impl: Optional[str],
              axis_row: str, axis_col: str) -> _Geom:
    a_bsr = isinstance(a_h, DistBSR)
    b_bsr = isinstance(b_h, DistBSR)
    return _Geom(
        g=a_h.g, tm=a_h.tile_shape[0], tn=b_h.tile_shape[1],
        a_nbr=(a_h.tile_shape[0] // a_h.block_size) if a_bsr else 0,
        b_nbr=(b_h.tile_shape[0] // b_h.block_size) if b_bsr else 0,
        b_nbc=(b_h.tile_shape[1] // b_h.block_size) if b_bsr else 0,
        impl=impl, axr=axis_row, axc=axis_col,
        out_dtype=jnp.promote_types(a_h.dtype, b_h.dtype))


def _mesh_key(mesh):
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return id(mesh)


def auto_select(a, b, *, machine: Optional["_roofline.Machine"] = None,
                g: Optional[int] = None, allow_pad: bool = False,
                axis_row: str = "row", axis_col: str = "col",
                registry: Optional[AlgorithmRegistry] = None
                ) -> Tuple[str, Dict[str, float]]:
    """Score every registered schedule for ``a @ b``; pick the cheapest.

    Returns ``(name, scores)`` where ``scores`` maps every algorithm to its
    predicted seconds (:func:`_predicted_time` on its cost model).  Pure
    planning — no mesh or devices needed, so large grids can be scored on
    a single host.  Ties resolve to registration order.
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    machine = machine or _roofline.TPU_V5E
    registry = registry or REGISTRY
    geom = _geometry(a_h, b_h, impl=None, axis_row=axis_row,
                     axis_col=axis_col)
    a_key, b_key = a_h.abstract_key(), b_h.abstract_key()
    scores = {alg.name: _predicted_time(_cost_model(alg, geom, a_key, b_key),
                                        alg, machine)
              for alg in registry}
    if not scores:
        raise ValueError("no algorithms registered")
    return min(scores, key=scores.get), scores


def plan_matmul(a, b, *, algorithm: str = "ring_c", mesh=None,
                impl: Optional[str] = None, g: Optional[int] = None,
                axis_row: str = "row", axis_col: str = "col",
                allow_pad: bool = False, cache: bool = True,
                machine: Optional["_roofline.Machine"] = None) -> MatmulPlan:
    """Build (or fetch from the shared cache) a plan for ``a @ b``.

    ``a`` / ``b`` may be :class:`DistMatrix` handles (preferred — placement
    caches live on the handle), raw :class:`TiledBSR` values, or plain dense
    arrays (``g`` required when both are dense).  ``cache=False`` forces a
    fresh plan — i.e. the legacy per-call behaviour, retracing every time.

    ``algorithm="auto"`` scores every registered schedule with
    :func:`auto_select` (against ``machine``, default TPU v5e) and builds
    the min-predicted-cost one; the choice and all candidate scores are
    recorded on the plan (``plan.requested``, ``plan.auto_scores``).
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    requested = algorithm
    auto_scores = None
    if algorithm == "auto":
        algorithm, auto_scores = auto_select(
            a_h, b_h, machine=machine, axis_row=axis_row, axis_col=axis_col,
            allow_pad=allow_pad)
    alg = REGISTRY.get(algorithm)
    mesh = _prep_mesh(mesh, a_h.g, axis_row, axis_col)
    key = (alg.name, impl, axis_row, axis_col, allow_pad, _mesh_key(mesh),
           a_h.abstract_key(), b_h.abstract_key())
    if cache:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            if auto_scores is not None and plan.auto_scores is None:
                plan.auto_scores = auto_scores   # record for introspection
            return plan
    plan = MatmulPlan(alg, _geometry(a_h, b_h, impl=impl, axis_row=axis_row,
                                     axis_col=axis_col),
                      mesh, a_h.abstract_key(), b_h.abstract_key(),
                      allow_pad=allow_pad, requested=requested,
                      auto_scores=auto_scores)
    if cache:
        _PLAN_CACHE[key] = plan
    return plan


def matmul(a, b, *, algorithm: str = "ring_c", mesh=None,
           impl: Optional[str] = None, g: Optional[int] = None,
           axis_row: str = "row", axis_col: str = "col",
           allow_pad: bool = False,
           machine: Optional["_roofline.Machine"] = None) -> jnp.ndarray:
    """Polymorphic distributed ``a @ b``.

    Dispatches sparse x dense -> SpMM, sparse x sparse -> SpGEMM, and
    dense x dense -> the dense engine, all through the shared plan cache:
    repeated calls with the same abstract shapes never re-trace.
    ``algorithm="auto"`` cost-model-selects the schedule (see
    :func:`plan_matmul`).
    """
    a_h, b_h = _coerce_pair(a, b, g=g, allow_pad=allow_pad)
    plan = plan_matmul(a_h, b_h, algorithm=algorithm, mesh=mesh, impl=impl,
                       axis_row=axis_row, axis_col=axis_col,
                       allow_pad=allow_pad, machine=machine)
    return plan(a_h, b_h)
