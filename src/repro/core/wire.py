"""Packed wire format: ship only real blocks on every communication path.

Every schedule in the engine moves sparse operand tiles at the uniform
padded ``store_capacity`` stride (capacity + coverage blocks), so the
bytes on the wire scale with the *bucketed capacity* — the hub tile's
load — even when most devices hold a fraction of that.  The paper's
one-sided model moves only the tiles a consumer actually needs, and the
sparsity-aware SpGEMM line of work (Hong et al., Bharadwaj et al.) shows
communication volume proportional to the *real* nonzero structure is the
dominant lever at scale.  This module is that lever for the plan-based
engine: a plan-time packed communication layout usable by every
registered algorithm.

The packed format, per sparse operand:

* **wire capacity** — ``bucket_capacity(max real blocks per tile + 1)``.
  SPMD shard_map bodies need one static shape per buffer, so the packed
  stride is the *max* real count over the tiles riding a path, rounded to
  the shared 1.25x bucket series (so near-identical structures keep
  producing identical executable shapes).  The ``+ 1`` guarantees every
  packed tile ends in at least one zero block: slot ``wc - 1`` is the
  universal inert target, replacing the padded layout's per-tile
  ``zero_slot``.
* **source-side pack** (:attr:`PackedOperand.pack_idx`) — static gather
  indices selecting each tile's real stored slots (in stored, i.e.
  row-sorted, order) into the packed prefix; trailing slots point at the
  tile's coverage zero block.  This is the trick ``core/steal3d.py``
  already used for moved tiles, promoted to a subsystem.
* **receiver-side consume maps** — because structure is static, the
  receiver never needs ``rows``/``cols`` on the wire.  Three plan-time
  maps reconstruct everything locally:

  - :attr:`PackedOperand.gidx`/``rows``/``cols`` — the coverage-augmented
    block list of each tile expressed as a *gather* into its packed
    blocks (zero entries point at the guaranteed-zero tail slot), so the
    ``bsr_spmm_raw(augment=False)`` contract (row-sorted, every block-row
    present) is met with no concat/sort inside the scanned step;
  - :attr:`PackedOperand.dmap` — densify-by-*gather*: packed slot (or the
    zero slot) per dense block position, so a sparse B tile rides the
    wire packed and materializes on the consumer via
    ``ops.densify_packed`` — a gather + transpose, no scatter in the
    scanned step;
  - :attr:`PackedOperand.slot_map` — stored slot -> packed slot, composed
    directly into the symbolic phase's pair lists
    (:func:`remap_pairs_packed`) so the packed SpGEMM kernels index
    packed buffers with no unpack copy.

Like ``core.symbolic`` and ``core.steal3d`` this module is internal to
``repro/core`` (direct imports elsewhere are banned by
``tools/check_api.py``); the public surface is
``plan_matmul(wire="packed")`` plus the re-exports in ``repro.core.api``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .grid import bucket_capacity
from .symbolic import GridStructure

__all__ = [
    "PackedOperand", "wire_capacity", "pack_operand",
    "placement_tiles", "tiles_ring_c", "tiles_ring_c_bwd", "tiles_ring_c_b",
    "tiles_ring_a_b", "tiles_summa_a", "tiles_summa_b", "schedule_consume",
    "schedule_dense_map", "remap_pairs_packed",
    "packed_block_bytes", "padded_tile_bytes",
]


@dataclasses.dataclass(frozen=True)
class PackedOperand:
    """Plan-time packed layout of one sparse operand (host numpy).

    All per-tile arrays are indexed by *natural* tile coordinates
    ``[ti, tj]``; the planner composes the placement / step schedule on
    top via :func:`schedule_consume` / :func:`schedule_dense_map`.
    """
    wire_capacity: int        # packed block slots on the wire (bucketed)
    aug_capacity: int         # coverage-augmented consume-list length
    pack_idx: np.ndarray      # i32[g, g, wc]: packed slot -> stored slot
    gidx: np.ndarray          # i32[g, g, aug_cap]: consume -> packed slot
    rows: np.ndarray          # i32[g, g, aug_cap] (sorted, all rows present)
    cols: np.ndarray          # i32[g, g, aug_cap]
    dmap: np.ndarray          # i32[g, g, nbr*nbc]: dense pos -> packed slot
    slot_map: np.ndarray      # i32[g, g, store]: stored -> packed (inert
                              #   slots -> wc - 1, the guaranteed zero)
    n_real: np.ndarray        # i64[g, g] real blocks per tile
    tile_nbr: int
    tile_nbc: int
    fingerprint: str          # the structure these maps encode

    @property
    def zero_slot(self) -> int:
        """The guaranteed-zero packed slot of every tile (``wc - 1``)."""
        return self.wire_capacity - 1


def wire_capacity(max_real: int, store_capacity: Optional[int] = None
                  ) -> int:
    """Packed wire stride for a path whose heaviest tile has ``max_real``
    real blocks: bucketed (plan-shape stability across near-identical
    structures) with one extra slot so every packed tile ends in a
    guaranteed zero block (the inert gather target).

    ``store_capacity`` (the operand's padded stride, itself
    capacity-bucketed and therefore equally cache-stable) clamps the
    result: a 1.25x bucket jump must never make the packed wire wider
    than the padded one it replaces.  The clamp keeps the zero-slot
    guarantee — a stored tile always holds at least one coverage zero,
    so ``max_real < store_capacity``.
    """
    wc = bucket_capacity(int(max_real) + 1)
    if store_capacity is not None:
        wc = min(wc, int(store_capacity))
    return wc


def pack_operand(struct: GridStructure) -> PackedOperand:
    """Build the packed wire layout for one operand's structure."""
    g = struct.grid_shape[0]
    nbr, nbc = struct.tile_nbr, struct.tile_nbc
    store = struct.rows.shape[2]
    n_real = struct.real.sum(axis=2).astype(np.int64)
    wc = wire_capacity(int(n_real.max()) if n_real.size else 0, store)
    # consume lists are local (never on the wire), but clamp them to the
    # padded stride too: the packed step must not execute more block
    # products than the padded one it replaces
    aug_cap = min(bucket_capacity(int(n_real.max()) + nbr
                                  if n_real.size else nbr), store)
    pack_idx = np.zeros((g, g, wc), dtype=np.int32)
    gidx = np.full((g, g, aug_cap), wc - 1, dtype=np.int32)
    rows = np.zeros((g, g, aug_cap), dtype=np.int32)
    cols = np.zeros((g, g, aug_cap), dtype=np.int32)
    dmap = np.full((g, g, nbr * nbc), wc - 1, dtype=np.int32)
    slot_map = np.full((g, g, store), wc - 1, dtype=np.int32)
    for i in range(g):
        for j in range(g):
            sl = np.nonzero(struct.real[i, j])[0]      # stored (row) order
            nr = len(sl)
            # source side: real slots first, zero slot padding after
            pack_idx[i, j, :nr] = sl
            pack_idx[i, j, nr:] = struct.zero_slot[i, j]
            slot_map[i, j, sl] = np.arange(nr)
            # consume side: merge the real blocks with one coverage zero
            # per block-row (the bsr_spmm_raw(augment=False) contract),
            # exactly like bsr._augment_tile but as packed-slot gathers
            r = struct.rows[i, j][sl].astype(np.int64)
            c = struct.cols[i, j][sl].astype(np.int64)
            cov = np.arange(nbr, dtype=np.int64)
            r_aug = np.concatenate([r, cov])
            order = np.argsort(r_aug, kind="stable")
            n_aug = nr + nbr
            gidx[i, j, :n_aug] = np.concatenate(
                [np.arange(nr), np.full(nbr, wc - 1)])[order]
            rows[i, j, :n_aug] = r_aug[order]
            cols[i, j, :n_aug] = np.concatenate(
                [c, np.zeros(nbr, np.int64)])[order]
            # padding keeps rows nondecreasing and gathers the zero slot
            rows[i, j, n_aug:] = nbr - 1
            # densify-by-gather map (positions with no real block keep the
            # zero slot); real positions are unique by the TiledBSR /
            # symbolic-layout construction
            dmap[i, j, r * nbc + c] = np.arange(nr)
    return PackedOperand(
        wire_capacity=wc, aug_capacity=aug_cap, pack_idx=pack_idx,
        gidx=gidx, rows=rows, cols=cols, dmap=dmap, slot_map=slot_map,
        n_real=n_real, tile_nbr=nbr, tile_nbc=nbc,
        fingerprint=struct.fingerprint)


# ---------------------------------------------------------------------------
# Placement / step-schedule composition
# ---------------------------------------------------------------------------
def placement_tiles(placement: str, g: int) -> np.ndarray:
    """Natural tile coordinates held at mesh position (i, j): i64[g, g, 2].

    Mirrors ``api._place_bsr`` / ``core.dist`` exactly (asserted by the
    packed-vs-padded allclose tests).
    """
    i = np.arange(g)[:, None]
    j = np.arange(g)[None, :]
    if placement == "natural":
        ti, tj = np.broadcast_to(i, (g, g)), np.broadcast_to(j, (g, g))
    elif placement == "skew_rows":
        ti, tj = np.broadcast_to(i, (g, g)), (i + j) % g
    elif placement == "skew_cols":
        ti, tj = (i + j) % g, np.broadcast_to(j, (g, g))
    elif placement == "stationary_a":
        ti, tj = np.broadcast_to(j, (g, g)), (i + j) % g
    else:
        raise ValueError(f"unknown placement {placement!r}")
    return np.stack([ti, tj], axis=-1).astype(np.int64)


def _steps(g: int):
    i = np.arange(g)[:, None, None]
    j = np.arange(g)[None, :, None]
    t = np.arange(g)[None, None, :]
    return i, j, t


def tiles_ring_c(g: int) -> np.ndarray:
    """Tile consumed at step t on device (i, j) in the stationary-C ring:
    A[i, (i + j + t) % g] (skewed placement + t forward rotations)."""
    i, j, t = _steps(g)
    return np.stack(np.broadcast_arrays(i, (i + j + t) % g), axis=-1)


def tiles_ring_c_bwd(g: int) -> np.ndarray:
    """Backward stream of ``ring_c_bidir``: A[i, (i + j - t) % g]."""
    i, j, t = _steps(g)
    return np.stack(np.broadcast_arrays(i, (i + j - t) % g), axis=-1)


def tiles_ring_c_b(g: int) -> np.ndarray:
    """B tile consumed at step t on device (i, j) in the stationary-C ring:
    B[(i + j + t) % g, j] (skew_cols placement + t rotations along rows)."""
    i, j, t = _steps(g)
    return np.stack(np.broadcast_arrays((i + j + t) % g, j + 0 * t), axis=-1)


def tiles_ring_a_b(g: int) -> np.ndarray:
    """B tile consumed in the stationary-A ring: B[j, (i + j + t) % g]
    (the ``stationary_a`` placement + t rotations along the row axis)."""
    i, j, t = _steps(g)
    return np.stack(np.broadcast_arrays(j + 0 * i, (i + j + t) % g), axis=-1)


def tiles_summa_a(g: int) -> np.ndarray:
    """A tile consumed at SUMMA inner step k on device (i, j): A[i, k]."""
    i, j, t = _steps(g)
    return np.stack(np.broadcast_arrays(i + 0 * j, t + 0 * j), axis=-1)


def tiles_summa_b(g: int) -> np.ndarray:
    """B tile consumed at SUMMA inner step k on device (i, j): B[k, j]."""
    i, j, t = _steps(g)
    return np.stack(np.broadcast_arrays(t + 0 * i, j + 0 * t), axis=-1)


def _gather_tiles(po: PackedOperand, arr: np.ndarray, tiles: np.ndarray
                  ) -> np.ndarray:
    """arr[g, g, L] per tile -> [g, g, T, L] per (device, step)."""
    return arr[tiles[..., 0], tiles[..., 1]]


def schedule_consume(po: PackedOperand, tiles: np.ndarray,
                     bases: Optional[np.ndarray] = None
                     ) -> Dict[str, np.ndarray]:
    """Per-(device, step) consume lists for a step schedule.

    ``tiles`` is ``[g, g, T, 2]`` (see the ``tiles_*`` helpers); ``bases``
    (``[g, g, T]``, default 0) offsets the gather indices into a pooled
    packed buffer — ``k * wire_capacity`` for an all-gathered panel, 0 for
    a carried ring buffer.  Because every packed tile's last slot is zero,
    ``base + wc - 1`` stays the inert target under any base.
    """
    gidx = _gather_tiles(po, po.gidx, tiles)
    if bases is not None:
        gidx = gidx + bases[..., None].astype(np.int32)
    return {
        "gidx": np.ascontiguousarray(gidx, dtype=np.int32),
        "rows": np.ascontiguousarray(_gather_tiles(po, po.rows, tiles)),
        "cols": np.ascontiguousarray(_gather_tiles(po, po.cols, tiles)),
    }


def schedule_dense_map(po: PackedOperand, tiles: np.ndarray,
                       bases: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-(device, step) densify-by-gather maps ``[g, g, T, nbr*nbc]``."""
    dmap = _gather_tiles(po, po.dmap, tiles)
    if bases is not None:
        dmap = dmap + bases[..., None].astype(np.int32)
    return np.ascontiguousarray(dmap, dtype=np.int32)


def remap_pairs_packed(pair_arr: np.ndarray, po: PackedOperand,
                       tiles_of_k: str) -> np.ndarray:
    """Compose the stored->packed slot map into symbolic pair lists.

    ``pair_arr`` is a symbolic-phase operand pair list ``[g, g, g, P]``
    indexed ``[i, j, k, p]`` whose values are *stored* slots of tile
    ``A[i, k]`` (``tiles_of_k="a"``) or ``B[k, j]`` (``"b"``); the result
    indexes the same blocks in their *packed* layout.  Inert pairs (the
    symbolic phase's per-tile ``zero_slot``) land on the packed zero tail,
    so the kernel contract (dummy pairs reference zero blocks) holds with
    no unpack copy.
    """
    g = po.slot_map.shape[0]
    i = np.arange(g)[:, None, None, None]
    j = np.arange(g)[None, :, None, None]
    k = np.arange(g)[None, None, :, None]
    if tiles_of_k == "a":
        ti, tj = i, k
    elif tiles_of_k == "b":
        ti, tj = k, j
    else:
        raise ValueError(f"tiles_of_k must be 'a' or 'b', got {tiles_of_k!r}")
    ti = np.broadcast_to(ti, pair_arr.shape)
    tj = np.broadcast_to(tj, pair_arr.shape)
    return np.ascontiguousarray(
        po.slot_map[ti, tj, pair_arr.astype(np.int64)], dtype=np.int32)


# ---------------------------------------------------------------------------
# Byte accounting (the cost-model / benchmark terms)
# ---------------------------------------------------------------------------
def packed_block_bytes(wc: int, block_size: int, itemsize: int) -> int:
    """Wire bytes of one packed tile shipment: blocks only — the consume
    maps stay home, so no rows/cols index traffic."""
    return wc * block_size * block_size * itemsize


def padded_tile_bytes(store_capacity: int, block_size: int,
                      itemsize: int) -> int:
    """Wire bytes of one padded tile shipment: coverage-augmented blocks
    plus the rows/cols int32 arrays that ride with them."""
    return store_capacity * (block_size * block_size * itemsize + 2 * 4)
