# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public surface: the plan-based distributed-matmul API (see DESIGN.md).
from .api import (REGISTRY, AlgorithmRegistry, DistBSR, DistDense,
                  DistMatrix, MatmulPlan, SymbolicProduct, algorithms,
                  clear_plan_cache, invalidate_plans, matmul, plan_matmul,
                  register_algorithm, reshard, sparse_algorithms,
                  symbolic_spgemm)

__all__ = [
    "REGISTRY", "AlgorithmRegistry", "DistBSR", "DistDense", "DistMatrix",
    "MatmulPlan", "SymbolicProduct", "algorithms", "clear_plan_cache",
    "invalidate_plans", "matmul", "plan_matmul", "register_algorithm",
    "reshard", "sparse_algorithms", "symbolic_spgemm",
]
