"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/shard_<host>.npz + manifest.json, committed by an
atomic rename of the staging directory (a crashed writer never corrupts the
latest checkpoint).  Saves run on a background thread (training continues on
the next step — async checkpointing).  Restore re-shards automatically: the
manifest stores the *global* array layout, so a job restarted on a different
mesh shape (elastic scaling) gets correctly re-sharded params via device_put
with the new sharding.

On a multi-host cluster each host writes its own shard file; in this
single-process container there is one shard holding full arrays — the
manifest format is host-count agnostic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state, extra: Optional[Dict] = None):
        """Snapshot to host memory now; write (possibly async) and commit."""
        host_tree = jax.tree.map(np.asarray, (params, opt_state))
        extra = dict(extra or {})
        if self._thread is not None:
            self._thread.join()          # one outstanding save at a time

        def write():
            stage = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(stage, ignore_errors=True)
            os.makedirs(stage, exist_ok=True)
            flat = _flatten(host_tree)
            np.savez(os.path.join(stage, "shard_0.npz"),
                     **{k: v for k, v in flat.items()})
            manifest = {
                "step": step,
                "time": time.time(),
                "keys": sorted(flat.keys()),
                "extra": extra,
                "treedef": str(jax.tree_util.tree_structure(host_tree)),
            }
            with open(os.path.join(stage, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(stage, final)      # atomic commit
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like: Tuple,
                shardings=None) -> Tuple[int, Tuple, Dict]:
        """Restore (params, opt_state) shaped/structured like ``like``.

        ``shardings``: matching pytree of NamedSharding for elastic
        re-sharding onto the *current* mesh (may differ from save-time mesh).
        Returns (step, tree, extra).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "shard_0.npz")) as z:
            flat = {k: z[k] for k in z.files}
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        flat_like = _flatten(like)
        if sorted(flat_like.keys()) != manifest["keys"]:
            missing = set(manifest["keys"]) ^ set(flat_like.keys())
            raise ValueError(f"checkpoint/model structure mismatch: {missing}")
        ordered = [flat[k] for k in flat_like.keys()]
        tree = jax.tree_util.tree_unflatten(treedef, ordered)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return step, tree, manifest.get("extra", {})
