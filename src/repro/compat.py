"""jax version-compatibility shims.

The codebase is written against the modern jax names (``jax.shard_map``,
``jax.sharding.AxisType``, ``lax.pvary``, ``jax.sharding.set_mesh``); older
runtimes (0.4.x, as shipped in the CPU test container) spell them
differently or lack them entirely.  Every call site goes through this module
so the rest of the code reads as if the modern API existed everywhere:

* :func:`make_mesh`   — ``jax.make_mesh`` with Auto axis types when the
  runtime knows about axis types, plain otherwise.
* :func:`shard_map`   — ``jax.shard_map`` (new) or
  ``jax.experimental.shard_map.shard_map`` (old); the new ``check_vma``
  flag maps onto the old ``check_rep``.
* :func:`pvary`       — identity on runtimes without the varying-axes
  checker (it only exists to annotate vma, never to move data).
* :func:`set_mesh`    — ``jax.sharding.set_mesh`` context where available;
  on old jax a ``Mesh`` is itself a context manager with the same effect.
"""
from __future__ import annotations

import jax
from jax import lax

__all__ = ["make_mesh", "shard_map", "pvary", "set_mesh",
           "get_abstract_mesh"]


def make_mesh(axis_shapes, axis_names):
    """A device mesh with Auto axis types (stable across jax 0.4/0.6+)."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def pvary(x, axis_names):
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axis_names)
    return x


def set_mesh(mesh):
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh  # old jax: Mesh.__enter__ sets the global mesh


def get_abstract_mesh():
    """The ambient mesh set by :func:`set_mesh` (None-ish when unset)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib
    env = getattr(mesh_lib.thread_resources, "env", None)
    return getattr(env, "physical_mesh", None)
