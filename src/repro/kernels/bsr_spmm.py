"""Pallas TPU kernels for local block-sparse matmul.

TPU adaptation of the paper's local cuSPARSE calls: sparsity is expressed at
MXU-block granularity (``bs x bs`` dense blocks, bs=128 in production), and
the CSR structure arrays become *scalar-prefetch* operands that steer the
BlockSpec index maps.  The grid walks the stored-block list with the reduction
innermost, so revisits of an output block are consecutive and accumulate in
VMEM (classic grouped-matmul pattern); double-buffering of the streamed A
blocks and B column panels is done by the Pallas pipeline automatically.

Three kernels:

* :func:`bsr_spmm_pallas`       — SpMM: BSR(A) @ dense(B).
* :func:`bsr_pair_matmul_pallas`— SpGEMM inner: pre-matched A/B block pairs
  accumulated into a dense C tile (host-known sparsity structure).
* :func:`bsr_pair_accumulate_pallas` — sparse-output SpGEMM inner: the same
  pre-matched pairs accumulated into *packed* output block slots (the
  symbolic phase's capacity-bounded layout), never materializing a dense C
  tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsr_spmm_pallas", "bsr_pair_matmul_pallas",
           "bsr_pair_accumulate_pallas"]


# ---------------------------------------------------------------------------
# SpMM: C[rows[s]] += A_blocks[s] @ B[cols[s], :]
# ---------------------------------------------------------------------------
def _spmm_kernel(rows_ref, cols_ref, a_ref, b_ref, c_ref):
    s = pl.program_id(1)  # stored-block step (innermost)
    prev = rows_ref[jnp.maximum(s - 1, 0)]
    is_first = jnp.logical_or(s == 0, rows_ref[s] != prev)

    @pl.when(is_first)
    def _zero():
        c_ref[...] = jnp.zeros_like(c_ref)

    a = a_ref[0]                      # [bs, bs]
    b = b_ref[...]                    # [bs, bn]
    c_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("n_block_rows", "block_n", "interpret"),
)
def bsr_spmm_pallas(blocks, rows, cols, dense, *, n_block_rows: int,
                    block_n: int = 256, interpret: bool = False):
    """C = BSR @ dense via pallas_call.

    blocks : f[cap, bs, bs] — zero-padded stored blocks, ``rows`` sorted
    rows, cols : i32[cap] — every output block-row must appear in ``rows``
                 (coverage contract: the kernel zeroes an output block on
                 first visit only; uncovered rows would return garbage).
                 ``ops.bsr_spmm_raw(augment=True)`` establishes this per
                 call; ``TiledBSR`` stores tiles pre-augmented.
    dense  : f[n_block_cols*bs, n] with n % block_n == 0
    """
    cap, bs, _ = blocks.shape
    n = dense.shape[1]
    if n % block_n:
        raise ValueError(f"n={n} not a multiple of block_n={block_n}")
    nj = n // block_n

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # rows, cols
        grid=(nj, cap),               # cap innermost => consecutive row visits
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda j, s, rows, cols: (s, 0, 0)),
            pl.BlockSpec((bs, block_n), lambda j, s, rows, cols: (cols[s], j)),
        ],
        out_specs=pl.BlockSpec(
            (bs, block_n), lambda j, s, rows, cols: (rows[s], j)),
    )
    out = pl.pallas_call(
        _spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows * bs, n), jnp.float32),
        interpret=interpret,
    )(rows, cols, blocks, dense)
    return out.astype(jnp.promote_types(blocks.dtype, dense.dtype))


# ---------------------------------------------------------------------------
# SpGEMM inner: C[pr[s], pc[s]] += A_blocks[pa[s]] @ B_blocks[pb[s]]
# ---------------------------------------------------------------------------
def _pair_kernel(pa_ref, pb_ref, pr_ref, pc_ref, a_ref, b_ref, c_ref):
    s = pl.program_id(0)
    prev_r = pr_ref[jnp.maximum(s - 1, 0)]
    prev_c = pc_ref[jnp.maximum(s - 1, 0)]
    is_first = jnp.logical_or(
        s == 0,
        jnp.logical_or(pr_ref[s] != prev_r, pc_ref[s] != prev_c))

    @pl.when(is_first)
    def _zero():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("n_block_rows", "n_block_cols", "interpret"),
)
def bsr_pair_matmul_pallas(a_blocks, b_blocks, pair_a, pair_b, pair_rows,
                           pair_cols, *, n_block_rows: int, n_block_cols: int,
                           interpret: bool = False):
    """Dense C tile from pre-matched sparse block pairs (sorted by (row,col)).

    Padding pairs must reference zero blocks and repeat the final (row, col).
    """
    npairs = pair_a.shape[0]
    bs = a_blocks.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,        # pair_a, pair_b, pair_rows, pair_cols
        grid=(npairs,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda s, pa, pb, pr, pc: (pa[s], 0, 0)),
            pl.BlockSpec((1, bs, bs), lambda s, pa, pb, pr, pc: (pb[s], 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bs, bs), lambda s, pa, pb, pr, pc: (pr[s], pc[s])),
    )
    out = pl.pallas_call(
        _pair_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_block_rows * bs, n_block_cols * bs), jnp.float32),
        interpret=interpret,
    )(pair_a, pair_b, pair_rows, pair_cols, a_blocks, b_blocks)
    return out.astype(jnp.promote_types(a_blocks.dtype, b_blocks.dtype))


# ---------------------------------------------------------------------------
# Sparse-output SpGEMM inner: C_blocks[ps[s]] += A_blocks[pa[s]] @ B_blocks[pb[s]]
# ---------------------------------------------------------------------------
def _pair_acc_kernel(pa_ref, pb_ref, ps_ref, a_ref, b_ref, c_ref):
    s = pl.program_id(0)
    prev = ps_ref[jnp.maximum(s - 1, 0)]
    is_first = jnp.logical_or(s == 0, ps_ref[s] != prev)

    @pl.when(is_first)
    def _zero():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("n_slots", "interpret"),
)
def bsr_pair_accumulate_pallas(a_blocks, b_blocks, pair_a, pair_b, pair_slot,
                               *, n_slots: int, interpret: bool = False):
    """Packed C blocks from pre-matched sparse block pairs.

    pair_slot : i32[P] — output slot per pair, NONDECREASING; every slot in
                ``[0, n_slots)`` must appear at least once (the symbolic
                phase emits one coverage pair per slot), because an output
                block is zeroed on its first visit only.
    Padding pairs must reference zero blocks and repeat the final slot.
    Returns f32[n_slots, bs, bs]; the caller casts to the output dtype.
    """
    npairs = pair_a.shape[0]
    bs = a_blocks.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,        # pair_a, pair_b, pair_slot
        grid=(npairs,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda s, pa, pb, ps: (pa[s], 0, 0)),
            pl.BlockSpec((1, bs, bs), lambda s, pa, pb, ps: (pb[s], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bs),
                               lambda s, pa, pb, ps: (ps[s], 0, 0)),
    )
    return pl.pallas_call(
        _pair_acc_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_slots, bs, bs), jnp.float32),
        interpret=interpret,
    )(pair_a, pair_b, pair_slot, a_blocks, b_blocks)
