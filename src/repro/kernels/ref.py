"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references (``assert_allclose`` targets) and also
the portable fallback used inside ``shard_map`` on CPU test meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "bsr_spmm_ref",
    "bsr_spmm_raw_ref",
    "bsr_pair_matmul_raw_ref",
    "bsr_pair_accumulate_raw_ref",
    "densify_raw",
]


def bsr_spmm_raw_ref(blocks, rows, cols, dense, n_block_rows: int,
                     out_dtype=None):
    """C = BSR(blocks, rows, cols) @ dense.

    blocks : f[cap, bs, bs]  (padding blocks are zero)
    rows   : i32[cap] block-row per stored block
    cols   : i32[cap] block-col per stored block
    dense  : f[n_block_cols*bs, n]
    returns f[n_block_rows*bs, n]
    """
    cap, bs, _ = blocks.shape
    n = dense.shape[1]
    out_dtype = out_dtype or jnp.promote_types(blocks.dtype, dense.dtype)
    b_blocks = dense.reshape(-1, bs, n)[cols]                      # [cap, bs, n]
    partial = jnp.einsum(
        "kab,kbn->kan", blocks, b_blocks,
        preferred_element_type=jnp.float32)                        # [cap, bs, n]
    out = jnp.zeros((n_block_rows, bs, n), dtype=jnp.float32)
    out = out.at[rows].add(partial)
    return out.reshape(n_block_rows * bs, n).astype(out_dtype)


def bsr_spmm_ref(a_bsr, dense):
    """Oracle via explicit densification: to_dense(A) @ B."""
    acc = jnp.dot(a_bsr.to_dense().astype(jnp.float32),
                  dense.astype(jnp.float32))
    return acc.astype(jnp.promote_types(a_bsr.dtype, dense.dtype))


def bsr_pair_matmul_raw_ref(a_blocks, b_blocks, pair_a, pair_b, pair_rows,
                            pair_cols, n_block_rows: int, n_block_cols: int,
                            out_dtype=None):
    """Sparse x sparse block-pair products, accumulated into a dense tile.

    For host-known sparsity structure: ``pair_a[k]``/``pair_b[k]`` index the
    stored blocks of A and B whose product contributes to output block
    ``(pair_rows[k], pair_cols[k])``.  Padding pairs must point at zero blocks.
    """
    bs = a_blocks.shape[1]
    prods = jnp.einsum(
        "kab,kbc->kac", a_blocks[pair_a], b_blocks[pair_b],
        preferred_element_type=jnp.float32)                        # [P, bs, bs]
    out = jnp.zeros((n_block_rows, n_block_cols, bs, bs), jnp.float32)
    out = out.at[pair_rows, pair_cols].add(prods)
    out = out.transpose(0, 2, 1, 3).reshape(n_block_rows * bs, n_block_cols * bs)
    out_dtype = out_dtype or jnp.promote_types(a_blocks.dtype, b_blocks.dtype)
    return out.astype(out_dtype)


def bsr_pair_accumulate_raw_ref(a_blocks, b_blocks, pair_a, pair_b,
                                pair_slot, n_slots: int):
    """Sparse x sparse block-pair products, accumulated into PACKED blocks.

    The sparse-output sibling of :func:`bsr_pair_matmul_raw_ref`: instead
    of scattering into a dense ``(nbr, nbc)`` block grid, products land in
    a flat slot array of length ``n_slots`` — the symbolic phase's
    capacity-bounded output layout.  ``pair_slot`` must be nondecreasing
    and pairs referencing zero blocks must be inert (both guaranteed by
    ``repro.core.symbolic``).  Returns f32[n_slots, bs, bs]; the caller
    casts to the output dtype.
    """
    prods = jnp.einsum(
        "kab,kbc->kac", a_blocks[pair_a], b_blocks[pair_b],
        preferred_element_type=jnp.float32)                        # [P, bs, bs]
    # pair_slot is nondecreasing by contract: a sorted segment reduction
    # beats a general scatter-add on CPU/GPU backends
    return jax.ops.segment_sum(prods, pair_slot, num_segments=n_slots,
                               indices_are_sorted=True)


def densify_raw(blocks, rows, cols, n_block_rows: int, n_block_cols: int):
    """Scatter a flat block list into a dense tile (SpGEMM B-side helper)."""
    cap, bs, _ = blocks.shape
    out = jnp.zeros((n_block_rows, n_block_cols, bs, bs), blocks.dtype)
    out = out.at[rows, cols].add(blocks)
    return out.transpose(0, 2, 1, 3).reshape(n_block_rows * bs, n_block_cols * bs)
