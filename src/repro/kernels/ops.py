"""Public jit'd wrappers for the Pallas kernels.

``impl`` dispatch:
  * ``"pallas"``     — real TPU lowering (production target).
  * ``"interpret"``  — Pallas interpret mode (CPU validation; this container).
  * ``"ref"``        — pure-jnp oracle (used inside CPU shard_map tests and as
                       the allclose target).
  * ``"auto"``       — pallas on TPU backends, ref elsewhere.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .bsr_spmm import (bsr_pair_accumulate_pallas, bsr_pair_matmul_pallas,
                       bsr_spmm_pallas)

__all__ = [
    "default_impl", "bsr_spmm", "bsr_spmm_raw", "match_block_pairs",
    "build_pair_lists", "bsr_pair_matmul", "bsr_pair_accumulate",
    "steal_pair_accumulate", "densify", "densify_packed",
]


def default_impl() -> str:
    return "pallas" if jax.default_backend() in ("tpu",) else "ref"


def _resolve(impl: Optional[str]) -> str:
    impl = impl or "auto"
    return default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------
def bsr_spmm_raw(blocks, rows, cols, dense, *, n_block_rows: int,
                 impl: Optional[str] = None, block_n: int = 256,
                 augment: bool = True):
    """C = BSR(blocks, rows, cols) @ dense — raw-array form (shard_map-safe).

    ``augment=False`` asserts the caller's arrays are already
    coverage-augmented and row-sorted (every output block-row present —
    the :class:`repro.core.bsr.TiledBSR` storage contract), skipping the
    concat + stable-argsort below.  The distributed ring bodies rely on
    this: augmentation must not be re-traced into every scanned step.
    """
    impl = _resolve(impl)
    bs = blocks.shape[1]
    n = dense.shape[1]
    if n == 0:  # half-panel schedules can produce empty panels at tiny tn;
        # impl-independent (the ref path's reshape(-1, bs, 0) divides by 0)
        return jnp.zeros((n_block_rows * bs, 0),
                         jnp.promote_types(blocks.dtype, dense.dtype))
    if impl == "ref":
        return _ref.bsr_spmm_raw_ref(blocks, rows, cols, dense, n_block_rows)
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    if augment:
        # Coverage augmentation: append one zero block per block-row so that
        # every output block is visited (and therefore zero-initialized) by
        # the kernel, even for rows with no stored blocks.  Stable sort keeps
        # row order.
        cov = jnp.arange(n_block_rows, dtype=rows.dtype)
        rows_aug = jnp.concatenate([rows, cov])
        order = jnp.argsort(rows_aug, stable=True)
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((n_block_rows, bs, bs), blocks.dtype)])[order]
        cols = jnp.concatenate(
            [cols, jnp.zeros((n_block_rows,), cols.dtype)])[order]
        rows = rows_aug[order]
    return bsr_spmm_pallas(blocks, rows, cols, dense,
                           n_block_rows=n_block_rows, block_n=max(bn, 1),
                           interpret=(impl == "interpret"))


def bsr_spmm(a_bsr, dense, *, impl: Optional[str] = None, block_n: int = 256):
    """C = A @ dense for a :class:`repro.core.bsr.BSR` A."""
    return bsr_spmm_raw(a_bsr.blocks, a_bsr.rows, a_bsr.cols, dense,
                        n_block_rows=a_bsr.n_block_rows, impl=impl,
                        block_n=block_n)


# ---------------------------------------------------------------------------
# SpGEMM (host-known structure): pair-list construction + kernel
# ---------------------------------------------------------------------------
def match_block_pairs(a_cols, b_rows):
    """Vectorized sort-merge join on ``a_cols[i] == b_rows[j]`` (host numpy).

    The core of the SpGEMM symbolic phase: every (A block, B block) pair
    whose product contributes to C.  Returns ``(ai, bj)`` index arrays into
    the given lists; within one A block, matched B blocks keep their
    original order (the stable argsort), matching the insertion order of
    the legacy dict-of-lists construction.  Shared by
    :func:`build_pair_lists` (dense-tile SpGEMM) and
    ``repro.core.symbolic`` (distributed sparse-output SpGEMM).
    """
    a_cols = np.asarray(a_cols, dtype=np.int64)
    b_rows = np.asarray(b_rows, dtype=np.int64)
    b_order = np.argsort(b_rows, kind="stable")
    b_rows_sorted = b_rows[b_order]
    starts = np.searchsorted(b_rows_sorted, a_cols, side="left")
    ends = np.searchsorted(b_rows_sorted, a_cols, side="right")
    deg = ends - starts
    ai = np.repeat(np.arange(len(a_cols), dtype=np.int64), deg)
    offs = np.arange(deg.sum(), dtype=np.int64) - np.repeat(
        np.cumsum(deg) - deg, deg)
    bj = b_order[np.repeat(starts, deg) + offs]
    return ai, bj


def build_pair_lists(a_rows, a_cols, a_nnzb: int, b_rows, b_cols, b_nnzb: int,
                     n_block_rows: int, n_block_cols: int,
                     capacity: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side symbolic phase of block SpGEMM.

    Matches stored blocks of A and B with ``a_cols[i] == b_rows[j]`` and emits
    flat pair lists sorted by output block (row, col).  Every output block is
    covered at least once (uncovered blocks get a dummy pair referencing the
    zero slot appended by :func:`bsr_pair_matmul`), so the Pallas kernel's
    first-visit zeroing covers the whole C tile.

    Returns (pair_a, pair_b, pair_rows, pair_cols, n_real_pairs); index
    ``len(a_blocks)`` / ``len(b_blocks)`` denotes the appended zero slot.
    """
    a_rows = np.asarray(a_rows)[:a_nnzb].astype(np.int64)
    a_cols = np.asarray(a_cols)[:a_nnzb].astype(np.int64)
    b_rows = np.asarray(b_rows)[:b_nnzb].astype(np.int64)
    b_cols = np.asarray(b_cols)[:b_nnzb].astype(np.int64)
    # Vectorized sort-merge join on a_cols == b_rows (replaces the python
    # dict-of-lists construction; ~11x faster at 5k stored blocks, growing
    # with the pair count — see benchmarks/kernels_bench.py).
    ai, bj = match_block_pairs(a_cols, b_rows)
    rows = a_rows[ai]
    cols = b_cols[bj]
    # Coverage: dummy pairs (referencing the appended zero slots) for output
    # blocks no real product touches, in row-major order like the real pairs.
    zslot_a, zslot_b = a_nnzb, b_nnzb  # remapped to zero slot by the wrapper
    covered = np.zeros((n_block_rows, n_block_cols), dtype=bool)
    covered[rows, cols] = True
    ur, uc = np.nonzero(~covered)
    pair_rows = np.concatenate([rows, ur])
    pair_cols = np.concatenate([cols, uc])
    pair_a = np.concatenate([ai, np.full(len(ur), zslot_a, np.int64)])
    pair_b = np.concatenate([bj, np.full(len(ur), zslot_b, np.int64)])
    # Final stable sort by output block (row, col); the trailing position key
    # pins tie order to construction order (lexsort alone is stable, but be
    # explicit — the kernel's first-visit zeroing depends only on grouping,
    # the exact tie order is part of the legacy output contract).
    order = np.lexsort((np.arange(len(pair_rows)), pair_cols, pair_rows))
    pair_a, pair_b = pair_a[order], pair_b[order]
    pair_rows, pair_cols = pair_rows[order], pair_cols[order]
    n_real = len(pair_rows)
    cap = capacity if capacity is not None else n_real
    if n_real > cap:
        raise ValueError(f"pair capacity {cap} < required {n_real}")
    pad = cap - n_real
    pair_rows = np.concatenate([pair_rows, np.full(pad, pair_rows[-1])])
    pair_cols = np.concatenate([pair_cols, np.full(pad, pair_cols[-1])])
    pair_a = np.concatenate([pair_a, np.full(pad, zslot_a, np.int64)])
    pair_b = np.concatenate([pair_b, np.full(pad, zslot_b, np.int64)])
    return (pair_a.astype(np.int32), pair_b.astype(np.int32),
            pair_rows.astype(np.int32), pair_cols.astype(np.int32), n_real)


def bsr_pair_matmul(a_blocks, b_blocks, pair_a, pair_b, pair_rows, pair_cols,
                    *, n_block_rows: int, n_block_cols: int,
                    impl: Optional[str] = None):
    """Dense C tile from matched block pairs (see :func:`build_pair_lists`)."""
    impl = _resolve(impl)
    bs = a_blocks.shape[1]
    zero = jnp.zeros((1, bs, bs), a_blocks.dtype)
    a_ext = jnp.concatenate([a_blocks, zero.astype(a_blocks.dtype)])
    b_ext = jnp.concatenate([b_blocks, zero.astype(b_blocks.dtype)])
    if impl == "ref":
        return _ref.bsr_pair_matmul_raw_ref(
            a_ext, b_ext, pair_a, pair_b, pair_rows, pair_cols,
            n_block_rows, n_block_cols)
    return bsr_pair_matmul_pallas(
        a_ext, b_ext, pair_a, pair_b, pair_rows, pair_cols,
        n_block_rows=n_block_rows, n_block_cols=n_block_cols,
        interpret=(impl == "interpret"))


def bsr_pair_accumulate(a_blocks, b_blocks, pair_a, pair_b, pair_slot, *,
                        n_slots: int, out_dtype=None,
                        impl: Optional[str] = None):
    """Packed C blocks from matched pairs — the sparse-output SpGEMM inner.

    Unlike :func:`bsr_pair_matmul`, products accumulate into a flat
    ``[n_slots, bs, bs]`` slot array (the symbolic phase's capacity-bounded
    output layout) instead of a dense C tile.  Contract (established by
    ``repro.core.symbolic``): ``pair_slot`` is nondecreasing, every slot is
    visited at least once (coverage pairs), and dummy pairs reference zero
    blocks.  No zero slot is appended here — the operand tiles' own zero
    (coverage) blocks serve as the dummy targets, keeping the scanned ring
    step concat-free.

    ``pair_a``/``pair_b`` may index the operands' stored (padded) layout
    or the packed wire layout of ``repro.core.wire`` — the receiver-side
    slot mapping is composed into the lists at plan time
    (``wire.remap_pairs_packed``), so packed buffers are consumed with no
    unpack copy and this kernel stays layout-agnostic.
    """
    impl = _resolve(impl)
    out_dtype = out_dtype or jnp.promote_types(a_blocks.dtype, b_blocks.dtype)
    if impl == "ref":
        out = _ref.bsr_pair_accumulate_raw_ref(
            a_blocks, b_blocks, pair_a, pair_b, pair_slot, n_slots)
    else:
        out = bsr_pair_accumulate_pallas(
            a_blocks, b_blocks, pair_a, pair_b, pair_slot, n_slots=n_slots,
            interpret=(impl == "interpret"))
    return out.astype(out_dtype)


def steal_pair_accumulate(a_pool, b_rows, pair_a, pair_b, pair_slot, *,
                          n_slots: int, impl: Optional[str] = None,
                          block_n: int = 256):
    """Packed partial-C accumulation for the steal3d static dispatch.

    ``a_pool`` is a device's pooled A blocks (row panel + moved tiles +
    trailing zero block), ``b_rows`` its pooled dense B panel flattened to
    bs-row chunks.  Each pair multiplies ``a_pool[pair_a[p]]`` against
    chunk ``pair_b[p]`` and accumulates the [bs, n] product into output
    row-block ``pair_slot[p]`` — exactly the :func:`bsr_spmm_raw` contract
    with plan-built pair lists (``repro.core.steal3d``) standing in for a
    tile's stored structure, so every impl path (ref / interpret / pallas)
    is reused unchanged.  Contract: ``pair_slot`` nondecreasing, every
    slot visited at least once (coverage pairs), dummy pairs reference the
    zero block.
    """
    return bsr_spmm_raw(a_pool[pair_a], pair_slot, pair_b, b_rows,
                        n_block_rows=n_slots, impl=impl, block_n=block_n,
                        augment=False)


def densify(blocks, rows, cols, *, n_block_rows: int, n_block_cols: int):
    return _ref.densify_raw(blocks, rows, cols, n_block_rows, n_block_cols)


def densify_packed(blocks, dmap, *, n_block_rows: int, n_block_cols: int):
    """Dense tile from packed wire blocks via a static *gather*.

    ``dmap`` (built by ``repro.core.wire``) maps every dense block
    position, row-major, to the packed slot holding its data — or to a
    guaranteed-zero slot for structurally empty positions.  This is the
    packed-wire replacement for :func:`densify` inside scanned ring steps:
    structure is plan-time static, so the scatter of ``densify_raw``
    becomes a gather + transpose and the hot-loop jaxpr stays
    sort/scatter-free (the invariant ``tests/test_api.py`` asserts).
    """
    bs = blocks.shape[-1]
    d = blocks[dmap].reshape(n_block_rows, n_block_cols, bs, bs)
    return d.transpose(0, 2, 1, 3).reshape(n_block_rows * bs,
                                           n_block_cols * bs)
