"""Public jit'd wrappers for the Pallas kernels.

``impl`` dispatch:
  * ``"pallas"``     — real TPU lowering (production target).
  * ``"interpret"``  — Pallas interpret mode (CPU validation; this container).
  * ``"ref"``        — pure-jnp oracle (used inside CPU shard_map tests and as
                       the allclose target).
  * ``"auto"``       — pallas on TPU backends, ref elsewhere.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .bsr_spmm import bsr_pair_matmul_pallas, bsr_spmm_pallas

__all__ = [
    "default_impl", "bsr_spmm", "bsr_spmm_raw", "build_pair_lists",
    "bsr_pair_matmul", "densify",
]


def default_impl() -> str:
    return "pallas" if jax.default_backend() in ("tpu",) else "ref"


def _resolve(impl: Optional[str]) -> str:
    impl = impl or "auto"
    return default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------
def bsr_spmm_raw(blocks, rows, cols, dense, *, n_block_rows: int,
                 impl: Optional[str] = None, block_n: int = 256):
    """C = BSR(blocks, rows, cols) @ dense — raw-array form (shard_map-safe)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.bsr_spmm_raw_ref(blocks, rows, cols, dense, n_block_rows)
    n = dense.shape[1]
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    # Coverage augmentation: append one zero block per block-row so that every
    # output block is visited (and therefore zero-initialized) by the kernel,
    # even for rows with no stored blocks.  Stable sort keeps row order.
    bs = blocks.shape[1]
    cov = jnp.arange(n_block_rows, dtype=rows.dtype)
    rows_aug = jnp.concatenate([rows, cov])
    order = jnp.argsort(rows_aug, stable=True)
    blocks_aug = jnp.concatenate(
        [blocks, jnp.zeros((n_block_rows, bs, bs), blocks.dtype)])[order]
    cols_aug = jnp.concatenate(
        [cols, jnp.zeros((n_block_rows,), cols.dtype)])[order]
    return bsr_spmm_pallas(blocks_aug, rows_aug[order], cols_aug, dense,
                           n_block_rows=n_block_rows, block_n=max(bn, 1),
                           interpret=(impl == "interpret"))


def bsr_spmm(a_bsr, dense, *, impl: Optional[str] = None, block_n: int = 256):
    """C = A @ dense for a :class:`repro.core.bsr.BSR` A."""
    return bsr_spmm_raw(a_bsr.blocks, a_bsr.rows, a_bsr.cols, dense,
                        n_block_rows=a_bsr.n_block_rows, impl=impl,
                        block_n=block_n)


# ---------------------------------------------------------------------------
# SpGEMM (host-known structure): pair-list construction + kernel
# ---------------------------------------------------------------------------
def build_pair_lists(a_rows, a_cols, a_nnzb: int, b_rows, b_cols, b_nnzb: int,
                     n_block_rows: int, n_block_cols: int,
                     capacity: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side symbolic phase of block SpGEMM.

    Matches stored blocks of A and B with ``a_cols[i] == b_rows[j]`` and emits
    flat pair lists sorted by output block (row, col).  Every output block is
    covered at least once (uncovered blocks get a dummy pair referencing the
    zero slot appended by :func:`bsr_pair_matmul`), so the Pallas kernel's
    first-visit zeroing covers the whole C tile.

    Returns (pair_a, pair_b, pair_rows, pair_cols, n_real_pairs); index
    ``len(a_blocks)`` / ``len(b_blocks)`` denotes the appended zero slot.
    """
    a_rows = np.asarray(a_rows)[:a_nnzb]
    a_cols = np.asarray(a_cols)[:a_nnzb]
    b_rows = np.asarray(b_rows)[:b_nnzb]
    b_cols = np.asarray(b_cols)[:b_nnzb]
    by_brow = {}
    for j, (br, bc) in enumerate(zip(b_rows, b_cols)):
        by_brow.setdefault(int(br), []).append((j, int(bc)))
    pairs = []
    for i, (ar, ac) in enumerate(zip(a_rows, a_cols)):
        for j, bc in by_brow.get(int(ac), ()):
            pairs.append((int(ar), bc, i, j))
    covered = {(r, c) for (r, c, _, _) in pairs}
    zslot_a, zslot_b = a_nnzb, b_nnzb  # remapped to zero slot by the wrapper
    for r in range(n_block_rows):
        for c in range(n_block_cols):
            if (r, c) not in covered:
                pairs.append((r, c, zslot_a, zslot_b))
    pairs.sort(key=lambda t: (t[0], t[1]))
    n_real = len(pairs)
    cap = capacity if capacity is not None else n_real
    if n_real > cap:
        raise ValueError(f"pair capacity {cap} < required {n_real}")
    last = pairs[-1]
    pairs.extend([(last[0], last[1], zslot_a, zslot_b)] * (cap - n_real))
    arr = np.asarray(pairs, dtype=np.int32)
    return arr[:, 2], arr[:, 3], arr[:, 0], arr[:, 1], n_real


def bsr_pair_matmul(a_blocks, b_blocks, pair_a, pair_b, pair_rows, pair_cols,
                    *, n_block_rows: int, n_block_cols: int,
                    impl: Optional[str] = None):
    """Dense C tile from matched block pairs (see :func:`build_pair_lists`)."""
    impl = _resolve(impl)
    bs = a_blocks.shape[1]
    zero = jnp.zeros((1, bs, bs), a_blocks.dtype)
    a_ext = jnp.concatenate([a_blocks, zero.astype(a_blocks.dtype)])
    b_ext = jnp.concatenate([b_blocks, zero.astype(b_blocks.dtype)])
    if impl == "ref":
        return _ref.bsr_pair_matmul_raw_ref(
            a_ext, b_ext, pair_a, pair_b, pair_rows, pair_cols,
            n_block_rows, n_block_cols)
    return bsr_pair_matmul_pallas(
        a_ext, b_ext, pair_a, pair_b, pair_rows, pair_cols,
        n_block_rows=n_block_rows, n_block_cols=n_block_cols,
        interpret=(impl == "interpret"))


def densify(blocks, rows, cols, *, n_block_rows: int, n_block_cols: int):
    return _ref.densify_raw(blocks, rows, cols, n_block_rows, n_block_cols)
