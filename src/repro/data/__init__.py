from .pipeline import SyntheticLM, MemmapTokens, Prefetcher, make_batch_specs  # noqa: F401
