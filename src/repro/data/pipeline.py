"""Deterministic, restartable data pipelines.

* :class:`SyntheticLM` — seeded synthetic token/frames/patch streams for all
  model families; batch content is a pure function of (seed, step), so a
  restarted job resumes bit-identically from a checkpointed step — part of
  the fault-tolerance contract.
* :class:`MemmapTokens` — flat binary token file (np.memmap), sequence-
  chunked, sharded by (host_index, num_hosts); what a real corpus would use.
* :class:`Prefetcher` — background-thread prefetch of the next N batches
  (overlaps host data work with device compute).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ModelConfig

__all__ = ["SyntheticLM", "MemmapTokens", "Prefetcher", "make_batch_specs"]


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    """Shape/dtype dict of one raw batch for every family (pre-shift)."""
    if cfg.frontend == "audio":
        return {
            "frames": ((batch, seq, cfg.frontend_dim), np.float32),
            "labels": ((batch, seq), np.int32),
        }
    if cfg.frontend == "vlm":
        text = seq - cfg.num_patches
        return {
            "tokens": ((batch, text + 1), np.int32),
            "patches": ((batch, cfg.num_patches, cfg.frontend_dim),
                        np.float32),
        }
    return {"tokens": ((batch, seq + 1), np.int32)}


class SyntheticLM:
    """Learnable synthetic streams (not uniform noise: a bigram-ish process
    so that a training run shows decreasing loss)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 host_index: int = 0, num_hosts: int = 1):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.host_index, self.num_hosts = seed, host_index, num_hosts

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.host_index)
        cfg = self.cfg
        if cfg.frontend == "audio":
            labels = rng.integers(0, cfg.vocab_size,
                                  (self.batch, self.seq), dtype=np.int32)
            # frames correlate with labels so the task is learnable
            proto = rng.standard_normal((cfg.vocab_size, cfg.frontend_dim))
            frames = proto[labels] + 0.1 * rng.standard_normal(
                (self.batch, self.seq, cfg.frontend_dim))
            return {"frames": frames.astype(np.float32), "labels": labels}
        if cfg.frontend == "vlm":
            text = self.seq - cfg.num_patches
            toks = self._bigram(rng, self.batch, text + 1, cfg.vocab_size)
            patches = rng.standard_normal(
                (self.batch, cfg.num_patches, cfg.frontend_dim))
            return {"tokens": toks,
                    "patches": patches.astype(np.float32)}
        return {"tokens": self._bigram(rng, self.batch, self.seq + 1,
                                       cfg.vocab_size)}

    @staticmethod
    def _bigram(rng, b: int, t: int, vocab: int) -> np.ndarray:
        """next ~ (3*prev + noise) mod vocab — low-entropy, learnable."""
        out = np.zeros((b, t), dtype=np.int64)
        out[:, 0] = rng.integers(0, vocab, b)
        noise = rng.integers(0, 7, (b, t))
        for i in range(1, t):
            out[:, i] = (3 * out[:, i - 1] + noise[:, i]) % vocab
        return out.astype(np.int32)


class MemmapTokens:
    """Sequence-chunked reader over a flat int32 token file."""

    def __init__(self, path: str, batch: int, seq: int,
                 host_index: int = 0, num_hosts: int = 1):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.batch, self.seq = batch, seq
        self.host_index, self.num_hosts = host_index, num_hosts
        per = seq + 1
        self.n_seqs = len(self.data) // per
        if self.n_seqs < batch * num_hosts:
            raise ValueError("token file too small for one global batch")

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        per = self.seq + 1
        # deterministic strided order, disjoint across hosts
        base = (step * self.batch * self.num_hosts
                + self.host_index * self.batch)
        idx = (base + np.arange(self.batch)) % self.n_seqs
        toks = np.stack([self.data[i * per:(i + 1) * per] for i in idx])
        return {"tokens": toks.astype(np.int32)}


class Prefetcher:
    """Thread prefetch of next batches; .get(step) keyed by step for resume."""

    def __init__(self, source, depth: int = 2, start_step: int = 0):
        self.source = source
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            step = self._next
            batch = self.source(step)
            self._q.put((step, batch))
            self._next += 1

    def get(self, step: int) -> Dict[str, np.ndarray]:
        while True:
            s, b = self._q.get()
            if s == step:
                return b
            # stale (post-restart): drop and keep draining

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
