"""Request-level serving over the plan-based sparse engine.

Public surface: :class:`ServeEngine` (continuous batching + plan-cache
reuse), :class:`Request`/:class:`RequestBatcher` (shape-bucketed
admission) and :class:`ServingMetrics` (TTFT/TPOT percentiles,
plans-per-second, dropped-token stats).  ``serving.engine`` internals are
off-limits outside this package — ``tools/check_api.py`` enforces it.
"""
from .batcher import (DEFAULT_BUCKETS, Request, RequestBatcher, bucket_for,
                      effective_bucket, padding_supported)
from .engine import ServeEngine
from .metrics import ServingMetrics, percentile, sync_elapsed
from .sparse import segment_trace_counts

__all__ = [
    "ServeEngine", "Request", "RequestBatcher", "ServingMetrics",
    "DEFAULT_BUCKETS", "bucket_for", "effective_bucket",
    "padding_supported", "percentile", "sync_elapsed",
    "segment_trace_counts",
]
