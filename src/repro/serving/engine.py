"""ServeEngine: request-level serving over the plan API.

Admission -> prefill -> decode with **continuous batching**: the engine
owns a fixed pool of ``max_batch`` decode slots; new requests prefill at a
bucketed shape (one jit trace / plan set per bucket, shared by every
tenant in it), their KV rows are spliced into the batch cache at a free
slot, and they join the very next decode step.  Finished requests retire
at step boundaries and their slots are immediately reusable — no
generation-length barrier, which is what keeps the decode batch full under
mixed-length traffic.

Each decode-batch row carries its own position (``pos: [B]``, see
``models/attention.py``), so requests at different depths coexist in one
step.  Vacant slots keep decoding garbage into their own cache row — their
outputs are ignored and the row is fully overwritten at the next
admission, so correctness is untouched and the step shape stays static
(one jitted executable for the whole run).

With ``sparse=True`` the hot path runs on the paper's engine: MoE
dispatch/combine and prefill attention scoring become ``DistBSR`` x
``DistDense`` products through the shared ``plan_matmul`` LRU cache (see
``serving/sparse.py``); :meth:`cache_stats` surfaces the hit/miss/eviction
counters that show plans being reused across tenants.

This module is internal: import :class:`ServeEngine` from
``repro.serving`` (enforced by ``tools/check_api.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs as _obs
from ..core import api as _api
from ..models import lm, transformer as tf
from ..models.config import ModelConfig
from .batcher import DEFAULT_BUCKETS, RequestBatcher
from .metrics import ServingMetrics, sync_elapsed
from .sparse import SparseOps, sparse_attn_forward, sparse_moe_forward


@dataclasses.dataclass
class _Active:
    rid: int
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    """Continuous-batching serving engine over one model + mesh."""

    def __init__(self, cfg: ModelConfig, *, params: Optional[Dict] = None,
                 seed: int = 0, max_batch: int = 4, max_len: int = 64,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 sparse: bool = False, block_size: int = 8, mesh=None,
                 cache_dtype=jnp.float32, replanner=None,
                 replan_budget_s: float = float("inf")):
        if cfg.is_encoder:
            raise ValueError("encoder models have no decode path")
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.sparse = sparse
        # optional ElasticReplanner (duck-typed: should_replan/refit) —
        # checked at batch boundaries; see _maybe_replan
        self.replanner = replanner
        self.replan_budget_s = replan_budget_s
        self.replans = 0
        self.params = params if params is not None else \
            tf.init_params(cfg, jax.random.PRNGKey(seed))
        self.batcher = RequestBatcher(cfg, max_len, buckets)
        self.metrics = ServingMetrics()
        self.ops = SparseOps(block_size=block_size, mesh=mesh) \
            if sparse else None

        # decode-slot state (B = max_batch rows, recycled across requests)
        self.caches = tf.init_cache(cfg, max_batch, max_len, cache_dtype)
        self.pos = jnp.zeros((max_batch,), jnp.int32)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.active: Dict[int, _Active] = {}        # slot -> request state
        self.results: Dict[int, np.ndarray] = {}
        self._cache_dtype = cache_dtype
        self._prefill_fns: Dict[int, callable] = {}
        self._decode_fn = None if sparse else \
            jax.jit(lm.make_decode_step(cfg, with_aux=True))
        self._insert_fn = jax.jit(self._insert_row)
        self._n_moe = (sum(1 for k in cfg.pattern if k in ("g", "l"))
                       if cfg.moe is not None else 0)

    # ------------------------------------------------------------ sparse fns
    def _moe_fn(self, p, x, cfg):
        return sparse_moe_forward(self.ops, p, x, cfg)

    def _attn_fn(self, p, x, cfg, kind, positions, cache):
        return sparse_attn_forward(self.ops, p, x, cfg, kind, positions,
                                   cache)

    # -------------------------------------------------------------- requests
    def submit(self, tokens, max_new_tokens: int, arrival: float = 0.0,
               rid: Optional[int] = None):
        """Queue a request.  ``arrival`` is an offset (s) from run start."""
        return self.batcher.submit(tokens, max_new_tokens, arrival, rid)

    # --------------------------------------------------------------- prefill
    @staticmethod
    def _insert_row(caches, row, slot):
        """Splice a batch-1 prefilled cache into the decode cache at slot.

        Every cache leaf is stacked ``[units, B, ...]``, so the batch dim
        is axis 1 throughout — one dynamic-update-slice per leaf.
        """
        return jax.tree.map(
            lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                c, r.astype(c.dtype), slot, axis=1), caches, row)

    def _prefill_for(self, bucket: int):
        fn = self._prefill_fns.get(bucket)
        if fn is not None:
            return fn
        cfg, max_len, cdt = self.cfg, self.max_len, self._cache_dtype
        if self.sparse:
            # The sparse forward interleaves host-side operator
            # construction with device math, so it cannot be one trace;
            # its pure-jax segments are jitted per bucket inside
            # serving/sparse.py (see segment_trace_counts), and the
            # matmuls themselves run through cached MatmulPlans.
            def fn(params, toks, lengths):
                caches = tf.init_cache(cfg, 1, max_len, cdt)
                logits, caches, _ = tf.forward_unscanned(
                    params, {"tokens": toks}, cfg, caches=caches,
                    moe_fn=self._moe_fn, attn_fn=self._attn_fn)
                last = jnp.take_along_axis(
                    logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
                return last, lm._mask_pad_slots(caches, lengths), lengths
        else:
            fn = jax.jit(lambda params, toks, lengths: lm.prefill(
                params, {"tokens": toks}, cfg, max_len, cdt, lengths))
        self._prefill_fns[bucket] = fn
        return fn

    def _admit(self, req) -> None:
        slot = next(s for s in range(self.max_batch)
                    if s not in self.active)
        toks_np, length = self.batcher.padded(req)
        sp = _obs.span("serve.admit", rid=req.rid, bucket=toks_np.shape[1])
        with sp:
            self.metrics.admitted(req.rid, toks_np.shape[1])
            t0 = time.perf_counter()
            with _obs.span("serve.prefill", rid=req.rid,
                           bucket=toks_np.shape[1]):
                fn = self._prefill_for(toks_np.shape[1])
                last, row, row_pos = fn(self.params, jnp.asarray(toks_np),
                                        jnp.asarray([length], jnp.int32))
                tok = jnp.argmax(last, axis=-1).astype(jnp.int32)  # [1]
                self.caches = self._insert_fn(self.caches, row,
                                              jnp.asarray(slot, jnp.int32))
                self.pos = self.pos.at[slot].set(length)
                self.tokens = self.tokens.at[slot, 0].set(tok[0])
                dt = sync_elapsed(t0, (self.caches, self.tokens))
            sp.note(prefill_s=dt)
        self.metrics.prefill_done(req.rid, dt)
        st = _Active(req.rid, req.max_new_tokens)
        st.out.append(int(tok[0]))
        self.active[slot] = st
        self._maybe_finish(slot)

    def _maybe_finish(self, slot: int) -> None:
        st = self.active[slot]
        if len(st.out) >= st.max_new_tokens \
                or int(self.pos[slot]) >= self.max_len:
            self.results[st.rid] = np.asarray(st.out, np.int32)
            self.metrics.finished(st.rid)
            del self.active[slot]

    # ---------------------------------------------------------------- decode
    def _decode_step(self) -> None:
        with _obs.span("serve.decode_step", batch=len(self.active)) as sp:
            self._decode_step_inner(sp)

    def _decode_step_inner(self, sp) -> None:
        t0 = time.perf_counter()
        if self.sparse:
            logits, caches, aux = tf.decode_step_unscanned(
                self.params, self.tokens, self.caches, self.pos, self.cfg,
                moe_fn=self._moe_fn)
            logits = logits[:, 0]
        else:
            logits, caches, aux = self._decode_fn(
                self.params, self.tokens, self.caches, self.pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)    # [B]
        active_mask = np.zeros((self.max_batch,), np.int32)
        for s in self.active:
            active_mask[s] = 1
        self.caches = caches
        self.pos = self.pos + jnp.asarray(active_mask)
        self.tokens = tok[:, None]
        dt = sync_elapsed(t0, (self.tokens, self.caches))
        sp.note(step_s=dt)
        dropped = (float(aux["dropped"]) / self._n_moe
                   if self._n_moe else None)
        rids = [st.rid for st in self.active.values()]
        self.metrics.decode_step_done(dt, rids, dropped)
        tok_np = np.asarray(tok)
        for slot in list(self.active):
            self.active[slot].out.append(int(tok_np[slot]))
            self._maybe_finish(slot)

    # ----------------------------------------------------------- replanning
    def _maybe_replan(self) -> bool:
        """Drain-and-refit at a batch boundary when the replanner trips.

        In-flight requests decode to completion first so no request ever
        straddles a plan swap; then the replanner re-fits the machine and
        evicts the stale plans (they rebuild lazily on the next cache
        miss).  The drain + refit together are expected to fit in
        ``replan_budget_s`` — overruns are surfaced as a counter, never
        an exception, so serving always makes progress.
        """
        rp = self.replanner
        if rp is None:
            return False
        trips = rp.should_replan()
        if not trips:
            return False
        t0 = time.perf_counter()
        with _obs.span("serve.replan", trips=",".join(sorted(trips))) as sp:
            drained = 0
            while self.active:
                self._decode_step()
                drained += 1
            rp.refit(trips)
            dt = sync_elapsed(t0, (self.tokens, self.caches))
            sp.note(drained_steps=drained, replan_s=dt)
        reg = _obs.registry()
        reg.counter("serve.replans").inc()
        reg.histogram("serve.replan_s").observe(dt)
        if dt > self.replan_budget_s:
            reg.counter("serve.replan_budget_exceeded").inc()
        self.replans += 1
        return True

    # ------------------------------------------------------------------- run
    def run(self) -> Dict[int, np.ndarray]:
        """Serve every queued request to completion; returns rid -> tokens.

        Admission happens at step boundaries: before each decode step any
        arrived request takes a free slot (continuous batching).  Timing
        blocks per measurement window — prefill and decode never overlap a
        measurement (see serving/metrics.py).
        """
        m = self.metrics
        t0 = m.start()
        for req in list(self.batcher._queue):
            m.submitted(req.rid, t0 + req.arrival, req.prompt_len)
        while len(self.batcher) or self.active:
            self._maybe_replan()
            now = time.perf_counter() - t0
            while len(self.active) < self.max_batch:
                req = self.batcher.pop(now)
                if req is None:
                    break
                self._admit(req)
            if not self.active:
                nxt = self.batcher.next_arrival()
                if nxt is not None and nxt > now:
                    time.sleep(min(nxt - now, 0.005))
                continue
            self._decode_step()
        m.stop()
        return dict(self.results)

    # ------------------------------------------------------------- observab.
    def cache_stats(self) -> Dict[str, Dict[str, int]]:
        """Plan-layer cache counters (``repro.core.api.cache_stats``)."""
        return _api.cache_stats()

    def summary(self) -> Dict:
        return self.metrics.summary()
