"""Plan-based sparse hot path: MoE dispatch and attention scoring as
``DistBSR`` x ``DistDense`` products through ``plan_matmul``.

This is the point where the paper's engine meets the model stack:

* **MoE dispatch/combine** — token-choice routing *is* SpMM (see
  ``models/moe.py``): the dispatch operator ``D`` is a {0,1}-sparse
  (expert-slots x tokens) matrix and ``dispatch = D @ X``,
  ``combine = (D * probs)^T @ Y``.  Here those two products literally run
  through the plan API on the stationary-A (``ring_a``) schedule — expert
  slots stay put, activations ride the ring — with ``D`` tiled at
  bucketed capacity so consecutive decode steps (whose routing structure
  differs, but whose bucketed abstract shapes coincide) reuse one cached,
  jitted executable.
* **Attention scoring** — per (batch, head) blocks stacked block-diagonal
  make ``S = Q_bd @ K_bd^T`` a genuinely block-sparse SpGEMM
  (``output="sparse"``: only diagonal blocks are ever computed or
  stored), and the probability matrix ``P`` (block-diagonal *and*
  block-triangular under the causal/local mask) feeds the combine
  ``O = P_bsr @ V`` as a second SpMM.  Both structures are a function of
  the padded bucket only, so every tenant in a bucket shares the plans.

Routing math is :func:`repro.models.moe.route_tokens` — the same function
the dense reference uses — so the two paths route identically and outputs
match token for token.

**Jit granularity.**  The hot path alternates device math with host-side
sparse-operator construction (numpy scatter of the dispatch matrix,
block-diagonal stacking), so the whole forward cannot be one trace.
Instead every pure-jax segment — router, expert FFN, QKV projection+RoPE,
masked softmax, output projection — is a module-level ``jax.jit`` whose
trace cache keys on the padded bucket shape (``cfg`` is a static arg):
one trace per bucket, shared by every tenant in it, exactly like the
plan cache underneath.  Static routing geometry comes from
:func:`repro.models.moe.route_meta` so no python int is ever traced.
"""
from __future__ import annotations

import functools

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.api import DistBSR, DistDense, make_grid_mesh, matmul
from ..models import attention as attn_mod
from ..models import moe as moe_mod
from ..models.common import apply_rope, rope, softcap
from ..models.config import ModelConfig

# MoE dispatch is expert-stationary (the paper's stationary-A schedule);
# the scoring SpGEMM needs a sparse-output body, which ring_a doesn't
# have, so scores ride ring_c.
SPMM_ALGORITHM = "ring_a"
SPGEMM_ALGORITHM = "ring_c"


class SparseOps:
    """Shared mesh + tiling config for the engine's plan-based operators.

    One instance per :class:`~repro.serving.ServeEngine`; holding the mesh
    here keeps ``_mesh_key`` stable across calls so plans actually cache.
    """

    def __init__(self, g: int = 1, block_size: int = 8, mesh=None):
        self.g = g
        self.block_size = block_size
        self.mesh = mesh if mesh is not None else make_grid_mesh(g)

    # ------------------------------------------------------------------ SpMM
    def spmm(self, a_dense: np.ndarray, x, algorithm: str = SPMM_ALGORITHM):
        """``a @ x`` with a materialized-sparse left operand.

        ``a_dense`` is tiled into a capacity-bucketed :class:`DistBSR`;
        the plan is fetched from (or added to) the shared LRU cache keyed
        on the bucketed abstract shapes.
        """
        a = DistBSR.from_dense(a_dense, g=self.g,
                               block_size=self.block_size)
        b = DistDense.for_rhs(x, a, allow_pad=True)
        return matmul(a, b, algorithm=algorithm, mesh=self.mesh)

    # ---------------------------------------------------------------- SpGEMM
    def spgemm_sparse(self, a_dense: np.ndarray, b_dense: np.ndarray
                      ) -> DistBSR:
        """Sparse-output ``a @ b`` for two materialized-sparse operands."""
        a = DistBSR.from_dense(a_dense, g=self.g, block_size=self.block_size)
        b = DistBSR.from_dense(b_dense, g=self.g, block_size=self.block_size)
        return matmul(a, b, algorithm=SPGEMM_ALGORITHM, mesh=self.mesh,
                      output="sparse")


# ---------------------------------------------------------------------------
# Jitted segments (one trace per bucket; cfg static, ints via route_meta)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=2)
def _route_segment(router, xf, cfg: ModelConfig) -> Dict:
    """Router math for one bucket — :func:`route_tokens` minus the static
    ints (those come from ``route_meta`` on the host side)."""
    with jax.named_scope("serve.route"):
        r = moe_mod.route_tokens(router, xf, cfg)
        return {k: v for k, v in r.items() if k not in ("cap", "G", "ng")}


@functools.partial(jax.jit, static_argnums=2)
def _expert_segment(p: Dict, xe, cfg: ModelConfig):
    with jax.named_scope("serve.expert_ffn"):
        return moe_mod.expert_ffn(p, xe, cfg)


@functools.partial(jax.jit, static_argnums=3)
def _qkv_segment(p: Dict, x, positions, cfg: ModelConfig):
    """Projection + RoPE + kv-head repeat, laid out for block-diagonal
    stacking: (q_scaled [bh,t,hd], k_rep [bh,t,hd], v_flat [bh*t,hd],
    k_roped, v) — the last two feed the prefill cache write."""
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kh = cfg.n_heads, cfg.n_kv_heads
    grp = h // kh
    q, k, v = attn_mod._project_qkv(p, x, cfg)
    sin, cos = rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    qh = (q.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
          .astype(jnp.float32)) * (hd ** -0.5)
    k_rep = jnp.repeat(k.transpose(0, 2, 1, 3), grp, axis=1)
    v_rep = jnp.repeat(v.transpose(0, 2, 1, 3), grp, axis=1)
    kh_f = k_rep.reshape(b * h, t, hd).astype(jnp.float32)
    v_f = v_rep.reshape(b * h * t, hd).astype(jnp.float32)
    return qh, kh_f, v_f, k, v


@functools.partial(jax.jit, static_argnums=2)
def _prob_segment(s_full, mask, cfg: ModelConfig):
    """Diagonal-block extraction + softcap + mask + softmax; returns the
    already-masked probability matrix (exact zeros off-mask) ready for
    block-diagonal stacking into the combine SpMM."""
    t = mask.shape[-1]
    bh = s_full.shape[0] // t
    diag = jnp.arange(bh)
    scores = s_full.reshape(bh, t, bh, t)[diag, :, diag, :]   # [bh, t, t]
    scores = softcap(scores, cfg.attn_softcap)
    logits = jnp.where(mask[None], scores, attn_mod.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return probs * mask[None].astype(probs.dtype)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _out_segment(o, wo, cfg: ModelConfig, b: int, dtype):
    hd = cfg.resolved_head_dim
    h = cfg.n_heads
    t = o.shape[0] // (b * h)
    out = (o.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
           .reshape(b, t, h * hd).astype(dtype))
    return jnp.einsum("bte,ed->btd", out, wo.astype(dtype))


_JIT_SEGMENTS = {
    "route": _route_segment,
    "expert_ffn": _expert_segment,
    "qkv_rope": _qkv_segment,
    "probs": _prob_segment,
    "out_proj": _out_segment,
}


def segment_trace_counts() -> Dict[str, int]:
    """Traces accumulated per jitted prefill segment — one per distinct
    bucket shape.  Test hook: same-bucket tenants must not grow these."""
    return {k: f._cache_size() for k, f in _JIT_SEGMENTS.items()}


# ---------------------------------------------------------------------------
# MoE forward on the plan API
# ---------------------------------------------------------------------------
def sparse_moe_forward(ops: SparseOps, p: Dict, x, cfg: ModelConfig
                       ) -> Tuple[jnp.ndarray, Dict]:
    """Drop-in for :func:`repro.models.moe.moe_forward` routing dispatch
    and combine through ``plan_matmul``.  x: [B, T, d] -> (y, aux)."""
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    e, k = m.n_experts, m.top_k
    xf = x.reshape(n, d)

    cap, G, ng = moe_mod.route_meta(n, cfg)              # static ints
    r = _route_segment(p["router"], xf, cfg)             # jitted per bucket
    top_e = np.asarray(r["top_e"])                       # [n, k] host sync
    slot = np.asarray(r["slot"])
    keep = np.asarray(r["keep"])

    # dispatch operator D: one unit row per (group, expert, capacity slot)
    gidx = (np.arange(n) // ng)[:, None]                 # [n, 1]
    rows = (gidx * e + top_e) * cap + slot               # [n, k]
    toks = np.broadcast_to(np.arange(n)[:, None], (n, k))
    dtype = np.dtype(jnp.dtype(x.dtype).name)
    disp = np.zeros((G * e * cap, n), dtype)
    np.add.at(disp, (rows[keep], toks[keep]), 1.0)

    buf = ops.spmm(disp, xf)                             # [G*e*cap, d]
    xe = buf.reshape(G, e, cap, d).astype(x.dtype)
    ye = _expert_segment(p, xe, cfg)                     # [G, e, cap, d]

    # combine operator W = (D * probs)^T: [n, G*e*cap], k nnz per row
    top_p = np.asarray(r["top_p"])
    comb = np.zeros((n, G * e * cap), dtype)
    np.add.at(comb, (toks[keep], rows[keep]), top_p[keep])
    y = ops.spmm(comb, ye.reshape(G * e * cap, d))       # [n, d]
    y = y.astype(x.dtype).reshape(b, t, d)
    return y, moe_mod.router_aux(r, cfg)


# ---------------------------------------------------------------------------
# Block-sparse attention on the plan API
# ---------------------------------------------------------------------------
def _block_diag(mats: np.ndarray) -> np.ndarray:
    """[h, r, c] -> [h*r, h*c] block-diagonal."""
    h, r, c = mats.shape
    out = np.zeros((h * r, h * c), mats.dtype)
    for i in range(h):
        out[i * r:(i + 1) * r, i * c:(i + 1) * c] = mats[i]
    return out


def sparse_attn_forward(ops: SparseOps, p: Dict, x, cfg: ModelConfig,
                        kind: str, positions, cache: Optional[Dict] = None):
    """Drop-in for :func:`repro.models.attention.attn_forward` (prefill)
    with scoring and combine on the plan API.

    Per-(batch, head) Q/K/V panels are stacked block-diagonally so the
    whole batch's scoring is one sparse-output SpGEMM and the masked
    probability matrix (block-diagonal x block-causal) drives one SpMM —
    block structure depends only on the padded shape, so plans are shared
    across every request in a bucket.
    """
    b = x.shape[0]
    # stack per-(batch, query-head) panels; kv heads repeat across the group
    qh, kh_f, v_f, k, v = _qkv_segment(p, x, positions, cfg)
    kh_np = np.asarray(kh_f, np.float32)

    # scoring: S_bd = Q_bd @ K_bd^T — sparse x sparse, sparse output
    s_bsr = ops.spgemm_sparse(_block_diag(np.asarray(qh, np.float32)),
                              _block_diag(kh_np.transpose(0, 2, 1)))
    s_full = jnp.asarray(s_bsr.densify())

    # softcap + mask + softmax (identical math to the dense _sdpa reference)
    mask = np.asarray(attn_mod._pair_mask(cfg, kind, positions, positions))
    pm = _prob_segment(s_full, jnp.asarray(mask), cfg)

    # combine: O = P_bd @ V — the mask prunes whole blocks of P
    pv = _block_diag(np.asarray(pm, np.float32))
    o = ops.spmm(pv, v_f)                                     # [bh*t, hd]
    out = _out_segment(jnp.asarray(o), p["wo"], cfg, b, x.dtype)
    if cache is None:
        return out, None
    return out, attn_mod._write_prefill(cache, k, v, positions, cfg, kind)
