"""Serving metrics: per-request TTFT/TPOT, aggregate percentiles, and
plan-cache reuse rates — built on the :mod:`repro.obs` metrics registry.

The engine records wall-clock per measurement window (every timed section
blocks on its outputs via :func:`sync_elapsed`, so async dispatch can never
smear prefill work into the decode window — the bug the old
``launch/serve.py`` loop had).  Aggregate series (prefill/decode seconds,
decode-step counts, TTFT/TPOT/dropped-token distributions) live as
instruments in a per-run :class:`~repro.obs.MetricsRegistry` rather than
ad-hoc attributes: ``summary()`` is a read of the registry plus the
request table, and the same run registry can be snapshot alongside the
process-wide ``obs.registry()``.  Plan-cache counters come from
``repro.core.api.cache_stats()``; ``plans_per_second`` is plan-cache
lookups (hits + misses) over the serving interval, i.e. how often the
engine reached for a ``MatmulPlan`` while under traffic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from .. import obs as _obs
from ..core import api as _api

# Timing + percentile helpers moved to repro.obs (the one sanctioned home
# for jax wall-timing); re-exported here for compatibility.
sync_elapsed = _obs.sync_elapsed
percentile = _obs.percentile


@dataclasses.dataclass
class RequestStats:
    rid: int
    arrival: float
    prompt_len: int
    bucket_len: int = 0
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0
    step_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        """Arrival -> first generated token (queueing + prefill)."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean per-token latency over the decode steps after the first."""
        if not self.step_s:
            return None
        return sum(self.step_s) / len(self.step_s)


class ServingMetrics:
    """Aggregates request lifecycles + cache counters for one serve run.

    Holds its own :class:`~repro.obs.MetricsRegistry` (pass ``registry=``
    to share one): per-run windows need isolated counters, while the
    process-wide ``obs.registry()`` keeps cross-run totals via the
    plan-cache callback.  ``registry.snapshot()`` exposes the raw series.
    """

    def __init__(self, registry: Optional[_obs.MetricsRegistry] = None):
        self.registry = registry or _obs.MetricsRegistry()
        self.requests: Dict[int, RequestStats] = {}
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None
        self._cache0: Optional[Dict] = None
        r = self.registry
        self._prefill_s = r.counter("serve.prefill_s")
        self._decode_s = r.counter("serve.decode_s")
        self._decode_steps = r.counter("serve.decode_steps")
        self._completed = r.counter("serve.completed")
        self._step_h = r.histogram("serve.decode_step_s")
        self._ttft_h = r.histogram("serve.ttft_s")
        self._tpot_h = r.histogram("serve.tpot_s")
        self._dropped_h = r.histogram("serve.dropped_tokens")

    # ------------------------------------------------------------- lifecycle
    def start(self) -> float:
        self._t0 = time.perf_counter()
        self._cache0 = _api.cache_stats()
        return self._t0

    def stop(self) -> None:
        self._t1 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter()

    def submitted(self, rid: int, arrival: float, prompt_len: int) -> None:
        self.requests[rid] = RequestStats(rid, arrival, prompt_len)

    def admitted(self, rid: int, bucket_len: int) -> None:
        r = self.requests[rid]
        r.admitted = time.perf_counter()
        r.bucket_len = bucket_len

    def prefill_done(self, rid: int, dt: float) -> None:
        self._prefill_s.inc(dt)
        self.requests[rid].first_token = time.perf_counter()
        self.requests[rid].n_tokens += 1

    def decode_step_done(self, dt: float, rids: List[int],
                         dropped: Optional[float] = None) -> None:
        self._decode_s.inc(dt)
        self._decode_steps.inc()
        self._step_h.observe(dt)
        if dropped is not None:
            self._dropped_h.observe(float(dropped))
        for rid in rids:
            r = self.requests[rid]
            r.step_s.append(dt)
            r.n_tokens += 1

    def finished(self, rid: int) -> None:
        r = self.requests[rid]
        r.finished = time.perf_counter()
        self._completed.inc()
        if r.ttft is not None:
            self._ttft_h.observe(r.ttft)
        if r.tpot is not None:
            self._tpot_h.observe(r.tpot)

    # --------------------------------------------------------------- summary
    def cache_delta(self) -> Dict[str, Dict[str, int]]:
        """Per-cache counter deltas since :meth:`start`."""
        now = _api.cache_stats()
        base = self._cache0 or {}
        out: Dict[str, Dict[str, int]] = {}
        for name, stats in now.items():
            b = base.get(name, {})
            out[name] = {k: stats[k] - b.get(k, 0)
                         for k in ("hits", "misses", "evictions")}
            out[name]["size"] = stats["size"]
        return out

    def summary(self) -> Dict:
        if self._t1 is None:
            self.stop()
        elapsed = (self._t1 or time.perf_counter()) - (self._t0 or 0.0)
        n_tokens = sum(r.n_tokens for r in self.requests.values())
        decode_s = self._decode_s.value
        caches = self.cache_delta()
        plans = caches.get("plans", {})
        lookups = plans.get("hits", 0) + plans.get("misses", 0)
        hit_rate = (plans.get("hits", 0) / lookups) if lookups else None
        dropped = self._dropped_h
        return {
            "requests": len(self.requests),
            "completed": int(self._completed.value),
            "elapsed_s": elapsed,
            "prefill_s": self._prefill_s.value,
            "decode_s": decode_s,
            "decode_steps": int(self._decode_steps.value),
            "tokens": n_tokens,
            "tokens_per_s": n_tokens / elapsed if elapsed > 0 else None,
            "decode_tok_per_s": (
                sum(len(r.step_s) for r in self.requests.values())
                / decode_s if decode_s > 0 else None),
            "ttft_p50_s": self._ttft_h.percentile(50),
            "ttft_p99_s": self._ttft_h.percentile(99),
            "tpot_p50_s": self._tpot_h.percentile(50),
            "tpot_p99_s": self._tpot_h.percentile(99),
            "plan_lookups": lookups,
            "plans_per_second": lookups / elapsed if elapsed > 0 else None,
            "plan_cache": plans,
            "plan_cache_hit_rate": hit_rate,
            "caches": caches,
            "dropped_mean": (dropped.mean() if dropped.count else 0.0),
            "dropped_max": (dropped.vmax if dropped.count else 0.0),
        }
