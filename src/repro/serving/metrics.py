"""Serving metrics: per-request TTFT/TPOT, aggregate percentiles, and
plan-cache reuse rates.

The engine records wall-clock per measurement window (every timed section
blocks on its outputs via :func:`sync_elapsed`, so async dispatch can never
smear prefill work into the decode window — the bug the old
``launch/serve.py`` loop had).  Plan-cache counters come from
``repro.core.api.cache_stats()``; ``plans_per_second`` is plan-cache
lookups (hits + misses) over the serving interval, i.e. how often the
engine reached for a ``MatmulPlan`` while under traffic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from ..core import api as _api


def sync_elapsed(t0: float, tree) -> float:
    """Block until ``tree``'s arrays are ready, return seconds since t0."""
    jax.block_until_ready(tree)
    return time.perf_counter() - t0


def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile; nan for an empty sample."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    f = (len(s) - 1) * q / 100.0
    lo = int(f)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (f - lo))


@dataclasses.dataclass
class RequestStats:
    rid: int
    arrival: float
    prompt_len: int
    bucket_len: int = 0
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0
    step_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        """Arrival -> first generated token (queueing + prefill)."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean per-token latency over the decode steps after the first."""
        if not self.step_s:
            return None
        return sum(self.step_s) / len(self.step_s)


class ServingMetrics:
    """Aggregates request lifecycles + cache counters for one serve run."""

    def __init__(self):
        self.requests: Dict[int, RequestStats] = {}
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.decode_steps = 0
        self.dropped: List[float] = []
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None
        self._cache0: Optional[Dict] = None

    # ------------------------------------------------------------- lifecycle
    def start(self) -> float:
        self._t0 = time.perf_counter()
        self._cache0 = _api.cache_stats()
        return self._t0

    def stop(self) -> None:
        self._t1 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter()

    def submitted(self, rid: int, arrival: float, prompt_len: int) -> None:
        self.requests[rid] = RequestStats(rid, arrival, prompt_len)

    def admitted(self, rid: int, bucket_len: int) -> None:
        r = self.requests[rid]
        r.admitted = time.perf_counter()
        r.bucket_len = bucket_len

    def prefill_done(self, rid: int, dt: float) -> None:
        self.prefill_s += dt
        self.requests[rid].first_token = time.perf_counter()
        self.requests[rid].n_tokens += 1

    def decode_step_done(self, dt: float, rids: List[int],
                         dropped: Optional[float] = None) -> None:
        self.decode_s += dt
        self.decode_steps += 1
        if dropped is not None:
            self.dropped.append(float(dropped))
        for rid in rids:
            r = self.requests[rid]
            r.step_s.append(dt)
            r.n_tokens += 1

    def finished(self, rid: int) -> None:
        self.requests[rid].finished = time.perf_counter()

    # --------------------------------------------------------------- summary
    def cache_delta(self) -> Dict[str, Dict[str, int]]:
        """Per-cache counter deltas since :meth:`start`."""
        now = _api.cache_stats()
        base = self._cache0 or {}
        out: Dict[str, Dict[str, int]] = {}
        for name, stats in now.items():
            b = base.get(name, {})
            out[name] = {k: stats[k] - b.get(k, 0)
                         for k in ("hits", "misses", "evictions")}
            out[name]["size"] = stats["size"]
        return out

    def summary(self) -> Dict:
        if self._t1 is None:
            self.stop()
        elapsed = (self._t1 or time.perf_counter()) - (self._t0 or 0.0)
        done = [r for r in self.requests.values() if r.finished is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [r.tpot for r in done if r.tpot is not None]
        n_tokens = sum(r.n_tokens for r in self.requests.values())
        caches = self.cache_delta()
        plans = caches.get("plans", {})
        lookups = plans.get("hits", 0) + plans.get("misses", 0)
        hit_rate = (plans.get("hits", 0) / lookups) if lookups else None
        return {
            "requests": len(self.requests),
            "completed": len(done),
            "elapsed_s": elapsed,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "decode_steps": self.decode_steps,
            "tokens": n_tokens,
            "tokens_per_s": n_tokens / elapsed if elapsed > 0 else None,
            "decode_tok_per_s": (
                sum(len(r.step_s) for r in self.requests.values())
                / self.decode_s if self.decode_s > 0 else None),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "tpot_p50_s": percentile(tpots, 50),
            "tpot_p99_s": percentile(tpots, 99),
            "plan_lookups": lookups,
            "plans_per_second": lookups / elapsed if elapsed > 0 else None,
            "plan_cache": plans,
            "plan_cache_hit_rate": hit_rate,
            "caches": caches,
            "dropped_mean": (sum(self.dropped) / len(self.dropped)
                             if self.dropped else 0.0),
            "dropped_max": max(self.dropped) if self.dropped else 0.0,
        }
