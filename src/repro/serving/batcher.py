"""Shape-bucketed request admission for the serving engine.

Prompts arrive with arbitrary lengths; jitting a prefill per exact length
would retrace (and re-plan) per tenant.  The batcher rounds each prompt up
to a small set of padded buckets, so concurrent tenants share a handful of
prefill shapes — and therefore the capacity-bucketed ``plan_matmul`` LRU
caches hit across requests (the serving-layer analogue of
``DistBSR.from_dense(capacity="bucket")``).

Right-padding is exact under causal attention: ``lm.prefill(lengths=...)``
reads logits at the last real token and invalidates pad-written cache
slots.  Two model families opt out of padding:

* recurrent layers ('r' RG-LRU / 'm' Mamba) fold *every* position into
  their state, pad tokens included — padded prefill would corrupt it;
* local-attention ring buffers shorter than the bucket would wrap, letting
  pad slots overwrite real ones before they can be invalidated.

For those, :func:`effective_bucket` degrades to the exact prompt length
(correct, just one trace per distinct length).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Sequence, Tuple

import numpy as np

DEFAULT_BUCKETS: Tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass
class Request:
    """One tenant request: a prompt and a generation budget."""
    rid: int
    tokens: np.ndarray               # int32 [L]
    max_new_tokens: int
    arrival: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


def bucket_for(length: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= length."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]}")


def padding_supported(cfg, bucket: int, max_len: int) -> bool:
    """True if right-padded prefill up to ``bucket`` is exact for ``cfg``."""
    from ..models import attention as attn_mod
    for kind in cfg.pattern:
        if kind not in ("g", "l"):
            return False                 # recurrent state sees pad tokens
        if bucket > attn_mod.cache_len(cfg, kind, max_len):
            return False                 # ring would wrap over pad slots
    return True


def effective_bucket(cfg, length: int, max_len: int,
                     buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Bucketed prefill length, degrading to exact length when padding
    would be unsound for this config (see module docstring)."""
    b = bucket_for(length, buckets)
    if b == length or padding_supported(cfg, b, max_len):
        return b
    return length


def pad_prompt(tokens: np.ndarray, bucket: int) -> np.ndarray:
    """Right-pad a [L] prompt to [bucket] with zeros (masked out later)."""
    out = np.zeros((bucket,), np.int32)
    out[: tokens.shape[0]] = tokens
    return out


class RequestBatcher:
    """FIFO admission queue with arrival times and shape bucketing."""

    def __init__(self, cfg, max_len: int,
                 buckets: Sequence[int] = DEFAULT_BUCKETS):
        self.cfg = cfg
        self.max_len = max_len
        self.buckets = tuple(buckets)
        self._queue: Deque[Request] = collections.deque()
        self._next_rid = 0

    def submit(self, tokens, max_new_tokens: int,
               arrival: float = 0.0, rid: Optional[int] = None) -> Request:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = Request(rid, tokens, max_new_tokens, arrival)
        if req.prompt_len + max_new_tokens > self.max_len:
            raise ValueError(
                f"request {rid}: prompt {req.prompt_len} + gen "
                f"{max_new_tokens} exceeds max_len {self.max_len}")
        self._queue.append(req)
        return req

    def pop(self, now: float) -> Optional[Request]:
        """Next admissible request (FIFO among those already arrived)."""
        if self._queue and self._queue[0].arrival <= now:
            return self._queue.popleft()
        return None

    def next_arrival(self) -> Optional[float]:
        return self._queue[0].arrival if self._queue else None

    def bucket(self, req: Request) -> int:
        return effective_bucket(self.cfg, req.prompt_len, self.max_len,
                                self.buckets)

    def padded(self, req: Request) -> Tuple[np.ndarray, int]:
        """(padded [1, bucket] prompt, real length) for prefill."""
        b = self.bucket(req)
        return pad_prompt(req.tokens, b)[None, :], req.prompt_len

    def __len__(self) -> int:
        return len(self._queue)
