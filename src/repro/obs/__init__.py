"""repro.obs — unified observability: metrics registry, execution tracing,
predicted-vs-measured drift tracking.

Quick tour::

    from repro import obs

    obs.enable()                         # tracing on (off by default)
    with obs.span("plan_build", algorithm="ring_c"):
        ...                              # spans nest, thread-safe
    obs.export_trace("trace.json")       # Chrome-trace JSON for Perfetto

    obs.registry().counter("steal3d.plans_built").inc()
    obs.registry().snapshot()            # plain-dict view of every metric

    obs.drift_report()                   # cost-model calibration per series

Importing this package never imports jax — benches may import it at module
scope before platform flags are set; the timing helpers defer their jax
import to call time.
"""
from .drift import (
    drift_records,
    drift_report,
    export_drift,
    record_drift,
    reset_drift,
)
from .registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
    registry,
)
from .trace import (
    REQUIRED_EVENT_KEYS,
    clear_trace,
    disable,
    enable,
    enabled,
    events,
    export_trace,
    instant,
    span,
    sync_elapsed,
    timed,
    validate_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REQUIRED_EVENT_KEYS",
    "clear_trace",
    "disable",
    "drift_records",
    "drift_report",
    "enable",
    "enabled",
    "events",
    "export_drift",
    "export_trace",
    "instant",
    "percentile",
    "record_drift",
    "registry",
    "reset_all",
    "reset_drift",
    "span",
    "sync_elapsed",
    "timed",
    "validate_trace",
]


def reset_all() -> None:
    """Clear trace buffer, drift series, and zero the default registry."""
    clear_trace()
    reset_drift()
    registry().reset()
