"""Unified metrics registry: counters, gauges, histograms with labeled series.

One process-wide default registry (``registry()``) absorbs what used to be
scattered instrumentation — plan-cache LRU counters, serving TTFT/TPOT,
MoE dropped tokens, steal3d moved-tile bytes.  Independent registries can
be created for windowed measurements (``ServingMetrics`` holds one per run).

Design points:

- Instruments are identified by ``(name, labels)``; asking twice for the
  same series returns the same object, so call sites can be stateless.
- ``snapshot()`` renders everything to plain dicts (JSON-safe); callbacks
  registered with ``register_callback`` are pulled lazily at snapshot time,
  which is how the plan caches expose their counters without the registry
  importing ``core.api``.
- ``reset()`` zeroes counts and clears histogram samples but keeps every
  instrument and callback registered, so long-running processes can window
  rates without re-wiring instrumentation.

Everything is thread-safe under one registry-wide lock; instrument updates
are a few dict/list operations, far off any jax hot path.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile; nan for an empty sample."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    f = (len(s) - 1) * q / 100.0
    lo = int(f)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (f - lo))


class Counter:
    """Monotonic (between resets) numeric total."""

    kind = "counter"

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = dict(labels)
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0.0

    def render(self):
        return self.value


class Gauge:
    """Last-set value (None until first set)."""

    kind = "gauge"

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = dict(labels)
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = None

    def render(self):
        return self.value


class Histogram:
    """Sample list with count/sum/min/max/percentile summaries.

    Samples are kept (bounded) so percentiles are exact over the window;
    ``max_samples`` caps memory for unbounded runs — beyond it the summary
    stats stay exact but percentiles cover the most recent window.
    """

    kind = "histogram"

    def __init__(self, name: str, labels: Dict[str, str], max_samples: int = 65536):
        self.name = name
        self.labels = dict(labels)
        self.max_samples = max_samples
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.samples.append(v)
        if len(self.samples) > self.max_samples:
            del self.samples[: len(self.samples) // 2]

    def reset(self) -> None:
        self.samples = []
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def render(self):
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean(),
            "min": self.vmin if self.count else float("nan"),
            "max": self.vmax if self.count else float("nan"),
            "p50": self.percentile(50.0),
            "p99": self.percentile(99.0),
        }


class MetricsRegistry:
    """A named collection of instruments plus pull-time callbacks."""

    def __init__(self):
        self._lock = threading.RLock()
        self._instruments: Dict[Tuple[str, LabelKey], object] = {}
        self._callbacks: Dict[str, Callable[[], object]] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, labels, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}, "
                    f"requested {cls.kind}"
                )
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def register_callback(self, name: str, fn: Callable[[], object]) -> None:
        """Register a zero-arg callable whose result appears under ``name``
        in snapshots.  Survives ``reset()``; re-registering replaces."""
        with self._lock:
            self._callbacks[name] = fn

    def series(self, name: str) -> List[object]:
        """All instruments registered under ``name`` (one per label set)."""
        with self._lock:
            return [v for (n, _), v in self._instruments.items() if n == name]

    def snapshot(self) -> Dict[str, object]:
        """Render every instrument and callback to a plain, JSON-safe dict.

        Unlabeled instruments render as ``{name: value}``; labeled series as
        ``{name: {"k=v,k2=v2": value, ...}}``.
        """
        out: Dict[str, object] = {}
        with self._lock:
            items = list(self._instruments.items())
            callbacks = list(self._callbacks.items())
        for (name, lkey), inst in items:
            if not lkey:
                out[name] = inst.render()
            else:
                label_str = ",".join(f"{k}={v}" for k, v in lkey)
                out.setdefault(name, {})
                out[name][label_str] = inst.render()  # type: ignore[index]
        for name, fn in callbacks:
            try:
                out[name] = fn()
            except Exception as e:  # pragma: no cover - defensive
                out[name] = f"<callback error: {e}>"
        return out

    def reset(self) -> None:
        """Zero every instrument; registrations and callbacks survive."""
        with self._lock:
            for inst in self._instruments.values():
                inst.reset()


_DEFAULT = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return _DEFAULT
