"""Predicted-vs-measured drift series for the plan cost model.

Every traced ``MatmulPlan.__call__`` records the measured (blocking)
per-multiply seconds next to the plan's ``predicted_perf()`` seconds,
keyed by ``(algorithm, wire, overlap)``.  ``drift_report()`` condenses
each series to a ratio (geometric mean of measured/predicted — the
cost model's systematic bias) and an RMSE (absolute spread).  Records
keep the plan's cost-model dict so ``tools/fit_machine.py`` can re-fit
``Machine`` parameters from the live registry instead of only from
committed bench JSON — the observed-step-time loop the ROADMAP's
elastic-replanning item needs.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Tuple

# Bounded per-series history: drift is a running estimate, not an archive.
_MAX_RECORDS_PER_KEY = 4096

_LOCK = threading.Lock()
_SERIES: Dict[Tuple[str, str, str], List[Dict]] = {}


def record_drift(
    algorithm: str,
    wire: str,
    overlap: str,
    predicted_s: float,
    measured_s: float,
    cm: Optional[Dict] = None,
    **extra,
) -> None:
    """Append one predicted/measured pair to its (algorithm, wire, overlap)
    series.  ``cm`` is the plan's cost-model dict, kept for re-fitting."""
    key = (str(algorithm), str(wire), str(overlap))
    rec = {
        "algorithm": key[0],
        "wire": key[1],
        "overlap": key[2],
        "predicted_s": float(predicted_s),
        "measured_s": float(measured_s),
    }
    if cm is not None:
        rec["cm"] = cm
    rec.update(extra)
    with _LOCK:
        series = _SERIES.setdefault(key, [])
        series.append(rec)
        if len(series) > _MAX_RECORDS_PER_KEY:
            del series[: len(series) // 2]


def drift_records() -> List[Dict]:
    """Flat copy of every record across all series (fit_machine input)."""
    with _LOCK:
        return [dict(r) for series in _SERIES.values() for r in series]


def reset_drift() -> None:
    with _LOCK:
        _SERIES.clear()


def _summarize(series: List[Dict]) -> Dict:
    n = len(series)
    pred = [r["predicted_s"] for r in series]
    meas = [r["measured_s"] for r in series]
    # Geomean of measured/predicted: multiplicative bias, robust to the
    # orders-of-magnitude spread between fake-CPU and modeled-TPU seconds.
    logs = [
        math.log(m / p)
        for m, p in zip(meas, pred)
        if p > 0.0 and m > 0.0 and math.isfinite(m / p)
    ]
    ratio = math.exp(sum(logs) / len(logs)) if logs else float("nan")
    rmse = math.sqrt(sum((m - p) ** 2 for m, p in zip(meas, pred)) / n)
    return {
        "n": n,
        "predicted_mean_s": sum(pred) / n,
        "measured_mean_s": sum(meas) / n,
        "ratio": ratio,
        "rmse_s": rmse,
    }


def drift_report() -> Dict[str, Dict]:
    """Per-series drift summary, keyed ``"algorithm/wire/overlap"``.

    ``ratio`` is geomean(measured/predicted): 1.0 means the cost model is
    calibrated; a drifting ratio is the signal to re-fit the machine.
    """
    with _LOCK:
        items = [(k, list(v)) for k, v in _SERIES.items()]
    return {"/".join(key): _summarize(series) for key, series in items}


def export_drift(path: str) -> Dict:
    """Write all drift records (with cost-model dicts) as JSON for offline
    re-fitting via ``tools/fit_machine.py --drift``."""
    obj = {"records": drift_records(), "report": drift_report()}
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj
