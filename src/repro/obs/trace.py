"""Span-based execution tracing with Chrome-trace (Perfetto) export.

Tracing is off by default.  When off, ``span()`` returns one shared no-op
context manager — no allocation, no clock read — so instrumented hot paths
(plan calls, serving decode steps) pay a single boolean check.  When on,
each span records a Chrome-trace "complete" event (``ph: "X"``) with
microsecond ``ts``/``dur``, the recording thread's id, and any keyword
attributes under ``args``.  Nesting needs no explicit parent plumbing:
Perfetto reconstructs the stack per-thread from interval containment, and
we additionally record the thread-local depth for the textual viewer.

Timing discipline helpers live here too: ``sync_elapsed`` (block until a
jax pytree is ready, then read the clock) and ``timed`` (time a thunk with
a trailing block) — the only sanctioned ways to wall-time jax work, which
``tools/check_api.py`` enforces repo-wide.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional

# Trace-buffer cap: ~100k spans bounds memory for runaway traced loops;
# drops are counted and surfaced in export metadata.
_MAX_EVENTS = 100_000


class _State:
    def __init__(self):
        self.enabled = False
        self.lock = threading.Lock()
        self.events: List[Dict] = []
        self.dropped = 0
        self.t0 = time.perf_counter()


_STATE = _State()
_TLS = threading.local()


class _NullSpan:
    """Shared do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **attrs):
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "args", "_start", "_depth")

    def __init__(self, name: str, args: Dict):
        self.name = name
        self.args = args
        self._start = 0.0
        self._depth = 0

    def note(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. cache hit/miss)."""
        self.args.update(attrs)

    def __enter__(self):
        depth = getattr(_TLS, "depth", 0)
        _TLS.depth = depth + 1
        self._depth = depth
        self._start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter()
        _TLS.depth = self._depth
        ev = {
            "ph": "X",
            "name": self.name,
            "cat": "repro",
            "ts": (self._start - _STATE.t0) * 1e6,
            "dur": (end - self._start) * 1e6,
            "pid": 0,
            "tid": threading.get_ident() % 2**31,
            "args": dict(self.args, depth=self._depth),
        }
        with _STATE.lock:
            if len(_STATE.events) < _MAX_EVENTS:
                _STATE.events.append(ev)
            else:
                _STATE.dropped += 1
        return False


def enable(clear: bool = False) -> None:
    """Turn tracing on; ``clear=True`` also drops buffered events."""
    if clear:
        clear_trace()
    _STATE.enabled = True


def disable() -> None:
    _STATE.enabled = False


def enabled() -> bool:
    return _STATE.enabled


def span(name: str, **attrs):
    """Context manager recording a Chrome-trace span while tracing is on.

    Returns a shared inert object when tracing is off — safe (and ~free)
    to leave on hot paths unconditionally.
    """
    if not _STATE.enabled:
        return _NULL_SPAN
    return _Span(name, attrs)


def instant(name: str, **attrs) -> None:
    """Record a zero-duration marker event (rendered as a span of dur 0)."""
    if not _STATE.enabled:
        return
    now = (time.perf_counter() - _STATE.t0) * 1e6
    ev = {
        "ph": "X",
        "name": name,
        "cat": "repro",
        "ts": now,
        "dur": 0.0,
        "pid": 0,
        "tid": threading.get_ident() % 2**31,
        "args": dict(attrs),
    }
    with _STATE.lock:
        if len(_STATE.events) < _MAX_EVENTS:
            _STATE.events.append(ev)
        else:
            _STATE.dropped += 1


def events() -> List[Dict]:
    """Copy of the buffered events (oldest first)."""
    with _STATE.lock:
        return list(_STATE.events)


def clear_trace() -> None:
    with _STATE.lock:
        _STATE.events = []
        _STATE.dropped = 0


def export_trace(path: Optional[str] = None) -> Dict:
    """Render buffered spans as a Chrome-trace JSON object.

    The result loads directly in Perfetto (ui.perfetto.dev) or
    chrome://tracing.  Every event carries the keys
    ``ph``/``ts``/``dur``/``name``/``pid``/``tid``.  When ``path`` is
    given the object is also written there as JSON.
    """
    with _STATE.lock:
        evs = list(_STATE.events)
        dropped = _STATE.dropped
    obj = {
        "traceEvents": evs,
        "displayTimeUnit": "ms",
        "otherData": {"dropped_events": dropped, "source": "repro.obs"},
    }
    if path is not None:
        with open(path, "w") as f:
            json.dump(obj, f)
    return obj


REQUIRED_EVENT_KEYS = ("ph", "ts", "dur", "name", "pid", "tid")


def validate_trace(obj: Dict) -> List[str]:
    """Return a list of schema problems ([] means valid Chrome trace)."""
    problems: List[str] = []
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(evs):
        for k in REQUIRED_EVENT_KEYS:
            if k not in ev:
                problems.append(f"event {i} missing key {k!r}")
        if "ts" in ev and not isinstance(ev["ts"], (int, float)):
            problems.append(f"event {i} ts not numeric")
        if "dur" in ev and not isinstance(ev["dur"], (int, float)):
            problems.append(f"event {i} dur not numeric")
    return problems


def sync_elapsed(t0: float, tree) -> float:
    """Block until ``tree``'s arrays are ready, return seconds since t0.

    The only honest way to wall-time async-dispatched jax work: without
    the block the stop-clock reads dispatch time, not execution time.
    """
    import jax  # deferred: obs must be importable before platform flags

    jax.block_until_ready(tree)
    return time.perf_counter() - t0


def timed(fn, repeats: int = 1, warmup: int = 0) -> float:
    """Mean wall seconds per call of ``fn()``, blocking on its result.

    Replaces the per-bench ``_timed`` helpers that read ``perf_counter``
    around un-blocked jax calls (the async-dispatch smear).
    """
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(max(1, repeats)):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / max(1, repeats)
