"""Model configuration dataclasses for every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False      # arctic: parallel dense FFN branch
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                # SSD head size P
    chunk: int = 256                  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    # --- attention flavor ---
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    local_window: Optional[int] = None
    # per-layer kinds, cycled/explicit: 'g' global attn, 'l' local attn,
    # 'r' RG-LRU recurrent, 'm' mamba2 SSD.  len divides or equals n_layers.
    layer_pattern: str = "g"
    causal: bool = True               # False => encoder (hubert)
    mlp_kind: str = "swiglu"          # swiglu | geglu | none
    post_norms: bool = False          # gemma2 sandwich norms
    emb_scale: bool = False           # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- families ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    lru_width: Optional[int] = None   # RG-LRU width (defaults d_model)
    # --- modality frontend stubs ---
    frontend: Optional[str] = None    # None | 'audio' | 'vlm'
    frontend_dim: int = 0
    num_patches: int = 0              # vlm: patch embeddings prepended
    # --- numerics / training ---
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # integration of the paper's engine into MoE dispatch
    moe_impl: str = "dense_onehot"    # dense_onehot | ring (see models/moe.py)
    # perf levers (EXPERIMENTS.md §Perf); defaults = optimized configuration
    moe_shard_capacity: bool = True   # shard dispatch capacity over data axes
    moe_dispatch_groups: int = 1      # per-group capacity; set = batch shards

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> str:
        """Explicit per-layer kind string of length n_layers."""
        pat = self.layer_pattern
        if len(pat) >= self.n_layers:
            return pat[: self.n_layers]
        reps = -(-self.n_layers // len(pat))
        return (pat * reps)[: self.n_layers]

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def subquadratic(self) -> bool:
        """True if no layer does full (global) attention — long_500k eligible."""
        return "g" not in self.pattern

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = (self.n_heads * hd + 2 * self.n_kv_heads * hd) * d \
            + self.n_heads * hd * d
        if self.mlp_kind in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        elif self.mlp_kind == "none":
            mlp = 0
        else:
            mlp = 2 * d * self.d_ff
        total = 0
        for kind in self.pattern:
            if kind in ("g", "l"):
                if self.moe:
                    experts = (3 * d * self.moe.d_ff_expert
                               * self.moe.n_experts + d * self.moe.n_experts)
                    total += attn + experts
                    if self.moe.dense_residual:
                        total += mlp
                else:
                    total += attn + mlp
            elif kind == "r":
                w = self.lru_width or d
                # in/out proj + conv + block-diag gates (approx) + MLP
                total += 2 * d * w + w * d + 4 * w + 2 * w * w // 8 + mlp
            elif kind == "m":
                s = self.ssm or SSMConfig()
                di = s.expand * d
                nh = di // s.head_dim
                total += (d * (2 * di + 2 * s.d_state + nh)   # in_proj
                          + (di + 2 * s.d_state) * s.d_conv   # conv1d
                          + di * d                            # out_proj
                          + 2 * nh + di)                      # A, D, norm
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.frontend:
            emb += self.frontend_dim * d
        return total + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        expert = 3 * d * self.moe.d_ff_expert
        inactive = (self.moe.n_experts - self.moe.top_k) * expert
        return full - inactive * self.n_layers
