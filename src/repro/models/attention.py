"""Grouped-query attention with QKV bias, logit softcap, local windows,
encoder (bidirectional) mode, and a ring-buffer KV cache for decode.

Sharding: head dims shard over "model", batch over ("pod", "data").  Local
(sliding-window) layers keep a cache of only ``window`` slots — this is what
makes recurrentgemma's ``long_500k`` decode memory-bounded.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (BATCH_AXES, MODEL_AXIS, apply_rope, constrain,
                     dense_init, rope, softcap)
from .config import ModelConfig

__all__ = ["init_attn", "attn_specs", "attn_forward", "attn_decode",
           "init_attn_cache", "attn_cache_specs"]

NEG_INF = -2.3819763e38  # large negative for masking (bf16-safe)


def init_attn(cfg: ModelConfig, key) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    keys = jax.random.split(key, 4)
    p = {
        "wq": dense_init(keys[0], (d, h * hd)),
        "wk": dense_init(keys[1], (d, k * hd)),
        "wv": dense_init(keys[2], (d, k * hd)),
        "wo": dense_init(keys[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,))
        p["bk"] = jnp.zeros((k * hd,))
        p["bv"] = jnp.zeros((k * hd,))
    return p


def attn_specs(cfg: ModelConfig) -> Dict:
    """FSDP (over 'data') x TP (over 'model') parameter shardings."""
    p = {
        "wq": P("data", MODEL_AXIS),
        "wk": P("data", MODEL_AXIS),
        "wv": P("data", MODEL_AXIS),
        "wo": P(MODEL_AXIS, "data"),
    }
    if cfg.qkv_bias:
        p["bq"] = P(MODEL_AXIS)
        p["bk"] = P(MODEL_AXIS)
        p["bv"] = P(MODEL_AXIS)
    return p


def _project_qkv(p: Dict, x, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dh->bth", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dh->bth", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dh->bth", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    b, t = x.shape[:2]
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    q = constrain(q, BATCH_AXES, None, MODEL_AXIS, None)
    k = constrain(k, BATCH_AXES, None, MODEL_AXIS, None)
    v = constrain(v, BATCH_AXES, None, MODEL_AXIS, None)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: [B,T,H,hd]; k,v: [B,S,K,hd]; mask: [B?,T,S] bool (True=attend)."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    q = q.reshape(b, t, kh, g, hd)
    logits = jnp.einsum("btkgd,bskd->bkgts", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, h, hd)


def _pair_mask(cfg: ModelConfig, kind: str, pos_q, pos_k):
    """bool[Tq, Tk] attend mask from absolute positions."""
    i = pos_q[:, None]
    j = pos_k[None, :]
    if cfg.causal:
        m = j <= i
    else:
        m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if kind == "l" and cfg.local_window:
        m = m & (i - j < cfg.local_window)
    return m


# Sequences longer than this use the kv-chunked online-softmax path, which
# never materializes the [T, S] score matrix (memory-roofline lever; the
# full Pallas flash kernel is follow-up work — see DESIGN.md).
BLOCKED_ATTN_THRESHOLD = 8192
KV_CHUNK = 1024


def _sdpa_blocked(q, k, v, cfg: ModelConfig, kind: str, pos_q, pos_k,
                  kv_chunk: int = KV_CHUNK):
    """Online-softmax attention, scanned over KV chunks.

    q: [B,T,H,hd]; k,v: [B,S,K,hd].  Score working set is [B,heads,T,chunk].
    """
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    pad = (-s) % kv_chunk
    if pad:  # ragged tail: pad with masked-out slots, never shrink the chunk
        k = jnp.concatenate([k, jnp.zeros((b, pad, kh, hd), k.dtype)], 1)
        v = jnp.concatenate([v, jnp.zeros((b, pad, kh, hd), v.dtype)], 1)
        pos_k = jnp.concatenate(
            [pos_k, jnp.full((pad,), jnp.iinfo(jnp.int32).max, jnp.int32)])
        s += pad
    nk = s // kv_chunk
    qr = q.reshape(b, t, kh, g, hd).astype(jnp.float32) * (hd ** -0.5)
    kr = k.reshape(b, nk, kv_chunk, kh, hd).swapaxes(0, 1)
    vr = v.reshape(b, nk, kv_chunk, kh, hd).swapaxes(0, 1)
    pk = pos_k.reshape(nk, kv_chunk)

    def step(carry, xs):
        m, l, acc = carry
        k_c, v_c, pk_c = xs
        sc = jnp.einsum("btkgd,bskd->bkgts", qr, k_c.astype(jnp.float32))
        sc = softcap(sc, cfg.attn_softcap)
        mask = _pair_mask(cfg, kind, pos_q, pk_c)
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgts,bskd->bkgtd", p, v_c.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (
        jnp.full((b, kh, g, t), NEG_INF, jnp.float32),
        jnp.zeros((b, kh, g, t), jnp.float32),
        jnp.zeros((b, kh, g, t, hd), jnp.float32),
    )
    step = jax.checkpoint(step)   # flash-style: recompute chunks in bwd
    (m, l, acc), _ = jax.lax.scan(step, init, (kr, vr, pk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # [b,kh,g,t,hd] -> [b,t,h,hd]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, hd).astype(q.dtype)


def attn_forward(p: Dict, x, cfg: ModelConfig, kind: str, positions,
                 cache: Optional[Dict] = None,
                 cache_offset: Optional[jnp.ndarray] = None):
    """Full-sequence attention (train / prefill).

    If ``cache`` is given (prefill), k/v are written into it and the updated
    cache is returned alongside the output.
    """
    b, t, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    sin, cos = rope(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    if t > BLOCKED_ATTN_THRESHOLD:
        out = _sdpa_blocked(q, k, v, cfg, kind, positions, positions)
    else:
        mask = _pair_mask(cfg, kind, positions, positions)[None]
        out = _sdpa(q, k, v, mask, cfg)
    out = jnp.einsum("bte,ed->btd", out.reshape(b, t, -1),
                     p["wo"].astype(x.dtype))
    out = constrain(out, BATCH_AXES, None, None)
    if cache is None:
        return out, None
    new_cache = _write_prefill(cache, k, v, positions, cfg, kind)
    return out, new_cache


# ---------------------------------------------------------------------------
# KV cache (ring buffer for local layers)
# ---------------------------------------------------------------------------
def cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if kind == "l" and cfg.local_window:
        return min(cfg.local_window, max_len)
    return max_len


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Dict:
    s = cache_len(cfg, kind, max_len)
    kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s, kh, hd), dtype),
        "v": jnp.zeros((batch, s, kh, hd), dtype),
        # global position per slot, per request: rows advance independently
        # under continuous batching (see repro.serving), -1 = never written
        "pos": jnp.full((batch, s), -1, jnp.int32),
    }


def attn_cache_specs(cfg: ModelConfig, kind: str) -> Dict:
    """KV cache sharding.

    Prefer head-sharding over the model axis (classic TP serving).  When the
    kv-head count can't cover the 16-way production model axis (GQA with
    kv=8/2/1), shard the *sequence* dim over 'model' instead — context
    parallelism; GSPMD inserts the softmax all-reduces.  This is what keeps
    a 32k x batch-128 cache inside HBM on the assigned mesh.
    """
    if cfg.n_kv_heads % 16 == 0:
        kv_spec = P(BATCH_AXES, None, MODEL_AXIS, None)
    else:
        kv_spec = P(BATCH_AXES, MODEL_AXIS, None, None)
    return {
        "k": kv_spec,
        "v": kv_spec,
        "pos": P(BATCH_AXES, None),
    }


def _write_prefill(cache: Dict, k, v, positions, cfg: ModelConfig, kind: str):
    """Write a full prefill's k/v into the (possibly ring) cache.

    Only the trailing ``cache_len`` positions are written (earlier ones would
    be overwritten in the ring anyway), which keeps slot indices unique.
    """
    s = cache["k"].shape[1]
    t = k.shape[1]
    keep = min(t, s)
    k_tail = k[:, t - keep:].astype(cache["k"].dtype)
    v_tail = v[:, t - keep:].astype(cache["v"].dtype)
    pos_tail = positions[t - keep:]
    slots = pos_tail % s
    new_k = cache["k"].at[:, slots].set(k_tail)
    new_v = cache["v"].at[:, slots].set(v_tail)
    new_pos = cache["pos"].at[:, slots].set(pos_tail[None, :])
    return {"k": new_k, "v": new_v, "pos": new_pos}


def attn_decode(p: Dict, x, cache: Dict, pos, cfg: ModelConfig, kind: str
                ) -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode step.  x: [B, 1, d].

    ``pos`` is a scalar int32 (all rows at the same position — the classic
    batch-decode path) or an int32 ``[B]`` vector of per-request positions,
    which is what lets continuous batching mix requests at different depths
    in one decode batch.  A scalar is broadcast; both paths share the code
    below.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    pos_b = jnp.asarray(pos, jnp.int32)
    if pos_b.ndim == 0:
        pos_b = jnp.broadcast_to(pos_b, (b,))
    sin, cos = rope(pos_b[:, None], cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    s = cache["k"].shape[1]
    slot = pos_b % s
    bidx = jnp.arange(b)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    new_v = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    new_pos = cache["pos"].at[bidx, slot].set(pos_b)
    # attend over valid slots: written, <= pos, and within window if local —
    # all per request, since each row carries its own position
    ok = (new_pos >= 0) & (new_pos <= pos_b[:, None])
    if kind == "l" and cfg.local_window:
        ok = ok & (pos_b[:, None] - new_pos < cfg.local_window)
    mask = ok[:, None, :]
    out = _sdpa(q, new_k.astype(x.dtype), new_v.astype(x.dtype), mask, cfg)
    out = jnp.einsum("bte,ed->btd", out.reshape(b, 1, -1),
                     p["wo"].astype(x.dtype))
    return out, {"k": new_k, "v": new_v, "pos": new_pos}
