"""Modality frontend STUBS (per assignment: ``input_specs()`` provides
precomputed frame/patch embeddings; the conv/ViT towers are out of scope).

* audio  — HuBERT-style: precomputed conv-feature frames [B, T, frontend_dim]
           projected + layer-normed into the encoder width.
* vlm    — LLaVA-NeXT-style: anyres patch embeddings [B, num_patches,
           frontend_dim] through the standard 2-layer MLP projector, then
           prepended to the token embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import MODEL_AXIS, dense_init, layer_norm
from .config import ModelConfig

__all__ = ["init_frontend", "frontend_specs", "audio_embed", "vlm_embed"]


def init_frontend(cfg: ModelConfig, key) -> Dict:
    if cfg.frontend == "audio":
        return {
            "proj": dense_init(key, (cfg.frontend_dim, cfg.d_model)),
            "ln_scale": jnp.ones((cfg.d_model,)),
            "ln_bias": jnp.zeros((cfg.d_model,)),
        }
    if cfg.frontend == "vlm":
        k1, k2 = jax.random.split(key)
        return {
            "proj1": dense_init(k1, (cfg.frontend_dim, cfg.d_model)),
            "proj2": dense_init(k2, (cfg.d_model, cfg.d_model)),
        }
    return {}


def frontend_specs(cfg: ModelConfig) -> Dict:
    if cfg.frontend == "audio":
        return {"proj": P(None, MODEL_AXIS), "ln_scale": P(None),
                "ln_bias": P(None)}
    if cfg.frontend == "vlm":
        return {"proj1": P(None, MODEL_AXIS), "proj2": P(MODEL_AXIS, None)}
    return {}


def audio_embed(p: Dict, frames, cfg: ModelConfig):
    x = jnp.einsum("btf,fd->btd", frames, p["proj"].astype(frames.dtype))
    return layer_norm(x, p["ln_scale"], p["ln_bias"])


def vlm_embed(p: Dict, patches, cfg: ModelConfig):
    h = jnp.einsum("bpf,fd->bpd", patches, p["proj1"].astype(patches.dtype))
    h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("bpd,de->bpe", h, p["proj2"].astype(patches.dtype))
