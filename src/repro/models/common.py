"""Shared model building blocks: norms, RoPE, init, softcap, sharding."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "dense_init", "rms_norm", "layer_norm", "rope", "apply_rope", "softcap",
    "constrain", "BATCH_AXES", "MODEL_AXIS",
]

# Logical axis conventions (see launch/mesh.py): batch-like dims shard over
# ("pod", "data"); hidden/head/expert dims shard over "model".
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """LeCun-normal (fan-in) init, the usual transformer default."""
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, dtype) * (fan_in ** -0.5)


def rms_norm(x, scale, eps: float = 1e-6, zero_centered: bool = True):
    """RMSNorm; ``zero_centered`` follows gemma's (1 + scale) convention.

    The reduction runs in f32, but for bf16 inputs the normalize/scale
    multiplies stay in bf16 (normalizer rounded): upcasting the whole
    residual tensor to f32 doubled backward HBM traffic through every norm
    fusion chain (EXPERIMENTS.md §Perf olmoe iteration 5).
    """
    w = (1.0 + scale) if zero_centered else scale
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    if x.dtype == jnp.bfloat16:
        return x * r.astype(x.dtype) * w.astype(x.dtype)
    return (x.astype(jnp.float32) * r
            * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale + bias).astype(dt)


def rope(positions, head_dim: int, theta: float = 10000.0):
    """Rotary position embedding tables.

    positions: i32[...]; returns (sin, cos) of shape [..., head_dim//2].
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: [..., T, n_heads, head_dim]; sin/cos: [..., T, head_dim//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin_ = sin[..., None, :]     # broadcast over heads
    cos_ = cos[..., None, :]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_,
                           x2 * cos_ + x1 * sin_], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def constrain(x, *spec):
    """Apply a sharding constraint if we're under a mesh; no-op otherwise.

    Axes the mesh doesn't have, and axes whose size does not divide the
    corresponding array dimension (e.g. 8 kv heads on a 16-way model axis),
    are dropped — the constraint degrades gracefully across mesh shapes.
    """
    try:
        from ..compat import get_abstract_mesh
        mesh = get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        sizes = dict(mesh.shape)
    except Exception:
        return x

    def keep(axis, dim):
        return axis in sizes and dim % sizes[axis] == 0

    fixed = []
    for i, s in enumerate(spec):
        dim = x.shape[i] if i < x.ndim else 1
        if s is None:
            fixed.append(None)
        elif isinstance(s, tuple):
            pick, prod = [], 1
            for a in s:
                if a in sizes and dim % (prod * sizes[a]) == 0:
                    pick.append(a)
                    prod *= sizes[a]
            fixed.append(tuple(pick) if pick else None)
        else:
            fixed.append(s if keep(s, dim) else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))
