"""Token-choice Mixture-of-Experts — where the paper's SpMM engine lives in
the LM stack.

Token-choice routing *is* sparse x dense matmul: the dispatch operator D is
a {0,1}-sparse (tokens x expert-slots) matrix and dispatch/combine are
``D @ X`` / ``(D * probs)^T @ Y`` — the paper's SpMM with a stationary-A
(expert-stationary) distribution: expert weights stay put on their shard of
the "model" axis while activation tiles move.

Dispatch implementation: capacity-padded batched scatter/gather; under
GSPMD with experts sharded over "model" and per-group (per-device) capacity
this lowers to the classic all-to-all pattern.  The §Perf study
(EXPERIMENTS.md, olmoe iterations 1-4) documents how the program structure
(vmapped batched scatter) is what lets the partitioner prove shard
alignment and avoid a whole-buffer all-reduce.

``cfg.moe_impl='ring'`` selects :func:`ring_moe_forward` — the paper's
stationary-A ring of ``core/spmm.py`` applied on the expert axis: tokens
ride ``ppermute`` hops instead of one all-to-all.  Measured on
olmoe train_4k it cuts the collective roofline term 3.6x (6.47->1.81 s)
at the cost of the memory term (16 rounds of local dispatch) — the same
async-vs-collective trade the paper studies; see EXPERIMENTS.md §Perf.

The LPT capacity logic in ``core/schedule.py`` motivates the default
capacity factor; dropped-token stats are returned for monitoring.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import get_abstract_mesh, make_mesh, pvary, set_mesh, shard_map
from .common import BATCH_AXES, MODEL_AXIS, constrain, dense_init
from .config import ModelConfig

__all__ = ["init_moe", "moe_specs", "moe_forward", "route_tokens",
           "route_meta", "expert_ffn", "router_aux", "selftest_distributed"]


def init_moe(cfg: ModelConfig, key) -> Dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    keys = jax.random.split(key, 4)
    p = {
        "router": dense_init(keys[0], (d, e)),
        "w_gate": dense_init(keys[1], (e, d, f), in_axis=1),
        "w_up": dense_init(keys[2], (e, d, f), in_axis=1),
        "w_down": dense_init(keys[3], (e, f, d), in_axis=1),
    }
    return p


def moe_specs(cfg: ModelConfig) -> Dict:
    return {
        "router": P(None, None),
        "w_gate": P(MODEL_AXIS, "data", None),
        "w_up": P(MODEL_AXIS, "data", None),
        "w_down": P(MODEL_AXIS, None, "data"),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(c, m.top_k)


def route_meta(n_tokens: int, cfg: ModelConfig) -> Tuple[int, int, int]:
    """Static routing geometry ``(cap, G, ng)`` as plain python ints.

    A pure function of the (padded) token count and config.  Host-side
    dispatch planning (the serving engine's sparse operator builder) and
    the traced router share this one implementation, so the ints never
    enter a jit trace and both paths agree on capacity by construction.
    """
    m = cfg.moe
    G = max(1, cfg.moe_dispatch_groups)
    while n_tokens % G:
        G //= 2
    ng = n_tokens // G
    cap = max(_capacity(n_tokens, cfg) // G, m.top_k)
    return cap, G, ng


def route_tokens(router, xf, cfg: ModelConfig) -> Dict:
    """Shared router math: softmax -> top-k -> per-group capacity slots.

    ``xf``: [n, d] flat tokens.  Returns a dict of routing tensors; both
    :func:`moe_forward` and the serving engine's plan-based dispatch
    (``repro.serving``) call this, so the two paths route identically and
    the SpMM formulation can be checked token-for-token against the dense
    scatter/gather reference.
    """
    m = cfg.moe
    n = xf.shape[0]
    e, k = m.n_experts, m.top_k
    cap, G, ng = route_meta(n, cfg)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # [n, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # per-group capacity assignment (slot = rank within group+expert)
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)        # [n, k, e]
    flat = onehot.reshape(G, ng * k, e)
    ranks = (jnp.cumsum(flat, axis=1) - flat)                 # excl, per group
    slot = jnp.einsum("gne,gne->gn", ranks, flat).reshape(n, k)
    keep = slot < cap
    return {"logits": logits, "probs": probs, "top_p": top_p,
            "top_e": top_e, "slot": slot, "keep": keep, "onehot": onehot,
            "cap": cap, "G": G, "ng": ng,
            "dropped": 1.0 - keep.mean()}


def expert_ffn(p: Dict, xe, cfg: ModelConfig):
    """Expert MLPs on dispatched slots.  xe: [..., e, cap, d] -> same."""
    act = jax.nn.silu if cfg.mlp_kind != "geglu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    g = jnp.einsum("...ecd,edf->...ecf", xe, p["w_gate"].astype(xe.dtype))
    u = jnp.einsum("...ecd,edf->...ecf", xe, p["w_up"].astype(xe.dtype))
    return jnp.einsum("...ecf,efd->...ecd", act(g) * u,
                      p["w_down"].astype(xe.dtype))


def router_aux(route: Dict, cfg: ModelConfig) -> Dict:
    """Switch-style aux losses + drop stats from :func:`route_tokens`."""
    m = cfg.moe
    me = route["probs"].mean(0)                               # [e]
    ce = route["onehot"].astype(jnp.float32).sum(1).mean(0)   # fraction routed
    return {
        "moe_aux": m.aux_loss * m.n_experts * jnp.sum(me * ce),
        "moe_z": m.router_z_loss * jnp.mean(
            jnp.square(jax.nn.logsumexp(route["logits"], axis=-1))),
        "moe_dropped": route["dropped"],
    }


def moe_forward(p: Dict, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, T, d] -> (y, aux) with load-balance/z losses in aux.

    Dispatch uses *per-group capacity*: tokens are split into G groups
    (G = number of batch shards at scale; 1 on a single device), each group
    ranks its own tokens and scatters into its own capacity slice.  The
    scatter then never crosses batch shards, so under GSPMD the dispatch
    lowers to an all-to-all over the expert axis instead of an all-reduce of
    the whole buffer (§Perf olmoe iterations 1-2: collective 138s -> ~0.3s).
    This is also the production-realistic semantics (per-device capacity).
    """
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    e, k = m.n_experts, m.top_k
    xf = x.reshape(n, d)

    r = route_tokens(p["router"], xf, cfg)
    top_p, top_e = r["top_p"], r["top_e"]
    slot, keep, cap, G, ng = r["slot"], r["keep"], r["cap"], r["G"], r["ng"]

    # --- dispatch: batched (per-group) scatter — the sparse D applied -------
    idx_e = jnp.where(keep, top_e, e).reshape(G, ng * k)
    idx_c = jnp.where(keep, slot, 0).reshape(G, ng * k)
    x_rep = jnp.repeat(xf[:, None, :], k, axis=1).reshape(G, ng * k, d)
    cap_axes = BATCH_AXES if cfg.moe_shard_capacity else None
    x_rep = constrain(x_rep, cap_axes, None, None)

    def _scatter_one(xg, ie, ic):
        return jnp.zeros((e + 1, cap, d), x.dtype).at[ie, ic].add(xg)

    buf = jax.vmap(_scatter_one)(x_rep, idx_e, idx_c)   # [G, e+1, cap, d]
    xe = buf[:, :e]                                     # [G, e, cap, d]
    xe = constrain(xe, cap_axes, MODEL_AXIS, None, None)

    # --- expert FFN (stationary-A: weights never move) ----------------------
    ye = expert_ffn(p, xe, cfg)
    ye = constrain(ye, cap_axes, MODEL_AXIS, None, None)

    # --- combine: (D * probs)^T @ Y — batched gather ------------------------
    ye_pad = jnp.concatenate(
        [ye, jnp.zeros((G, 1, cap, d), ye.dtype)], axis=1)

    def _gather_one(yg, ie, ic):
        return yg[ie, ic]                               # [ng*k, d]

    gathered = jax.vmap(_gather_one)(ye_pad, idx_e, idx_c)
    gathered = constrain(gathered, cap_axes, None, None)
    w = jnp.where(keep, top_p, 0.0).astype(x.dtype)
    y = jnp.einsum("nkd,nk->nd", gathered.reshape(n, k, d), w).reshape(b, t, d)
    y = constrain(y, BATCH_AXES, None, None)

    # --- aux losses (Switch-style) ------------------------------------------
    return y, router_aux(r, cfg)


# ---------------------------------------------------------------------------
# Distributed equivalence check (called from launch/selftest.py)
# ---------------------------------------------------------------------------
def selftest_distributed(n_devices: int) -> bool:
    """EP-sharded MoE == single-device MoE, on a host-device mesh."""
    import numpy as np
    from jax.sharding import NamedSharding

    from .config import MoEConfig

    cfg = ModelConfig(
        name="moe-selftest", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=32, vocab_size=64, compute_dtype="float32",
        moe=MoEConfig(n_experts=n_devices * 2, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = init_moe(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))

    y_ref, _ = moe_forward(p, x, cfg)

    mesh = make_mesh((1, n_devices), ("data", MODEL_AXIS))
    specs = moe_specs(cfg)
    # EP-only for the test: experts over the model axis, rest replicated
    specs = {k: P(MODEL_AXIS, None, None) if k != "router" else P(None, None)
             for k in specs}
    with set_mesh(mesh):
        p_sh = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                for k, v in p.items()}
        x_sh = jax.device_put(x, NamedSharding(mesh, P(None, None, None)))
        y_ep, _ = jax.jit(lambda pp, xx: moe_forward(pp, xx, cfg))(p_sh, x_sh)
    err = float(np.max(np.abs(np.asarray(y_ref) - np.asarray(y_ep))))
    return err < 1e-4


# ---------------------------------------------------------------------------
# Ring dispatch — the paper's stationary-A ring applied to the expert axis
# ---------------------------------------------------------------------------
def ring_moe_forward(p: Dict, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """MoE with the paper's RDMA stationary-A schedule (``moe_impl='ring'``).

    Experts stay put on their 'model'-axis shard (stationary A); token
    shards ride ``ppermute`` hops around the expert ring, each rank applies
    its local experts to every passing shard, and the partial outputs ride
    along with the tokens — after R hops everything is home and fully
    accumulated.  Communication is 2·d per token per hop, nearest-neighbour
    only (vs. the all-to-all of the default dispatch: ~2·k·d per token but
    through the switch fabric) — exactly the trade the paper studies.

    Requires an ambient mesh with a 'model' axis whose size divides
    n_experts, and T divisible by that size; falls back to
    :func:`moe_forward` otherwise (e.g. single-device smoke tests).
    """
    mesh = get_abstract_mesh()
    m = cfg.moe
    b, t, d = x.shape
    if (mesh is None or mesh.empty or MODEL_AXIS not in mesh.axis_names):
        return moe_forward(p, x, cfg)
    R = mesh.shape[MODEL_AXIS]
    if R < 2 or m.n_experts % R or t % R:
        return moe_forward(p, x, cfg)
    el = m.n_experts // R
    batch_axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    all_axes = batch_axes + (MODEL_AXIS,)
    nl = b * (t // R) // max(
        1, _axes_size(mesh, batch_axes))  # tokens per device (for capacity)
    cap = max(int(m.capacity_factor * nl * m.top_k * el / m.n_experts),
              m.top_k)

    def body(xs, router, wg, wu, wd):
        # xs: [B_l, T/R, d] local token shard; w*: [el, d, f] local experts
        bl, tl, _ = xs.shape
        n_loc = bl * tl
        xf = xs.reshape(n_loc, d)
        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, m.top_k)
        top_p = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
                 ).astype(xs.dtype)
        r = jax.lax.axis_index(MODEL_AXIS)
        perm = [((i + 1) % R, i) for i in range(R)]

        def step(carry, _):
            xc, te, tp, acc = carry
            # prefetch the next shard (paper SS3.3: overlap with compute)
            nxt = [jax.lax.ppermute(v, MODEL_AXIS, perm)
                   for v in (xc, te, tp, acc)]
            # tokens of this shard routed to MY experts
            mine = (te // el) == r
            le = jnp.where(mine, te - r * el, el)      # el = overflow slot
            onehot = jax.nn.one_hot(le, el + 1, dtype=jnp.int32)
            flat = onehot.reshape(n_loc * m.top_k, el + 1)
            ranks_ = jnp.cumsum(flat, axis=0) - flat
            slot = jnp.einsum("ne,ne->n", ranks_, flat).reshape(
                n_loc, m.top_k)
            keep = mine & (slot < cap)
            ie = jnp.where(keep, le, el)
            ic = jnp.where(keep, slot, 0)
            buf = jnp.zeros((el + 1, cap, d), xs.dtype)
            buf = buf.at[ie.reshape(-1), ic.reshape(-1)].add(
                jnp.repeat(xc[:, None, :], m.top_k, 1).reshape(-1, d))
            act = jax.nn.silu if cfg.mlp_kind != "geglu" else (
                lambda v: jax.nn.gelu(v, approximate=True))
            g = jnp.einsum("ecd,edf->ecf", buf[:el], wg.astype(xs.dtype))
            u = jnp.einsum("ecd,edf->ecf", buf[:el], wu.astype(xs.dtype))
            ye = jnp.einsum("ecf,efd->ecd", act(g) * u, wd.astype(xs.dtype))
            ye = jnp.concatenate([ye, jnp.zeros((1, cap, d), ye.dtype)])
            part = jnp.einsum("nkd,nk->nd", ye[ie, ic],
                              jnp.where(keep, tp, 0.0))
            acc_out = acc + part
            nxt[3] = jax.lax.ppermute(          # pass the updated partials
                acc_out, MODEL_AXIS, perm)
            return tuple(nxt), None

        acc0 = pvary(jnp.zeros((n_loc, d), xs.dtype), all_axes)
        (xc, te, tp, acc), _ = jax.lax.scan(
            step, (xf, top_e, top_p, acc0), None, length=R)
        # aux losses, reduced over the whole mesh
        me = jax.lax.pmean(probs.mean(0), all_axes)
        ce = jax.lax.pmean(
            jax.nn.one_hot(top_e, m.n_experts).sum(1).mean(0), all_axes)
        aux_vec = jnp.stack([
            m.aux_loss * m.n_experts * jnp.sum(me * ce),
            m.router_z_loss * jax.lax.pmean(jnp.mean(
                jnp.square(jax.nn.logsumexp(logits, -1))), all_axes),
        ])
        return acc.reshape(bl, tl, d), aux_vec

    from jax.sharding import PartitionSpec as _P
    f = shard_map(
        body, mesh=mesh,
        in_specs=(_P(batch_axes or None, MODEL_AXIS, None),
                  _P(None, None),
                  _P(MODEL_AXIS, None, None), _P(MODEL_AXIS, None, None),
                  _P(MODEL_AXIS, None, None)),
        out_specs=(_P(batch_axes or None, MODEL_AXIS, None), _P(None)),
        check_vma=False)
    y, aux_vec = f(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    aux = {"moe_aux": aux_vec[0], "moe_z": aux_vec[1],
           "moe_dropped": jnp.zeros((), jnp.float32)}
    return y, aux


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def selftest_ring(n_devices: int) -> bool:
    """ring dispatch == dense_onehot dispatch (no drops), on an EP mesh."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .config import MoEConfig

    cfg = ModelConfig(
        name="moe-ring-selftest", family="moe", n_layers=1, d_model=16,
        n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=64,
        compute_dtype="float32",
        moe=MoEConfig(n_experts=n_devices * 2, top_k=2, d_ff_expert=32,
                      capacity_factor=16.0))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, n_devices * 4, 16))
    y_ref, _ = moe_forward(p, x, cfg)

    mesh = make_mesh((1, n_devices), ("data", MODEL_AXIS))
    with set_mesh(mesh):
        p_sh = {k: jax.device_put(
            v, NamedSharding(mesh, P(MODEL_AXIS, None, None)
                             if k != "router" else P(None, None)))
            for k, v in p.items()}
        y_ring, _ = jax.jit(
            lambda pp, xx: ring_moe_forward(pp, xx, cfg))(p_sh, x)
    err = float(np.max(np.abs(np.asarray(y_ref) - np.asarray(y_ring))))
    return err < 1e-4
