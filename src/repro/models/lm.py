"""Losses and step functions (train / prefill / decode) for all families."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import transformer as tf
from .config import ModelConfig

__all__ = ["loss_fn", "make_train_step", "prefill", "make_decode_step"]


def cross_entropy(logits, labels, ignore: int = -1):
    """Mean CE over non-ignored positions.  logits: [B,T,V] f32."""
    mask = (labels != ignore)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom


def _shift_batch(batch: Dict, cfg: ModelConfig) -> Tuple[Dict, jnp.ndarray]:
    """Produce (model inputs, labels) from a raw batch."""
    if cfg.frontend == "audio":
        # encoder: frame-level unit prediction, no shift
        return {"frames": batch["frames"]}, batch["labels"]
    if cfg.frontend == "vlm":
        toks = batch["tokens"]
        inputs = {"tokens": toks[:, :-1], "patches": batch["patches"]}
        npatch = batch["patches"].shape[1]
        ignore = jnp.full((toks.shape[0], npatch), -1, toks.dtype)
        labels = jnp.concatenate([ignore, toks[:, 1:]], axis=1)
        return inputs, labels
    toks = batch["tokens"]
    return {"tokens": toks[:, :-1]}, toks[:, 1:]


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig):
    inputs, labels = _shift_batch(batch, cfg)
    logits, _, aux = tf.forward(params, inputs, cfg)
    loss = cross_entropy(logits, labels)
    total = loss + aux["aux"]
    metrics = {"loss": loss, "aux": aux["aux"], "dropped": aux["dropped"]}
    return total, metrics


def make_train_step(cfg: ModelConfig, optimizer):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  The optimizer is a repro.optim object (init/update)."""

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        metrics["grad_norm"] = optimizer.last_grad_norm(opt_state)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def _mask_pad_slots(caches, lengths):
    """Invalidate KV-cache slots written by right-padding tokens.

    Attention caches carry per-request slot positions (``pos: [units, B,
    s]``); slots at or beyond a request's real length are marked -1 so
    decode masks them out.  Requires no ring wrap over the padded span
    (``padded len <= cache_len``) — the batcher guarantees this.
    Recurrent-state caches (no ``pos`` key) pass through untouched.
    """
    ln = lengths[None, :, None]

    def fix(c):
        if isinstance(c, dict) and "pos" in c and "k" in c:
            pos = c["pos"]
            return {**c, "pos": jnp.where(pos < ln, pos, -1)}
        return c

    return [[fix(c) for c in group] for group in caches]


def prefill(params: Dict, batch: Dict, cfg: ModelConfig, max_len: int,
            cache_dtype=jnp.bfloat16, lengths=None):
    """Run the prompt through the model, filling a fresh decode cache.

    Returns (last_token_logits [B, V], caches, next_pos).

    ``lengths`` (int32 [B], optional) marks right-padded prompts: logits
    are read at each request's last *real* token, pad-written cache slots
    are invalidated, and ``next_pos`` comes back as the per-request vector
    ``lengths`` instead of a shared scalar.  Under causal attention a
    right-padded prefill is then exactly the unpadded one — this is what
    lets the serving batcher bucket prompt shapes (shared jit traces,
    shared matmul plans) without perturbing outputs.
    """
    if cfg.is_encoder:
        raise ValueError("encoder models have no decode path")
    bsz = (batch["tokens"].shape[0] if "tokens" in batch
           else batch["frames"].shape[0])
    caches = tf.init_cache(cfg, bsz, max_len, cache_dtype)
    logits, caches, _ = tf.forward(params, batch, cfg, caches=caches)
    t = logits.shape[1]
    if lengths is None:
        return logits[:, -1], caches, jnp.asarray(t, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
    return last, _mask_pad_slots(caches, lengths), lengths


def make_decode_step(cfg: ModelConfig, with_aux: bool = False):
    """Returns decode_step(params, token [B,1], caches, pos) ->
    (logits [B,V], new_caches).  ``pos`` may be a scalar or a [B] vector of
    per-request positions.  With ``with_aux`` the step also returns the
    summed layer aux dict (MoE dropped-token stats for the metrics layer).
    """

    def decode_step(params, token, caches, pos):
        logits, new_caches = tf.decode_step(params, token, caches, pos, cfg)
        return logits[:, 0], new_caches

    def decode_step_aux(params, token, caches, pos):
        logits, new_caches, aux = tf.decode_step(params, token, caches, pos,
                                                 cfg, return_aux=True)
        return logits[:, 0], new_caches, aux

    return decode_step_aux if with_aux else decode_step


def greedy_decode(params: Dict, batch: Dict, cfg: ModelConfig, steps: int,
                  max_len: int, cache_dtype=jnp.float32):
    """Prefill + N greedy steps (reference path for tests/examples)."""
    logits, caches, pos = prefill(params, batch, cfg, max_len, cache_dtype)
    step = make_decode_step(cfg)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, caches = step(params, tok, caches, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
