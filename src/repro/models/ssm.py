"""Mamba-2 SSD (state-space duality) mixer.

Chunked SSD algorithm (Dao & Gu 2024): within a chunk of length L the
recurrence is computed as a masked quadratic form (attention-like, MXU
friendly); across chunks a small state S [H, P, N] is carried by a scan.
Decode is the plain single-step recurrence.  n_groups = 1.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, MODEL_AXIS, constrain, dense_init, rms_norm
from .config import ModelConfig, SSMConfig

__all__ = ["init_mamba", "mamba_specs", "mamba_forward", "mamba_decode",
           "init_mamba_cache", "mamba_cache_specs"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return s, di, nh


def init_mamba(cfg: ModelConfig, key) -> Dict:
    s, di, nh = _dims(cfg)
    d = cfg.d_model
    conv_ch = di + 2 * s.d_state
    keys = jax.random.split(key, 3)
    return {
        "in_proj": dense_init(keys[0], (d, 2 * di + 2 * s.d_state + nh)),
        "conv_w": dense_init(keys[1], (s.d_conv, conv_ch)) * 0.1,
        "conv_b": jnp.zeros((conv_ch,)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "d_skip": jnp.ones((nh,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 1e-2))),  # softplus^-1
        "norm": jnp.zeros((di,)),
        "out_proj": dense_init(keys[2], (di, d)),
    }


def mamba_specs(cfg: ModelConfig) -> Dict:
    return {
        "in_proj": P("data", MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "a_log": P(None),
        "d_skip": P(None),
        "dt_bias": P(None),
        "norm": P(MODEL_AXIS),
        "out_proj": P(MODEL_AXIS, "data"),
    }


def _split_proj(p, x, cfg: ModelConfig):
    s, di, nh = _dims(cfg)
    zxbcdt = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * s.d_state], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, p, cfg: ModelConfig, state=None):
    """Depthwise causal conv over time; returns (out, new_state)."""
    s, _, _ = _dims(cfg)
    w = p["conv_w"].astype(xbc.dtype)                      # [W, C]
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)               # [B, T+W-1, C]
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    out = jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))
    new_state = xp[:, xp.shape[1] - (width - 1):]
    return out, new_state


def _ssd_chunked(xh, bmat, cmat, dt, a_log, chunk: int):
    """Chunked SSD.

    xh:   [B, T, H, P]   (dt-weighted inputs are formed here)
    bmat: [B, T, N], cmat: [B, T, N]   (n_groups = 1, shared across heads)
    dt:   [B, T, H]      (positive step sizes)
    Returns y [B, T, H, P].
    """
    bsz, t, h, pdim = xh.shape
    t_orig = t
    n = bmat.shape[-1]
    L = min(chunk, t)
    t_pad = -(-t // L) * L
    if t_pad != t:  # pad with identity steps (dt=0 => a=1, input 0)
        z = lambda v: jnp.concatenate(
            [v, jnp.zeros((bsz, t_pad - t, *v.shape[2:]), v.dtype)], axis=1)
        xh, bmat, cmat, dt = z(xh), z(bmat), z(cmat), z(dt)
        t = t_pad
    nc = t // L
    la = (-jnp.exp(a_log.astype(jnp.float32))[None, None] *
          dt.astype(jnp.float32))                           # log a_t  [B,T,H]
    xdt = xh * dt[..., None].astype(xh.dtype)               # dt_j x_j

    def r(v, extra=()):
        return v.reshape(bsz, nc, L, *v.shape[2:])

    la_c, x_c = r(la), r(xdt)
    b_c, c_c = r(bmat), r(cmat)
    cs = jnp.cumsum(la_c, axis=2)                           # [B,nc,L,H] incl.

    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cs_i - cs_j) * (i >= j)
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c,
                    preferred_element_type=jnp.float32)     # [B,nc,L,L]
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # [B,nc,i,j,H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    scores = jnp.where(tri[None, None, :, :, None],
                       cb[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(xh.dtype), x_c)

    # chunk state contribution: S_c = sum_j exp(cs_L - cs_j) B_j (dt_j x_j)
    tail = jnp.exp(cs[:, :, -1:, :] - cs)                   # [B,nc,L,H]
    s_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                     b_c, tail.astype(xh.dtype), x_c)       # [B,nc,H,N,P]
    total = jnp.exp(cs[:, :, -1])                           # [B,nc,H]

    def scan_fn(s_prev, inp):
        s_chunk, tot = inp                                  # [B,H,N,P], [B,H]
        s_new = s_prev * tot[..., None, None].astype(s_prev.dtype) + s_chunk
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, pdim), xh.dtype)
    _, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (s_c.swapaxes(0, 1), total.swapaxes(0, 1).astype(xh.dtype)))
    s_prevs = s_prevs.swapaxes(0, 1)                        # [B,nc,H,N,P]

    # inter-chunk: y_i += exp(cs_i) * C_i . S_prev
    inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                       c_c, jnp.exp(cs).astype(xh.dtype), s_prevs)
    y = (y_intra + inter).reshape(bsz, t, h, pdim)
    return y[:, :t_orig]


def mamba_forward(p: Dict, x, cfg: ModelConfig,
                  cache: Dict = None) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence SSD forward.  x: [B, T, d]."""
    s, di, nh = _dims(cfg)
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, conv_state = _causal_conv(xbc, p, cfg)
    xin, bmat, cmat = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    xh = xin.reshape(*xin.shape[:2], nh, s.head_dim)
    xh = constrain(xh, BATCH_AXES, None, MODEL_AXIS, None)
    dt_pos = jax.nn.softplus(dt.astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))
    y = _ssd_chunked(xh, bmat, cmat, dt_pos, p["a_log"], s.chunk)
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(*x.shape[:2], di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    out = constrain(out, BATCH_AXES, None, None)
    if cache is None:
        return out, None
    # prefill: recompute the final SSM state for decode
    new_cache = _final_state(xh, bmat, cmat, dt_pos, p["a_log"])
    new_cache = {"ssm": new_cache.astype(cache["ssm"].dtype),
                 "conv": conv_state.astype(cache["conv"].dtype)}
    return out, new_cache


def _final_state(xh, bmat, cmat, dt, a_log):
    """Exact state after the full sequence (for prefill -> decode handoff)."""
    la = (-jnp.exp(a_log.astype(jnp.float32))[None, None] * dt)  # [B,T,H]
    cs = jnp.cumsum(la, axis=1)
    tail = jnp.exp(cs[:, -1:, :] - cs)                      # [B,T,H]
    xdt = xh * dt[..., None].astype(xh.dtype)
    return jnp.einsum("btn,bth,bthp->bhnp",
                      bmat, tail.astype(xh.dtype), xdt)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    s, di, nh = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state), dtype),
    }


def mamba_cache_specs(cfg: ModelConfig) -> Dict:
    return {"ssm": P(BATCH_AXES, MODEL_AXIS, None, None),
            "conv": P(BATCH_AXES, None, MODEL_AXIS)}


def mamba_decode(p: Dict, x, cache: Dict, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, Dict]:
    """Single-step recurrence.  x: [B, 1, d]."""
    s, di, nh = _dims(cfg)
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, conv_state = _causal_conv(xbc, p, cfg, state=cache["conv"])
    xin, bmat, cmat = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    xh = xin.reshape(x.shape[0], 1, nh, s.head_dim)[:, 0]   # [B,H,P]
    dt_pos = jax.nn.softplus(dt.astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None] * dt_pos)
    xdt = xh * dt_pos[..., None].astype(xh.dtype)
    h_new = (cache["ssm"] * a[..., None, None].astype(cache["ssm"].dtype)
             + jnp.einsum("bn,bhp->bhnp", bmat[:, 0], xdt))
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0], h_new)
    y = y + p["d_skip"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(x.shape[0], 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    out = out.astype(x.dtype)   # f32 state must not promote the residual
    return out, {"ssm": h_new.astype(cache["ssm"].dtype),
                 "conv": conv_state.astype(cache["conv"].dtype)}
