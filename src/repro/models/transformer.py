"""Unified model: embeds -> scanned layer groups -> norm -> logits.

Layer kinds ('g' global attn, 'l' local attn, 'r' RG-LRU, 'm' Mamba-2 SSD)
come from ``cfg.layer_pattern``; the pattern unit is scanned (stacked params,
one compiled layer body) with any remainder layers unrolled, so an 80-layer
model lowers to one unit's HLO.  Params, shardings, caches and cache specs
all mirror the same grouped structure.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_mod
from . import frontend as front_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import BATCH_AXES, MODEL_AXIS, constrain, dense_init, rms_norm, softcap
from .config import ModelConfig

__all__ = [
    "layer_plan", "init_params", "param_specs", "forward",
    "init_cache", "cache_specs", "decode_step",
    "unstack_groups", "restack_groups", "forward_unscanned",
    "decode_step_unscanned",
]


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------
def layer_plan(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """[(unit_pattern, n_units), ...]; remainder layers become a 1-unit group."""
    unit = cfg.layer_pattern
    n_full = cfg.n_layers // len(unit)
    rem = cfg.pattern[n_full * len(unit):]
    plan = []
    if n_full:
        plan.append((unit, n_full))
    if rem:
        plan.append((rem, 1))
    return plan


# ---------------------------------------------------------------------------
# Per-layer init / specs / apply
# ---------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, kind: str, key) -> Dict:
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    p: Dict = {"ln1": jnp.zeros((d,))}
    if kind in ("g", "l"):
        p["attn"] = attn_mod.init_attn(cfg, keys[0])
        if cfg.moe is not None:
            p["ln2"] = jnp.zeros((d,))
            p["moe"] = moe_mod.init_moe(cfg, keys[1])
            if cfg.moe.dense_residual:
                p["mlp"] = mlp_mod.init_mlp(cfg, keys[2])
        elif cfg.mlp_kind != "none":
            p["ln2"] = jnp.zeros((d,))
            p["mlp"] = mlp_mod.init_mlp(cfg, keys[2])
        if cfg.post_norms:
            p["pn1"] = jnp.zeros((d,))
            p["pn2"] = jnp.zeros((d,))
    elif kind == "r":
        p["rec"] = rglru_mod.init_rglru(cfg, keys[0])
        if cfg.mlp_kind != "none":
            p["ln2"] = jnp.zeros((d,))
            p["mlp"] = mlp_mod.init_mlp(cfg, keys[2])
    elif kind == "m":
        p["mamba"] = ssm_mod.init_mamba(cfg, keys[0])
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def _layer_specs(cfg: ModelConfig, kind: str) -> Dict:
    p: Dict = {"ln1": P(None)}
    if kind in ("g", "l"):
        p["attn"] = attn_mod.attn_specs(cfg)
        if cfg.moe is not None:
            p["ln2"] = P(None)
            p["moe"] = moe_mod.moe_specs(cfg)
            if cfg.moe.dense_residual:
                p["mlp"] = mlp_mod.mlp_specs(cfg)
        elif cfg.mlp_kind != "none":
            p["ln2"] = P(None)
            p["mlp"] = mlp_mod.mlp_specs(cfg)
        if cfg.post_norms:
            p["pn1"] = P(None)
            p["pn2"] = P(None)
    elif kind == "r":
        p["rec"] = rglru_mod.rglru_specs(cfg)
        if cfg.mlp_kind != "none":
            p["ln2"] = P(None)
            p["mlp"] = mlp_mod.mlp_specs(cfg)
    elif kind == "m":
        p["mamba"] = ssm_mod.mamba_specs(cfg)
    return p


def _apply_layer(p: Dict, x, kind: str, cfg: ModelConfig, positions,
                 cache: Optional[Dict], pos=None, decode: bool = False,
                 moe_fn=None, attn_fn=None):
    """Returns (x, new_cache, aux_scalar_dict).

    ``moe_fn`` / ``attn_fn`` override the MoE and (forward-path) attention
    bodies — this is the hook the serving engine uses to route expert
    dispatch and attention scoring through the plan-based sparse engine
    while reusing every other piece of the layer (norms, residuals, cache
    plumbing) unchanged.  ``attn_fn`` matches ``attn_forward``'s signature;
    ``moe_fn`` matches ``moe_forward``'s.
    """
    aux = {"aux": jnp.zeros((), jnp.float32),
           "dropped": jnp.zeros((), jnp.float32)}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("g", "l"):
        if decode:
            y, new_cache = attn_mod.attn_decode(p["attn"], h, cache, pos,
                                                cfg, kind)
        elif attn_fn is not None:
            y, new_cache = attn_fn(p["attn"], h, cfg, kind, positions, cache)
        else:
            y, new_cache = attn_mod.attn_forward(p["attn"], h, cfg, kind,
                                                 positions, cache)
    elif kind == "r":
        if decode:
            y, new_cache = rglru_mod.rglru_decode(p["rec"], h, cache, cfg)
        else:
            y, new_cache = rglru_mod.rglru_forward(p["rec"], h, cfg, cache)
    elif kind == "m":
        if decode:
            y, new_cache = ssm_mod.mamba_decode(p["mamba"], h, cache, cfg)
        else:
            y, new_cache = ssm_mod.mamba_forward(p["mamba"], h, cfg, cache)
    if cfg.post_norms:
        y = rms_norm(y, p["pn1"], cfg.norm_eps)
    x = x + y

    if "mlp" in p or "moe" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            if moe_fn is None:
                moe_fn = (moe_mod.ring_moe_forward if cfg.moe_impl == "ring"
                          else moe_mod.moe_forward)
            y2, moe_aux = moe_fn(p["moe"], h2, cfg)
            aux["aux"] = aux["aux"] + moe_aux["moe_aux"] + moe_aux["moe_z"]
            aux["dropped"] = aux["dropped"] + moe_aux["moe_dropped"]
            if "mlp" in p:  # arctic's parallel dense residual branch
                y2 = y2 + mlp_mod.mlp_forward(p["mlp"], h2, cfg)
        else:
            y2 = mlp_mod.mlp_forward(p["mlp"], h2, cfg)
        if cfg.post_norms:
            y2 = rms_norm(y2, p["pn2"], cfg.norm_eps)
        x = x + y2
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model params / specs
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> Dict:
    keys = jax.random.split(key, 8)
    params: Dict = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                            in_axis=1),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size))
    if cfg.frontend:
        params["frontend"] = front_mod.init_frontend(cfg, keys[2])
    groups = []
    gkeys = jax.random.split(keys[3], max(len(layer_plan(cfg)), 1))
    for gi, (unit, n_units) in enumerate(layer_plan(cfg)):
        ukeys = jax.random.split(gkeys[gi], n_units)

        def one_unit(k, _unit=unit):
            lkeys = jax.random.split(k, len(_unit))
            return [_init_layer(cfg, kind, lk)
                    for kind, lk in zip(_unit, lkeys)]

        stacked = jax.vmap(one_unit)(ukeys)   # leaves: [n_units, ...]
        groups.append(stacked)
    params["groups"] = groups
    return params


def _stacked(spec: P) -> P:
    return P(None, *spec)


def param_specs(cfg: ModelConfig) -> Dict:
    specs: Dict = {
        "embed": P("data", MODEL_AXIS),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("data", MODEL_AXIS)
    if cfg.frontend:
        specs["frontend"] = front_mod.frontend_specs(cfg)
    groups = []
    for unit, _ in layer_plan(cfg):
        unit_specs = [jax.tree.map(_stacked, _layer_specs(cfg, kind),
                                   is_leaf=lambda s: isinstance(s, P))
                      for kind in unit]
        groups.append(unit_specs)
    specs["groups"] = groups
    return specs


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype):
    if kind in ("g", "l"):
        return attn_mod.init_attn_cache(cfg, kind, batch, max_len, dtype)
    if kind == "r":
        return rglru_mod.init_rglru_cache(cfg, batch)
    if kind == "m":
        return ssm_mod.init_mamba_cache(cfg, batch)
    raise ValueError(kind)


def _layer_cache_specs(cfg: ModelConfig, kind: str):
    if kind in ("g", "l"):
        return attn_mod.attn_cache_specs(cfg, kind)
    if kind == "r":
        return rglru_mod.rglru_cache_specs(cfg)
    if kind == "m":
        return ssm_mod.mamba_cache_specs(cfg)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> List:
    groups = []
    for unit, n_units in layer_plan(cfg):
        unit_cache = []
        for kind in unit:
            one = _init_layer_cache(cfg, kind, batch, max_len, dtype)
            unit_cache.append(jax.tree.map(
                lambda v: jnp.broadcast_to(v, (n_units, *v.shape)), one))
        groups.append(unit_cache)
    return groups


def cache_specs(cfg: ModelConfig) -> List:
    groups = []
    for unit, _ in layer_plan(cfg):
        groups.append([jax.tree.map(_stacked, _layer_cache_specs(cfg, kind),
                                    is_leaf=lambda s: isinstance(s, P))
                       for kind in unit])
    return groups


# ---------------------------------------------------------------------------
# Forward (train / prefill) and decode
# ---------------------------------------------------------------------------
def _embed_inputs(params: Dict, batch: Dict, cfg: ModelConfig):
    """Returns (x [B,T,d], label_positions [T])."""
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "audio":
        x = front_mod.audio_embed(params["frontend"],
                                  batch["frames"].astype(dtype), cfg)
    elif cfg.frontend == "vlm":
        tok = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
        patches = front_mod.vlm_embed(params["frontend"],
                                      batch["patches"].astype(dtype), cfg)
        x = jnp.concatenate([patches.astype(dtype), tok], axis=1)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return constrain(x, BATCH_AXES, None, None)


def _run_groups(params: Dict, x, cfg: ModelConfig, positions,
                caches: Optional[List] = None):
    """Scan each layer group; returns (x, new_caches, aux_sum)."""
    aux_sum = {"aux": jnp.zeros((), jnp.float32),
               "dropped": jnp.zeros((), jnp.float32)}
    new_caches: Optional[List] = [] if caches is not None else None

    for gi, (unit, n_units) in enumerate(layer_plan(cfg)):
        gparams = params["groups"][gi]
        gcache = caches[gi] if caches is not None else None

        def unit_fn(x, unit_params, unit_cache, _unit=unit):
            nc_list, aux_l = [], []
            for li, kind in enumerate(_unit):
                c = unit_cache[li] if unit_cache is not None else None
                x, nc, aux = _apply_layer(unit_params[li], x, kind, cfg,
                                          positions, c)
                nc_list.append(nc)
                aux_l.append(aux)
            aux_tot = jax.tree.map(lambda *v: sum(v), *aux_l)
            return x, nc_list, aux_tot

        if cfg.remat:
            unit_fn = jax.checkpoint(
                unit_fn, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())

        def scan_body(x, xs):
            unit_params, unit_cache = xs
            x, nc, aux = unit_fn(x, unit_params, unit_cache)
            return x, (nc, aux)

        xs = (gparams, gcache)
        x, (nc_stack, aux_stack) = jax.lax.scan(scan_body, x, xs)
        aux_sum = jax.tree.map(lambda a, b: a + b.sum(), aux_sum, aux_stack)
        if new_caches is not None:
            new_caches.append(nc_stack)
    return x, new_caches, aux_sum


def forward(params: Dict, batch: Dict, cfg: ModelConfig,
            caches: Optional[List] = None,
            positions: Optional[jnp.ndarray] = None):
    """Full-sequence forward.  Returns (logits, new_caches, aux)."""
    x = _embed_inputs(params, batch, cfg)
    t = x.shape[1]
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)
    x, new_caches, aux = _run_groups(params, x, cfg, positions, caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = jnp.einsum("btd,dv->btv", x, head,
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    logits = constrain(logits, BATCH_AXES, None, MODEL_AXIS)
    return logits, new_caches, aux


def decode_step(params: Dict, token, caches: List, pos, cfg: ModelConfig,
                return_aux: bool = False):
    """One-token step.  token: [B, 1] int32; pos: scalar int32 position or
    int32 [B] per-request positions (continuous batching).

    Returns (logits [B, 1, V], new_caches), plus the summed per-layer aux
    dict (dropped-token stats) when ``return_aux`` is set.
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)

    new_caches = []
    aux_sum = {"aux": jnp.zeros((), jnp.float32),
               "dropped": jnp.zeros((), jnp.float32)}
    for gi, (unit, n_units) in enumerate(layer_plan(cfg)):
        gparams = params["groups"][gi]
        gcache = caches[gi]

        def scan_body(x, xs, _unit=unit):
            unit_params, unit_cache = xs
            nc_list, aux_l = [], []
            for li, kind in enumerate(_unit):
                x, nc, aux = _apply_layer(unit_params[li], x, kind, cfg,
                                          None, unit_cache[li], pos=pos,
                                          decode=True)
                nc_list.append(nc)
                aux_l.append(aux)
            aux_tot = jax.tree.map(lambda *v: sum(v), *aux_l)
            return x, (nc_list, aux_tot)

        x, (nc_stack, aux_stack) = jax.lax.scan(scan_body, x,
                                                (gparams, gcache))
        aux_sum = jax.tree.map(lambda a, b: a + b.sum(), aux_sum, aux_stack)
        new_caches.append(nc_stack)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = jnp.einsum("btd,dv->btv", x, head,
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    if return_aux:
        return logits, new_caches, aux_sum
    return logits, new_caches


# ---------------------------------------------------------------------------
# Unscanned (per-layer) paths — the serving engine's entry points
# ---------------------------------------------------------------------------
def unstack_groups(cfg: ModelConfig, groups: List) -> List:
    """Flatten the grouped/stacked param or cache tree into per-layer trees.

    The scanned representation stacks each group's units along a leading
    ``[n_units, ...]`` axis; host-driven code (the serving engine routing
    layers through the plan API one by one) needs plain per-layer subtrees
    in ``cfg.pattern`` order.  Inverse of :func:`restack_groups`.
    """
    layers = []
    for gi, (unit, n_units) in enumerate(layer_plan(cfg)):
        for ui in range(n_units):
            for li in range(len(unit)):
                layers.append(jax.tree.map(lambda v, _ui=ui: v[_ui],
                                           groups[gi][li]))
    return layers


def restack_groups(cfg: ModelConfig, layers: List) -> List:
    """Stack per-layer trees back into the grouped ``[n_units, ...]`` form."""
    groups, idx = [], 0
    for unit, n_units in layer_plan(cfg):
        per_unit = [[] for _ in unit]
        for ui in range(n_units):
            for li in range(len(unit)):
                per_unit[li].append(layers[idx])
                idx += 1
        groups.append([jax.tree.map(lambda *v: jnp.stack(v), *ls)
                       for ls in per_unit])
    return groups


def _head_logits(params: Dict, x, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = jnp.einsum("btd,dv->btv", x, head,
                        preferred_element_type=jnp.float32)
    return softcap(logits, cfg.final_softcap)


def forward_unscanned(params: Dict, batch: Dict, cfg: ModelConfig,
                      caches: Optional[List] = None,
                      positions: Optional[jnp.ndarray] = None,
                      moe_fn=None, attn_fn=None):
    """Full-sequence forward with a python layer loop (no scan).

    Same contract as :func:`forward` but each layer runs eagerly, so
    ``moe_fn`` / ``attn_fn`` may perform host-side work per layer — this is
    how the serving engine materializes routing/attention structure into
    ``DistBSR`` handles and calls cached ``plan_matmul`` executables from
    inside the model.  Returns (logits, new_caches, aux).
    """
    x = _embed_inputs(params, batch, cfg)
    t = x.shape[1]
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)
    layers_p = unstack_groups(cfg, params["groups"])
    layers_c = unstack_groups(cfg, caches) if caches is not None else \
        [None] * len(layers_p)
    aux_sum = {"aux": jnp.zeros((), jnp.float32),
               "dropped": jnp.zeros((), jnp.float32)}
    new_layers = []
    for p_l, c_l, kind in zip(layers_p, layers_c, cfg.pattern):
        x, nc, aux = _apply_layer(p_l, x, kind, cfg, positions, c_l,
                                  moe_fn=moe_fn, attn_fn=attn_fn)
        new_layers.append(nc)
        aux_sum = jax.tree.map(lambda a, b: a + b, aux_sum, aux)
    new_caches = restack_groups(cfg, new_layers) \
        if caches is not None else None
    return _head_logits(params, x, cfg), new_caches, aux_sum


def decode_step_unscanned(params: Dict, token, caches: List, pos,
                          cfg: ModelConfig, moe_fn=None):
    """One-token step with a python layer loop (see ``forward_unscanned``).

    Returns (logits [B, 1, V], new_caches, aux).
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    layers_p = unstack_groups(cfg, params["groups"])
    layers_c = unstack_groups(cfg, caches)
    aux_sum = {"aux": jnp.zeros((), jnp.float32),
               "dropped": jnp.zeros((), jnp.float32)}
    new_layers = []
    for p_l, c_l, kind in zip(layers_p, layers_c, cfg.pattern):
        x, nc, aux = _apply_layer(p_l, x, kind, cfg, None, c_l, pos=pos,
                                  decode=True, moe_fn=moe_fn)
        new_layers.append(nc)
        aux_sum = jax.tree.map(lambda a, b: a + b, aux_sum, aux)
    return (_head_logits(params, x, cfg), restack_groups(cfg, new_layers),
            aux_sum)
