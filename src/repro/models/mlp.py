"""Gated MLPs (SwiGLU / GeGLU)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, MODEL_AXIS, constrain, dense_init
from .config import ModelConfig

__all__ = ["init_mlp", "mlp_specs", "mlp_forward"]


def init_mlp(cfg: ModelConfig, key, d_ff=None) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, (d, f)),
        "w_down": dense_init(k3, (f, d)),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k1, (d, f))
    return p


def mlp_specs(cfg: ModelConfig) -> Dict:
    p = {
        "w_up": P("data", MODEL_AXIS),
        "w_down": P(MODEL_AXIS, "data"),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["w_gate"] = P("data", MODEL_AXIS)
    return p


def mlp_forward(p: Dict, x, cfg: ModelConfig):
    gelu = lambda v: jax.nn.gelu(v, approximate=True)
    u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
    if cfg.mlp_kind == "gelu":          # plain 2-matrix MLP (hubert)
        h = gelu(u)
    else:                               # gated: swiglu / geglu
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else gelu
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype))
        h = act(g) * u
    h = constrain(h, BATCH_AXES, None, MODEL_AXIS)
    out = jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))
    return constrain(out, BATCH_AXES, None, None)
