"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block = linear-in x2 (x branch, GeLU gate branch), temporal conv (width 4)
on the x branch, the RG-LRU diagonal linear recurrence, multiplicative gate,
linear-out.  Gates use block-diagonal projections (8 blocks) as in Griffin.
Training uses an associative scan over time (log-depth); decode is the plain
one-step recurrence — this is what makes ``long_500k`` state-bounded.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, MODEL_AXIS, constrain, dense_init
from .config import ModelConfig

__all__ = ["init_rglru", "rglru_specs", "rglru_forward", "rglru_decode",
           "init_rglru_cache", "rglru_cache_specs"]

_NBLOCKS = 8
_CONV_W = 4
_C = 8.0  # Griffin's fixed gate sharpness


def _w(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_rglru(cfg: ModelConfig, key) -> Dict:
    d, w = cfg.d_model, _w(cfg)
    wb = w // _NBLOCKS
    keys = jax.random.split(key, 6)
    return {
        "in_x": dense_init(keys[0], (d, w)),
        "in_gate": dense_init(keys[1], (d, w)),
        "conv_w": dense_init(keys[2], (_CONV_W, w)) * 0.1,
        "conv_b": jnp.zeros((w,)),
        "gate_a": dense_init(keys[3], (_NBLOCKS, wb, wb), in_axis=1),
        "gate_x": dense_init(keys[4], (_NBLOCKS, wb, wb), in_axis=1),
        "gate_a_b": jnp.zeros((w,)),
        "gate_x_b": jnp.zeros((w,)),
        # a = exp(-c * softplus(lam) * r); init so a^c ~ 0.9..0.999
        "lam": jnp.linspace(0.3, 1.5, w),
        "out": dense_init(keys[5], (w, d)),
    }


def rglru_specs(cfg: ModelConfig) -> Dict:
    return {
        "in_x": P("data", MODEL_AXIS),
        "in_gate": P("data", MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "gate_a": P(None, None, MODEL_AXIS),
        "gate_x": P(None, None, MODEL_AXIS),
        "gate_a_b": P(MODEL_AXIS),
        "gate_x_b": P(MODEL_AXIS),
        "lam": P(MODEL_AXIS),
        "out": P(MODEL_AXIS, "data"),
    }


def _block_proj(x, wmat, bias):
    """x: [..., w] -> block-diagonal projection, blocks on the last dim."""
    shape = x.shape
    xb = x.reshape(*shape[:-1], _NBLOCKS, shape[-1] // _NBLOCKS)
    out = jnp.einsum("...nb,nbc->...nc", xb, wmat.astype(x.dtype))
    return out.reshape(shape) + bias.astype(x.dtype)


def _gates(p, xc):
    r = jax.nn.sigmoid(_block_proj(xc, p["gate_a"], p["gate_a_b"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_proj(xc, p["gate_x"], p["gate_x_b"])
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, (mult * i * xc.astype(jnp.float32))


def _conv(xb, p, state=None):
    w = p["conv_w"].astype(xb.dtype)
    if state is None:
        pad = jnp.zeros((xb.shape[0], _CONV_W - 1, xb.shape[2]), xb.dtype)
    else:
        pad = state.astype(xb.dtype)
    xp = jnp.concatenate([pad, xb], axis=1)
    out = sum(xp[:, i:i + xb.shape[1]] * w[i] for i in range(_CONV_W))
    new_state = xp[:, xp.shape[1] - (_CONV_W - 1):]
    return out + p["conv_b"].astype(xb.dtype), new_state


def rglru_forward(p: Dict, x, cfg: ModelConfig,
                  cache: Dict = None) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, T, d] full-sequence forward via associative scan."""
    xb = jnp.einsum("btd,dw->btw", x, p["in_x"].astype(x.dtype))
    gate = jax.nn.gelu(
        jnp.einsum("btd,dw->btw", x, p["in_gate"].astype(x.dtype)),
        approximate=True)
    xc, conv_state = _conv(xb, p)
    xc = constrain(xc, BATCH_AXES, None, MODEL_AXIS)
    a, b = _gates(p, xc)                     # [B,T,W] f32 each

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    y = h * gate
    out = jnp.einsum("btw,wd->btd", y, p["out"].astype(x.dtype))
    out = constrain(out, BATCH_AXES, None, None)
    if cache is None:
        return out, None
    new_cache = {"h": h[:, -1].astype(cache["h"].dtype),
                 "conv": conv_state.astype(cache["conv"].dtype)}
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    w = _w(cfg)
    return {"h": jnp.zeros((batch, w), dtype),
            "conv": jnp.zeros((batch, _CONV_W - 1, w), dtype)}


def rglru_cache_specs(cfg: ModelConfig) -> Dict:
    return {"h": P(BATCH_AXES, MODEL_AXIS),
            "conv": P(BATCH_AXES, None, MODEL_AXIS)}


def rglru_decode(p: Dict, x, cache: Dict, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, 1, d] single-step recurrence."""
    xb = jnp.einsum("btd,dw->btw", x, p["in_x"].astype(x.dtype))
    gate = jax.nn.gelu(
        jnp.einsum("btd,dw->btw", x, p["in_gate"].astype(x.dtype)),
        approximate=True)
    xc, conv_state = _conv(xb, p, state=cache["conv"])
    a, b = _gates(p, xc)                     # [B,1,W]
    h = (a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0])
    y = h.astype(x.dtype)[:, None] * gate
    out = jnp.einsum("btw,wd->btd", y, p["out"].astype(x.dtype))
    return out, {"h": h.astype(cache["h"].dtype),
                 "conv": conv_state.astype(cache["conv"].dtype)}
