"""AdamW with global-norm clipping and cosine schedule (no optax here)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Union

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "cosine_schedule"]


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Union[float, Callable] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # second-moment dtype: bf16 halves optimizer memory (beyond-paper lever)
    nu_dtype: str = "float32"

    def init(self, params) -> Dict:
        zeros = lambda dt: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(dt)), params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": zeros("float32"),
            "nu": zeros(self.nu_dtype),
            "gnorm": jnp.zeros((), jnp.float32),
        }

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32) * scale
            mu = self.b1 * mu + (1 - self.b1) * g
            nu_f = nu.astype(jnp.float32)
            nu_f = self.b2 * nu_f + (1 - self.b2) * jnp.square(g)
            mu_hat = mu / (1 - self.b1 ** step.astype(jnp.float32))
            nu_hat = nu_f / (1 - self.b2 ** step.astype(jnp.float32))
            u = -self._lr(step) * (
                mu_hat / (jnp.sqrt(nu_hat) + self.eps)
                + self.weight_decay * p.astype(jnp.float32))
            return u, mu, nu_f.astype(nu.dtype)

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        # unzip the (u, mu, nu) triples
        updates = jax.tree.map(lambda t: t[0], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
        new_state = {"step": step, "mu": mu, "nu": nu, "gnorm": gnorm}
        return updates, new_state

    @staticmethod
    def last_grad_norm(state) -> jnp.ndarray:
        return state["gnorm"]

    # ------------------------------------------------------ sharding helpers
    @staticmethod
    def state_specs(param_specs) -> Dict:
        """Optimizer state shards exactly like the parameters (ZeRO)."""
        from jax.sharding import PartitionSpec as P
        return {
            "step": P(),
            "mu": param_specs,
            "nu": param_specs,
            "gnorm": P(),
        }
