"""int8 gradient compression with error feedback, for the slow cross-pod
(DCN) data-parallel all-reduce.

Per-tensor symmetric quantization: q = round(g / s * 127) with
s = max|g| per tensor; residual (g - dequant(q)) is carried to the next
step (error feedback), which keeps SGD/Adam convergence unbiased in
practice.  8x volume reduction on the pod axis at ~zero quality cost —
one of the distributed-optimization levers recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ErrorFeedbackState",
           "compressed_psum"]


def compress_int8(g) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: object  # pytree like grads

    @classmethod
    def init(cls, grads_like):
        return cls(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compressed_psum(grads, axis_name: str, ef: ErrorFeedbackState
                    ) -> Tuple[object, ErrorFeedbackState]:
    """psum(grads) over ``axis_name`` with int8 wire format + error feedback.

    Must run inside shard_map with ``axis_name`` bound.  The int8 tensors are
    what crosses the (slow) axis; scales are psum'd at f32 (negligible).
    """
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, s = compress_int8(g)
        deq = decompress_int8(q, s)
        new_r = g - deq
        summed = jax.lax.psum(deq, axis_name)   # wire-equivalent of int8 sum
        return summed, new_r

    pairs = jax.tree.map(one, grads, ef.residual)
    summed = jax.tree.map(lambda t: t[0], pairs,
                          is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    return summed, ErrorFeedbackState(resid)
