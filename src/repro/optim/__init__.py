from .adamw import AdamW, cosine_schedule  # noqa: F401
from .compression import (compress_int8, decompress_int8,  # noqa: F401
                          ErrorFeedbackState)
