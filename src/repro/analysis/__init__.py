"""Static analysis: prove communication plans correct before they run.

Three passes (see DESIGN.md "Static analysis"):

* :mod:`repro.analysis.schedule_check` — host-side verification over
  plan metadata (ppermute bijections, steal3d exactly-once +
  conservation, packed-wire consume-map contracts, sparse pair lists,
  balance perms).
* :mod:`repro.analysis.jaxpr_lint` — structural rules over the plan's
  traced executable (sort/scatter-free scan steps, collective count ==
  cost-model messages, overlap-carry happens-before), plus the shared
  jaxpr-walk primitives the test suite builds on.
* :mod:`repro.analysis.source_rules` — the AST-level source hygiene
  registry behind ``tools/check_api.py``.

Entry points: ``check_plan`` / ``lint_plan`` return ``List[Finding]``
(empty == proven clean); ``plan_matmul(validate="fast"|"full")`` runs
them at plan-build time and raises :class:`PlanValidationError` on any
finding.
"""
from .findings import Finding, PlanValidationError
from .jaxpr_lint import (iter_eqns, lint_plan, scan_body_primitives,
                         scan_eqns, subjaxprs, trace_plan)
from .schedule_check import check_plan, check_survivor_coverage

from . import jaxpr_lint, schedule_check, source_rules


def all_rules():
    """(rule id, description) for every registered rule, all passes."""
    return (tuple(schedule_check.RULES) + tuple(jaxpr_lint.RULES)
            + tuple((r.id, r.description) for r in source_rules.RULES))


__all__ = [
    "Finding", "PlanValidationError", "check_plan",
    "check_survivor_coverage", "lint_plan",
    "trace_plan", "subjaxprs", "iter_eqns", "scan_eqns",
    "scan_body_primitives", "all_rules", "jaxpr_lint", "schedule_check",
    "source_rules",
]
