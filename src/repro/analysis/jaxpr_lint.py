"""Jaxpr lint: structural rules over a plan's traced executable.

The schedule bodies are scanned/flat jaxprs whose communication structure
is fully static, so three properties can be proven by walking the trace
instead of measured at runtime:

* ``jaxpr.scan-hot-loop`` — scanned schedule steps stay sort/scatter-free
  (coverage augmentation and B-densification are hoisted to plan time;
  a sort or scatter inside the ring step is the hot-loop bloat PR 2
  eliminated creeping back in).
* ``jaxpr.collective-count`` — the number of collective message groups in
  the trace equals the cost model's message count (``n_msgs`` for
  steal3d, ``msgs_per_step``/wire-derived otherwise).  This catches
  cost-model/code drift statically, before ``fit_machine`` fits
  constants against a miscounted model.  Skipped at g == 1, where the
  forward and backward ring permutations collapse to the same
  ``[(0, 0)]`` and message groups alias.
* ``jaxpr.overlap-carry`` — the double-buffered two-slot carry discipline:
  in an overlap scan body, step t+1's transfer is issued before step t's
  accumulate, and no collective's in-flight output reaches a compute op
  inside the same body (computes must consume the *carried* slot).

The walk primitives (:func:`subjaxprs`, :func:`iter_eqns`,
:func:`scan_eqns`) are the single shared copy of the helpers that used to
be duplicated across ``tests/test_api.py`` / ``test_wire.py`` /
``test_overlap.py``.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

from .findings import Finding

#: primitives that ship bytes between devices (jaxpr primitive names)
COLLECTIVE_PRIMS = ("ppermute", "psum", "all_gather", "all_to_all",
                    "reduce_scatter")

#: primitives (by substring) banned inside scanned schedule steps
HOT_LOOP_BANNED = ("sort", "scatter")

#: primitives that do real math on tile payloads — "compute" for the
#: happens-before race check, and group breakers for message counting
COMPUTE_PRIMS = ("dot_general", "pallas_call", "conv_general_dilated")


# ---------------------------------------------------------------------------
# walk primitives (shared with the test suite)
# ---------------------------------------------------------------------------
def subjaxprs(v) -> Iterator:
    """Yield every Jaxpr reachable from an eqn-param value."""
    from jax import core as jcore
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from subjaxprs(x)


def iter_eqns(jaxpr) -> Iterator:
    """Depth-first over all eqns, recursing through sub-jaxpr params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in subjaxprs(v):
                yield from iter_eqns(sub)


def scan_eqns(jaxpr) -> List:
    """All ``scan`` eqns anywhere in the jaxpr."""
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "scan"]


def scan_body_primitives(jaxpr) -> set:
    """Primitive names appearing inside any scanned body."""
    prims = set()
    for eqn in scan_eqns(jaxpr):
        for sub in iter_eqns(eqn.params["jaxpr"].jaxpr):
            prims.add(sub.primitive.name)
    return prims


def trace_plan(plan, a, b):
    """Trace exactly what ``plan(a, b)`` executes and return the jaxpr.

    Uses ``plan._operands`` so the linted trace is the executed trace
    (packed wire trees, steal3d aux, sparse pair lists included).  The
    plan's trace counter is restored afterwards — linting must not
    perturb the retrace-count invariants the test suite asserts.
    """
    import jax
    from repro.core import api as _api
    a_h, b_h = _api._coerce_pair(a, b, g=plan.geom.g,
                                 allow_pad=plan._allow_pad)
    operands = plan._operands(a_h, b_h)
    traces0 = plan.traces
    try:
        closed = jax.make_jaxpr(lambda *xs: plan._exec(*xs))(*operands)
    finally:
        plan.traces = traces0
    return closed.jaxpr


# ---------------------------------------------------------------------------
# rule: jaxpr.scan-hot-loop
# ---------------------------------------------------------------------------
def check_hot_loop(jaxpr, impl: Optional[str] = None) -> List[Finding]:
    if impl in (None, "auto"):
        from repro.kernels.ops import default_impl
        impl = default_impl()
    if impl == "ref":
        # the reference (numpy-style) kernel accumulates via scatter-add
        # by design; the gather-only contract binds the pallas paths
        return []
    offenders = sorted(
        p for p in scan_body_primitives(jaxpr)
        if any(bad in p for bad in HOT_LOOP_BANNED))
    if not offenders:
        return []
    return [Finding(
        "jaxpr.scan-hot-loop",
        f"scanned schedule step contains {offenders}: coverage "
        "augmentation / densification must be hoisted to plan time "
        "(pre-augmented tiles, plan-built consume maps), not re-done "
        "every ring step")]


# ---------------------------------------------------------------------------
# rule: jaxpr.collective-count
# ---------------------------------------------------------------------------
def _is_compute(name: str) -> bool:
    return name in COMPUTE_PRIMS or any(b in name for b in HOT_LOOP_BANNED)


def _collective_key(eqn) -> tuple:
    params = eqn.params
    key = tuple(sorted(
        (k, str(params[k]))
        for k in ("axis_name", "axes", "perm", "axis_index_groups")
        if k in params))
    return (eqn.primitive.name, key)


def count_message_groups(jaxpr) -> int:
    """Count collective *message groups* in trace order.

    A message group is one logical shipment: a float-payload collective
    plus any immediately following integer-payload collectives with the
    same (primitive, axis/perm) — the blocks/rows/cols legs of one
    tree-ppermute'd sparse tile are one message, while two independent
    float payloads (say B's tile and the riding-home C partial) are two
    even when they share a ring.  Groups inside a ``scan`` body count
    once per iteration (times the scan length); compute ops, control
    flow and scan boundaries end the current group.
    """
    events: List[Optional[Tuple[tuple, bool, int]]] = []

    def walk(jx, mult: int) -> None:
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "scan":
                events.append(None)
                walk(eqn.params["jaxpr"].jaxpr,
                     mult * int(eqn.params["length"]))
                events.append(None)
                continue
            if name == "pvary":     # axis-metadata no-op, not a message
                continue
            if name in COLLECTIVE_PRIMS:
                int_payload = all(
                    getattr(v.aval.dtype, "kind", "f") in "iub"
                    for v in eqn.outvars)
                events.append((_collective_key(eqn), int_payload, mult))
                continue
            subs = [s for v in eqn.params.values() for s in subjaxprs(v)]
            if _is_compute(name) or name in ("while", "cond"):
                events.append(None)
                for s in subs:
                    walk(s, mult)
                    events.append(None)
            elif subs:              # pjit/closed_call etc: transparent
                for s in subs:
                    walk(s, mult)
    walk(jaxpr, 1)

    total = 0
    cur_key = None
    for ev in events:
        if ev is None:
            cur_key = None
            continue
        key, int_payload, mult = ev
        if key == cur_key and int_payload:
            continue                # metadata rider on the current group
        total += mult
        cur_key = key
    return total


def check_collective_count(plan, jaxpr) -> List[Finding]:
    if plan.geom.g < 2:
        return []    # degenerate ring perms alias; counted in selftest
    from repro.core import roofline as _roofline
    from repro.core.api import _time_breakdown
    cm = plan.cost_model()
    expected = int(round(_time_breakdown(
        cm, plan.algorithm, _roofline.TPU_V5E, plan.overlap)["msgs"]))
    got = count_message_groups(jaxpr)
    if got == expected:
        return []
    return [Finding(
        "jaxpr.collective-count",
        f"trace has {got} collective message group(s) but the cost model "
        f"charges {expected} (n_msgs/msgs_per_step); the model and the "
        "schedule body have drifted — fix whichever is wrong before "
        "fit_machine calibrates against the miscount",
        subject=f"{plan.algorithm.name}/{plan.wire}")]


# ---------------------------------------------------------------------------
# rule: jaxpr.overlap-carry
# ---------------------------------------------------------------------------
def check_overlap_carry(plan, jaxpr) -> List[Finding]:
    if not plan.geom.overlap:
        return []
    from jax import core as jcore
    findings = []
    for scan in scan_eqns(jaxpr):
        body = scan.params["jaxpr"].jaxpr
        first_coll = first_comp = None
        tainted = set()
        for idx, eqn in enumerate(body.eqns):
            name = eqn.primitive.name
            invars = [v for v in eqn.invars if isinstance(v, jcore.Var)]
            if name in COLLECTIVE_PRIMS:
                if first_coll is None:
                    first_coll = idx
                tainted.update(eqn.outvars)
            elif _is_compute(name):
                if first_comp is None:
                    first_comp = idx
                hot = [str(v) for v in invars if v in tainted]
                if hot:
                    findings.append(Finding(
                        "jaxpr.overlap-carry",
                        f"compute op {name!r} consumes in-flight transfer "
                        f"output {hot} inside the scan body that issued "
                        "it — the double-buffered contract is compute on "
                        "the carried slot while the next slot's transfer "
                        "flies; carry the fresh buffer and consume it "
                        "next step",
                        subject=f"{plan.algorithm.name}/overlap"))
            elif any(v in tainted for v in invars):
                tainted.update(eqn.outvars)
        if first_coll is not None and first_comp is not None \
                and first_comp < first_coll:
            findings.append(Finding(
                "jaxpr.overlap-carry",
                "overlap scan body accumulates before issuing step t+1's "
                "transfer (first compute eqn precedes first collective) — "
                "the transfer can no longer fly under this step's "
                "compute; issue the collectives first",
                subject=f"{plan.algorithm.name}/overlap"))
    return findings


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
RULES = (
    ("jaxpr.scan-hot-loop",
     "scanned schedule steps contain no sort/scatter primitives"),
    ("jaxpr.collective-count",
     "collective message groups in the trace == cost model msgs (g >= 2)"),
    ("jaxpr.overlap-carry",
     "overlap bodies issue transfers first and never compute on "
     "in-flight buffers"),
)


def lint_plan(plan, a=None, b=None, *, jaxpr=None) -> List[Finding]:
    """Run every jaxpr rule over the plan's executed trace.

    Pass the plan's operands (handles or raw values) so the trace covers
    the real operand trees, or a pre-traced ``jaxpr``.
    """
    if jaxpr is None:
        if a is None or b is None:
            raise ValueError(
                "lint_plan needs the plan's operands (or jaxpr=) to trace "
                "the executable")
        jaxpr = trace_plan(plan, a, b)
    return (check_hot_loop(jaxpr, impl=plan.geom.impl)
            + check_collective_count(plan, jaxpr)
            + check_overlap_carry(plan, jaxpr))
