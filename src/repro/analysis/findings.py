"""Finding/verdict types shared by the static-analysis passes.

A finding is one rule violation: a stable machine-readable rule id, a
human-actionable message, and an optional subject (which plan / device /
permutation the violation is about).  Passes return ``List[Finding]`` —
empty means proven clean under that pass's rules — and
:class:`PlanValidationError` is how ``plan_matmul(validate=...)`` turns a
non-empty list into a refusal to hand back the plan.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis violation."""

    rule: str       # stable id, e.g. "schedule.ppermute-bijection"
    message: str    # actionable description of what is wrong + how to fix
    subject: str = ""   # what the finding is about (plan/device/step/...)

    def __str__(self) -> str:
        where = f" [{self.subject}]" if self.subject else ""
        return f"{self.rule}{where}: {self.message}"


class PlanValidationError(ValueError):
    """A communication plan failed static verification.

    Raised by ``MatmulPlan.validate`` / ``plan_matmul(validate=...)``.
    ``.findings`` holds the full list; the message leads with the rule
    ids so the failure is greppable.
    """

    def __init__(self, findings: Sequence[Finding]):
        self.findings: List[Finding] = list(findings)
        rules = sorted({f.rule for f in self.findings})
        lines = "\n".join(f"  {f}" for f in self.findings)
        super().__init__(
            f"plan failed static verification ({len(self.findings)} "
            f"finding(s), rules {rules}):\n{lines}")
