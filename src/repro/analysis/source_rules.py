"""Source rules: the repo's AST-level hygiene pass as a rule registry.

This is ``tools/check_api.py`` refactored into pluggable rules (that
script is now a thin shim over this module so the CLI contract and the
tier-1 wiring are unchanged).  Three rule families, byte-compatible with
the legacy guard:

* ``source.import.<module>`` — deprecated/internal module imports
  (``repro.core.spmm`` shims, the Pallas kernel module, the symbolic /
  steal3d / wire planners, ``repro.serving.engine``) outside their
  allowed homes; first-party code goes through ``repro.core.api``.
* ``source.xla-flags-write`` — direct ``XLA_FLAGS`` environment writes
  anywhere but ``repro/runtime/platform.py`` (XLA reads the variable
  once, at first backend init; scattered writes are silently dead).
* ``source.perf-counter-discipline`` — functions timing with raw
  ``perf_counter`` pairs and no blocking discipline (jax dispatch is
  async; use ``obs.sync_elapsed`` / ``obs.timed`` /
  ``block_until_ready``).

Waivers: a violation is suppressed when the flagged line carries the
pragma ``# analysis: allow(<rule-id>)``, e.g.::

    from repro.core import steal3d  # analysis: allow(source.import.repro.core.steal3d)

Waivers are per-line and per-rule — there is deliberately no file-level
or wildcard form.

Deliberately stdlib-only (no jax import) so the ``tools/`` shim works in
any interpreter.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import sys
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# legacy configuration (byte-compatible with the pre-registry check_api)
# ---------------------------------------------------------------------------
# module -> scan config:
#   parent/leaf  : detect `from parent import leaf`
#   dirs         : repo-relative directories to scan
#   allow        : path prefixes (relative, posix) where the import is fine
FORBIDDEN_MODULES = {
    "repro.core.spmm": {
        "parent": "repro.core", "leaf": "spmm",
        "dirs": ("examples", "benchmarks"), "allow": (),
    },
    "repro.kernels.bsr_spmm": {
        "parent": "repro.kernels", "leaf": "bsr_spmm",
        "dirs": ("examples", "benchmarks"), "allow": (),
    },
    "repro.core.symbolic": {
        "parent": "repro.core", "leaf": "symbolic",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/core",),
    },
    # The steal3d planner couples LPT assignments to executables the same
    # way the symbolic phase couples pair lists: plans own that coupling,
    # so the builder is internal to repro/core (use
    # plan_matmul(algorithm="steal3d")).
    "repro.core.steal3d": {
        "parent": "repro.core", "leaf": "steal3d",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/core",),
    },
    # The packed wire layer couples consume maps / remapped pair lists to
    # executables exactly like the symbolic phase; its public surface is
    # plan_matmul(wire="packed") plus the repro.core.api re-exports
    # (PackedOperand / wire_capacity / DistBSR.packed_operand).  The
    # static analyzer needs the tile-schedule tables to re-derive the
    # consume-map contract, so it is a second allowed home.
    "repro.core.wire": {
        "parent": "repro.core", "leaf": "wire",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/core", "src/repro/analysis"),
    },
    # The serving engine's slot/cache-splicing internals are not API:
    # import ServeEngine from repro.serving (the package __init__), which
    # owns the admission/batching/metrics surface.
    "repro.serving.engine": {
        "parent": "repro.serving", "leaf": "engine",
        "dirs": ("examples", "benchmarks", "tools", "tests", "src/repro"),
        "allow": ("src/repro/serving",),
    },
}


# XLA_FLAGS write ban: scanned dirs and the single allowed writer.
XLA_FLAG_DIRS = ("src/repro", "examples", "benchmarks", "tools", "tests")
XLA_FLAG_ALLOW = ("src/repro/runtime/platform.py",)


# Direct Assignment3D construction ban: a hand-rolled 3D assignment
# bypasses validate_assignment's fail-fast invariant checks (locality,
# exactly-once, makespan <= owner-computes), so every rebuilt assignment
# must flow through core/steal3d.py (the planner) or runtime/replan.py
# (elastic recovery) — both gate on validate_assignment before the
# assignment reaches an executable.  core/schedule.py defines the class
# and its one sanctioned constructor (assign_3d_lpt).
ASSIGNMENT3D_DIRS = ("src/repro", "examples", "benchmarks", "tools",
                     "tests")
ASSIGNMENT3D_ALLOW = ("src/repro/core/schedule.py",
                      "src/repro/core/steal3d.py",
                      "src/repro/runtime/replan.py")


# Raw-perf_counter timing ban: jax dispatch is asynchronous, so a
# perf_counter pair around a jax call times the *dispatch*, not the work
# (the timing smear PR 6 fixed in launch/serve.py).  Any function that
# reads perf_counter twice or more must reference one of the sanctioned
# blocking helpers (``block_until_ready`` directly, or ``sync_elapsed`` /
# ``timed`` from ``repro.obs``) in the same scope.  ``repro/obs`` and the
# thin re-export in ``serving/metrics.py`` are the helpers' home.
PERF_COUNTER_DIRS = ("src/repro", "examples", "benchmarks", "tools")
PERF_COUNTER_ALLOW = ("src/repro/obs", "src/repro/serving/metrics.py")
PERF_COUNTER_BLOCKERS = ("block_until_ready", "sync_elapsed", "timed")


# ---------------------------------------------------------------------------
# per-file hit functions (unchanged behavior)
# ---------------------------------------------------------------------------
def _perf_counter_hits(tree: ast.AST) -> List:
    """Functions timing with >= 2 raw perf_counter reads and no blocking
    discipline (no block_until_ready/sync_elapsed/timed reference)."""
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        n_pc = 0
        blocked = False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                f = sub.func
                name = f.attr if isinstance(f, ast.Attribute) else \
                    f.id if isinstance(f, ast.Name) else None
                if name == "perf_counter":
                    n_pc += 1
            ref = sub.attr if isinstance(sub, ast.Attribute) else \
                sub.id if isinstance(sub, ast.Name) else None
            if ref in PERF_COUNTER_BLOCKERS:
                blocked = True
        if n_pc >= 2 and not blocked:
            hits.append(
                (node.lineno,
                 f"function {node.name!r} times with raw perf_counter "
                 "pairs and never blocks (use obs.sync_elapsed / "
                 "obs.timed / block_until_ready)"))
    return hits


def _is_xla_key(node) -> bool:
    return isinstance(node, ast.Constant) and node.value == "XLA_FLAGS"


def _xla_flag_hits(tree: ast.AST) -> List:
    """Direct XLA_FLAGS writes: ``env["XLA_FLAGS"] = ...`` (any mapping)
    and ``.setdefault("XLA_FLAGS", ...)``."""
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) and _is_xla_key(t.slice):
                    hits.append(
                        (node.lineno, 'sets ["XLA_FLAGS"] directly '
                         "(use repro.runtime.platform)"))
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "setdefault"
                    and node.args and _is_xla_key(node.args[0])):
                hits.append(
                    (node.lineno, 'setdefault("XLA_FLAGS", ...) '
                     "(use repro.runtime.platform)"))
    return hits


def _assignment3d_hits(tree: ast.AST) -> List:
    """Direct ``Assignment3D(...)`` calls (by name or attribute)."""
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = f.attr if isinstance(f, ast.Attribute) else \
            f.id if isinstance(f, ast.Name) else None
        if name == "Assignment3D":
            hits.append(
                (node.lineno,
                 "constructs Assignment3D directly (build it with "
                 "assign_3d_lpt or inject via plan_matmul(assignment=...) "
                 "so validate_assignment gates it)"))
    return hits


def _module_hits(tree: ast.AST, mod: str, parent: str, leaf: str) -> List:
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.name
                if name == mod or name.startswith(mod + "."):
                    hits.append((node.lineno, f"import {name}"))
        elif isinstance(node, ast.ImportFrom):
            src = node.module or ""
            if src == mod or src.startswith(mod + "."):
                hits.append((node.lineno, f"from {src} import ..."))
            elif src == parent:
                for alias in node.names:
                    if alias.name == leaf:
                        hits.append((node.lineno,
                                     f"from {parent} import {leaf}"))
    return hits


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SourceRule:
    """One AST-level hygiene rule.

    ``scan(tree)`` returns ``[(lineno, description), ...]`` hits for one
    parsed file; ``dirs``/``allow`` bound where the rule applies.
    """

    id: str
    description: str
    dirs: Tuple[str, ...]
    allow: Tuple[str, ...]
    scan: Callable[[ast.AST], List[Tuple[int, str]]]


def _make_rules() -> Tuple[SourceRule, ...]:
    rules = []
    for mod, cfg in FORBIDDEN_MODULES.items():
        rules.append(SourceRule(
            id=f"source.import.{mod}",
            description=f"no imports of internal/deprecated module {mod} "
                        "(go through repro.core.api / the package "
                        "__init__)",
            dirs=tuple(cfg["dirs"]),
            allow=tuple(cfg["allow"]),
            scan=(lambda tree, m=mod, c=cfg:
                  _module_hits(tree, m, c["parent"], c["leaf"])),
        ))
    rules.append(SourceRule(
        id="source.xla-flags-write",
        description="XLA_FLAGS is written only by repro/runtime/"
                    "platform.py (XLA reads it once at backend init)",
        dirs=XLA_FLAG_DIRS,
        allow=XLA_FLAG_ALLOW,
        scan=_xla_flag_hits,
    ))
    rules.append(SourceRule(
        id="source.assignment3d-construction",
        description="Assignment3D is constructed only by core/schedule.py "
                    "(assign_3d_lpt), core/steal3d.py and runtime/"
                    "replan.py, so every assignment passes "
                    "validate_assignment",
        dirs=ASSIGNMENT3D_DIRS,
        allow=ASSIGNMENT3D_ALLOW,
        scan=_assignment3d_hits,
    ))
    rules.append(SourceRule(
        id="source.perf-counter-discipline",
        description="no raw perf_counter timing pairs without a blocking "
                    "helper (obs.sync_elapsed / obs.timed / "
                    "block_until_ready)",
        dirs=PERF_COUNTER_DIRS,
        allow=PERF_COUNTER_ALLOW,
        scan=_perf_counter_hits,
    ))
    return tuple(rules)


RULES: Tuple[SourceRule, ...] = _make_rules()


def iter_rules() -> Tuple[SourceRule, ...]:
    return RULES


def _allowed(rel_posix: str, allow: Sequence[str]) -> bool:
    return any(rel_posix == pre or rel_posix.startswith(pre + "/")
               for pre in allow)


def _waived(lines: List[str], lineno: int, rule_id: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    return f"# analysis: allow({rule_id})" in lines[lineno - 1]


def _scan(root: Optional[str] = None) -> List[dict]:
    """All hits as dicts {file, line, rule, desc}, waivers applied."""
    root_path = pathlib.Path(root) if root else \
        pathlib.Path(__file__).resolve().parents[3]
    cache: Dict[pathlib.Path, Tuple[ast.AST, List[str]]] = {}
    out = []
    for rule in RULES:
        for sub in rule.dirs:
            base = root_path / sub
            if not base.is_dir():
                continue
            for path in sorted(base.glob("**/*.py")):
                rel = path.relative_to(root_path).as_posix()
                if _allowed(rel, rule.allow):
                    continue
                if path not in cache:
                    text = path.read_text()
                    cache[path] = (ast.parse(text, filename=str(path)),
                                   text.splitlines())
                tree, lines = cache[path]
                for lineno, desc in rule.scan(tree):
                    if _waived(lines, lineno, rule.id):
                        continue
                    out.append({"file": rel, "line": lineno,
                                "rule": rule.id, "desc": desc})
    return out


def violations(root: Optional[str] = None) -> List[str]:
    """Legacy string form: sorted unique ``file:line: desc`` lines."""
    return sorted({f"{h['file']}:{h['line']}: {h['desc']}"
                   for h in _scan(root)})


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    as_json = "--json" in argv
    list_rules = "--list-rules" in argv
    args = [a for a in argv if a not in ("--json", "--list-rules")]
    if list_rules:
        if as_json:
            print(json.dumps([{"rule": r.id, "description": r.description}
                              for r in RULES], indent=2))
        else:
            for r in RULES:
                print(f"{r.id}: {r.description}")
        return 0
    root = args[0] if args else None
    if as_json:
        hits = _scan(root)
        print(json.dumps({"ok": not hits, "violations": hits}, indent=2))
        return 1 if hits else 0
    found = violations(root)
    if found:
        print("deprecated/internal module usage (use repro.core.api):")
        for v in found:
            print(f"  {v}")
        return 1
    scanned = sorted({d for cfg in FORBIDDEN_MODULES.values()
                      for d in cfg["dirs"]})
    print(f"check_api: OK ({', '.join(scanned)} are plan-API clean)")
    return 0
